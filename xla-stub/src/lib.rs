//! Offline stand-in for the `xla` (xla-rs) crate.
//!
//! The real crate links the XLA/PJRT C++ libraries, which are unavailable
//! in offline and CI environments.  This stub mirrors exactly the subset
//! of the xla-rs API that `metaml`'s PJRT backend uses, so that
//! `cargo check --features xla` type-checks the whole PJRT path with no
//! native dependencies:
//!
//! * [`Literal`] is a *real* host-side implementation (construction,
//!   reshape, readback, tuples) — literal marshaling round-trips work;
//! * [`PjRtClient`] and everything behind it returns a descriptive
//!   [`Error`] at runtime: there is no execution engine here.
//!
//! To run real AOT artifacts, repoint the `xla` dependency in the root
//! `Cargo.toml` at the actual xla-rs crate; no `metaml` source changes
//! are required.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the surface `metaml` relies on (`Display` + source).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: this build links the offline xla-stub crate; \
         point the `xla` dependency at the real xla-rs crate (with the XLA \
         C++ libraries installed) to execute PJRT artifacts"
    ))
}

/// Element types used by the metaml marshaling layer.  Non-exhaustive to
/// mirror the real crate's much larger dtype set (callers must keep a
/// fallback arm).
#[non_exhaustive]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Dense array shape: element type + dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Marker trait for element types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn store(data: &[Self]) -> Store;
    fn read(store: &Store) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn store(data: &[Self]) -> Store {
        Store::F32(data.to_vec())
    }
    fn read(store: &Store) -> Result<Vec<Self>> {
        match store {
            Store::F32(v) => Ok(v.clone()),
            Store::I32(_) => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn store(data: &[Self]) -> Store {
        Store::I32(data.to_vec())
    }
    fn read(store: &Store) -> Result<Vec<Self>> {
        match store {
            Store::I32(v) => Ok(v.clone()),
            Store::F32(_) => Err(Error("literal is not i32".into())),
        }
    }
}

/// Host-side literal: fully functional (unlike the execution types below).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: ArrayShape,
    store: Store,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            shape: ArrayShape { ty: T::TY, dims: vec![data.len() as i64] },
            store: T::store(data),
        }
    }

    fn len(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape.dims, dims
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty: self.shape.ty, dims: dims.to_vec() },
            store: self.store.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.store)
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come from executions), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literal decomposition"))
    }
}

/// Parsed HLO module handle (stub: construction always fails).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

/// Computation handle compiled from an HLO proto.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("the PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_validates_count() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn execution_surfaces_error_not_panic() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
