//! Customizability demo: a user-defined O-task integrated into a flow.
//!
//! The paper: "users can develop their own tasks and integrate them into
//! the design-flow."  Here we write WEIGHT-CLUSTER — an O-task that snaps
//! surviving weights to a small codebook (power-of-two clustering), which
//! lets the synthesizer fold multiplies into shifts — register it
//! alongside the built-ins, and run PRUNING → WEIGHT-CLUSTER → HLS4ML →
//! VIVADO-HLS.  Clustering only pays off when pruning kept the model
//! accurate, so the cluster step hangs off a **conditional edge**: if
//! pruned accuracy is below the bar, the flow bypasses WEIGHT-CLUSTER
//! straight to HLS4ML (both decisions land in the LOG).
//!
//!     cargo run --release --example custom_flow

use metaml::error::Result;
use metaml::flow::{
    CmpOp, EdgeGuard, Engine, FlowGraph, ParamSpec, PipeTask, Session, TaskCtx,
    TaskOutcome, TaskRegistry, TaskRole,
};
use metaml::metamodel::{Abstraction, MetaModel, ModelPayload};
use metaml::train::Trainer;

/// Snap each surviving weight to the nearest power of two (sign kept).
/// A classic FPGA trick: shift-add replaces multiply.
struct WeightClusterTask;

impl PipeTask for WeightClusterTask {
    fn name(&self) -> &str {
        "WEIGHT-CLUSTER"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![ParamSpec {
            name: "tolerate_acc_loss",
            description: "accepted accuracy drop from clustering",
            default: Some("0.02"),
        }]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let tolerance = ctx.cfg_f64("tolerate_acc_loss", 0.02);
        let input = ctx
            .meta
            .space
            .latest(Abstraction::Dnn)
            .cloned()
            .ok_or_else(|| metaml::Error::other("no DNN model"))?;
        let mut state = input.dnn()?.clone();
        let variant = ctx.session.manifest.get(&state.tag)?.clone();

        let exec = ctx.session.executable(&variant.tag)?;
        let data = ctx.session.dataset(&variant.model)?;
        let trainer = Trainer::new(&ctx.session.runtime, &exec, &data);
        let before = trainer.evaluate(&state)?;

        // snap weights (not biases) to ±2^k
        let mut snapped = 0usize;
        for l in 0..state.n_weight_layers() {
            let idx = state.weight_param_index(l);
            let w = state.params[idx].as_f32_mut()?;
            for v in w.iter_mut() {
                if *v != 0.0 {
                    let sign = v.signum();
                    let k = v.abs().log2().round();
                    *v = sign * 2f32.powf(k);
                    snapped += 1;
                }
            }
        }
        let after = trainer.evaluate(&state)?;
        ctx.log_metric("accuracy", after.accuracy);
        ctx.log_metric("snapped_weights", snapped as f64);
        ctx.log_message(format!(
            "clustered {snapped} weights to powers of two: acc {:.4} -> {:.4}",
            before.accuracy, after.accuracy
        ));
        if before.accuracy - after.accuracy > tolerance {
            ctx.log_message("accuracy drop above tolerance; keeping input model");
            return Ok(TaskOutcome::produced([input.id]));
        }

        let id = ctx.meta.space.store(
            format!("{}_clustered", variant.tag),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Dnn(state),
        );
        ctx.meta.space.set_metric(id, "accuracy", after.accuracy)?;
        for key in ["pruning_rate", "scale"] {
            if let Some(v) = input.metric(key) {
                ctx.meta.space.set_metric(id, key, v)?;
            }
        }
        Ok(TaskOutcome::produced([id]))
    }
}

fn main() -> Result<()> {
    let artifacts =
        std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let session = Session::open(&artifacts)?;

    // register the custom task next to the built-ins
    let mut registry = TaskRegistry::builtin();
    registry.register("WEIGHT-CLUSTER", || Box::new(WeightClusterTask));

    let mut flow = FlowGraph::new("custom-cluster-flow");
    let gen = flow.add_task("gen", "KERAS-MODEL-GEN");
    let prune = flow.add_task("prune", "PRUNING");
    let cluster = flow.add_task("cluster", "WEIGHT-CLUSTER");
    let hls = flow.add_task("hls4ml", "HLS4ML");
    let synth = flow.add_task("synth", "VIVADO-HLS");
    flow.connect(gen, prune)?;
    // conditional: cluster only a model that pruned well, else bypass
    let acc_bar = 0.5;
    flow.connect_when(
        prune,
        cluster,
        EdgeGuard { metric: "prune.accuracy".into(), op: CmpOp::Ge, value: acc_bar },
    )?;
    flow.connect_when(
        prune,
        hls,
        EdgeGuard { metric: "prune.accuracy".into(), op: CmpOp::Lt, value: acc_bar },
    )?;
    flow.connect(cluster, hls)?;
    flow.connect(hls, synth)?;

    let mut meta = MetaModel::new();
    meta.log.echo = true;
    meta.cfg.set("model", "jet_dnn");

    Engine::new(&session, &registry).run(&flow, &mut meta)?;

    let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
    println!(
        "\ncustom flow result: acc {:.2}%  DSP {}  LUT {}",
        100.0 * rtl.metric("accuracy").unwrap_or(0.0),
        rtl.metric("dsp").unwrap_or(0.0) as u64,
        rtl.metric("lut").unwrap_or(0.0) as u64,
    );
    Ok(())
}
