//! Quickstart: build and run the paper's pruning design flow (Fig 2a)
//! programmatically.
//!
//!     cargo run --release --example quickstart
//!
//! The flow is KERAS-MODEL-GEN → PRUNING → HLS4ML → VIVADO-HLS: train the
//! LHC jet tagger, auto-prune it by binary search, translate to an HLS
//! C++ model and synthesize an RTL resource/latency report.

use metaml::flow::{Engine, FlowGraph, Session, TaskRegistry};
use metaml::metamodel::{Abstraction, MetaModel};

fn main() -> metaml::Result<()> {
    // 1. open the session: PJRT runtime + AOT artifacts (`make artifacts`)
    let artifacts =
        std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let session = Session::open(&artifacts)?;
    let registry = TaskRegistry::builtin();

    // 2. compose the design flow as a task graph (paper Fig 2a)
    let mut flow = FlowGraph::new("quickstart-pruning");
    let gen = flow.add_task("gen", "KERAS-MODEL-GEN");
    let prune = flow.add_task("prune", "PRUNING");
    let hls = flow.add_task("hls4ml", "HLS4ML");
    let synth = flow.add_task("synth", "VIVADO-HLS");
    flow.connect(gen, prune)?;
    flow.connect(prune, hls)?;
    flow.connect(hls, synth)?;

    // 3. parameterize through the meta-model CFG (Table I parameters)
    let mut meta = MetaModel::new();
    meta.log.echo = true;
    meta.cfg.set("model", "jet_dnn");
    meta.cfg.set("prune.tolerate_acc_loss", 0.02); // α_p
    meta.cfg.set("prune.pruning_rate_thresh", 0.02); // β_p
    meta.cfg.set("hls4ml.FPGA_part_number", "vu9p");

    // 4. execute
    Engine::new(&session, &registry).run(&flow, &mut meta)?;

    // 5. inspect the model space
    let dnn = meta.space.latest(Abstraction::Dnn).unwrap();
    let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
    println!(
        "\npruned model: rate {:.1}%  accuracy {:.2}%",
        100.0 * dnn.metric("pruning_rate").unwrap_or(0.0),
        100.0 * dnn.metric("accuracy").unwrap_or(0.0),
    );
    println!("{}", metaml::synth::report::render(rtl.rtl()?));
    Ok(())
}
