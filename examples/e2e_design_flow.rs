//! End-to-end driver (the repo's full-system validation workload).
//!
//! Runs the complete MetaML stack on all three paper benchmarks — the
//! synthetic substitutes of Jet-HLF (Jet-DNN), MNIST (VGG7) and SVHN
//! (ResNet9) — executing for each:
//!
//!   1. the no-O-task baseline flow (train → HLS4ML → VIVADO-HLS), and
//!   2. the full cross-stage S→P→Q strategy (Fig 2b),
//!
//! then reports the paper's headline metric: DSP / LUT reduction at
//! matched accuracy.  Every probe of every search runs through the AOT
//! Pallas/XLA executables from rust via PJRT — Python is never invoked.
//!
//!     cargo run --release --example e2e_design_flow          # all models
//!     cargo run --release --example e2e_design_flow jet_dnn  # one model
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use metaml::config::builtin_flow;
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::metamodel::{Abstraction, MetaModel, ModelArtifact};
use metaml::report::table::Table;

struct RunResult {
    acc: f64,
    dsp: f64,
    lut: f64,
    cycles: f64,
    ns: f64,
    power: f64,
    secs: f64,
}

fn run_flow(
    session: &Session,
    registry: &TaskRegistry,
    flow_name: &str,
    model: &str,
    device: &str,
) -> metaml::Result<RunResult> {
    let spec = builtin_flow(flow_name)?;
    let mut meta = MetaModel::new();
    spec.apply_cfg(&mut meta.cfg);
    meta.cfg.set("model", model);
    meta.cfg.set("hls4ml.FPGA_part_number", device);
    meta.cfg.set("quantize.tolerate_acc_loss", 0.01);
    let t0 = Instant::now();
    Engine::new(session, registry).run(&spec.graph, &mut meta)?;
    let rtl: &ModelArtifact = meta
        .space
        .latest(Abstraction::Rtl)
        .ok_or_else(|| metaml::Error::other("no RTL artifact"))?;
    let m = |k: &str| rtl.metric(k).unwrap_or(0.0);
    Ok(RunResult {
        acc: m("accuracy"),
        dsp: m("dsp"),
        lut: m("lut"),
        cycles: m("latency_cycles"),
        ns: m("latency_ns"),
        power: m("power_w"),
        secs: t0.elapsed().as_secs_f64(),
    })
}

fn main() -> metaml::Result<()> {
    let artifacts =
        std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let session = Session::open(&artifacts)?;
    let registry = TaskRegistry::builtin();

    let only: Option<String> = std::env::args().nth(1);
    let workloads: Vec<(&str, &str)> = vec![
        ("jet_dnn", "vu9p"),
        ("vgg7_mini", "zynq7020"),
        ("resnet9_mini", "u250"),
    ];

    let mut table = Table::new(&[
        "model", "flow", "acc %", "DSP", "LUT", "cycles", "ns", "W", "wall s",
    ]);
    let mut headlines = Vec::new();

    for (model, device) in workloads {
        if let Some(o) = &only {
            if o != model {
                continue;
            }
        }
        println!("==> {model} on {device}: baseline flow");
        let base = run_flow(&session, &registry, "baseline", model, device)?;
        println!("==> {model} on {device}: S->P->Q flow");
        let spq = run_flow(&session, &registry, "s_p_q", model, device)?;

        for (name, r) in [("baseline", &base), ("s_p_q", &spq)] {
            table.row(&[
                model.to_string(),
                name.to_string(),
                format!("{:.2}", 100.0 * r.acc),
                format!("{:.0}", r.dsp),
                format!("{:.0}", r.lut),
                format!("{:.0}", r.cycles),
                format!("{:.0}", r.ns),
                format!("{:.3}", r.power),
                format!("{:.1}", r.secs),
            ]);
        }
        let dsp_red = if base.dsp > 0.0 { 100.0 * (1.0 - spq.dsp / base.dsp) } else { 0.0 };
        let lut_red = if base.lut > 0.0 { 100.0 * (1.0 - spq.lut / base.lut) } else { 0.0 };
        headlines.push(format!(
            "{model}: DSP -{dsp_red:.0}%  LUT -{lut_red:.0}%  accuracy {:.2}% -> {:.2}%",
            100.0 * base.acc,
            100.0 * spq.acc
        ));
    }

    println!("\n{}", table.render());
    println!("headline (paper claims up to 92% DSP / 89% LUT reduction):");
    for h in &headlines {
        println!("  {h}");
    }
    Ok(())
}
