//! Multi-flow exploration demo: run four flow *architectures*
//! concurrently from one spec and print the (accuracy, DSP, LUT, latency)
//! Pareto front.
//!
//! Uses the in-memory synthetic jet manifest (scale grid included), so
//! it runs on any machine — no `make artifacts` needed:
//!
//!     cargo run --release --example explore_flows
//!
//! The equivalent CLI invocation:
//!
//!     cargo run --release -- explore \
//!         --flow examples/specs/explore_jet.json --synthetic

use metaml::bench_support::synthetic_jet_manifest_scales;
use metaml::config::FlowSpec;
use metaml::error::Result;
use metaml::flow::explore::{expand_variants, explore_variants, front_table};
use metaml::flow::{Session, TaskRegistry};
use metaml::runtime::Runtime;

fn main() -> Result<()> {
    let spec = FlowSpec::load("examples/specs/explore_jet.json")?;
    let session = Session::with_backend(
        Runtime::cpu()?,
        synthetic_jet_manifest_scales(&[1.0, 0.75, 0.5]),
    );
    let registry = TaskRegistry::builtin();
    let jobs = metaml::dse::default_jobs();

    let variants = expand_variants(&spec)?;
    println!("exploring {} flow variants (jobs={jobs}):", variants.len());
    for v in &variants {
        println!("  - {}", v.label);
    }

    let outcome = explore_variants(&session, &registry, &variants, &[], jobs)?;

    println!("\n{}", front_table(&outcome).render());
    println!("Pareto front:");
    for &i in &outcome.front {
        let r = &outcome.results[i];
        println!(
            "  * {} (acc {:.4}, {} DSP, {} LUT)",
            r.label,
            r.metric("accuracy").unwrap_or(0.0),
            r.metric("dsp").unwrap_or(0.0) as u64,
            r.metric("lut").unwrap_or(0.0) as u64,
        );
    }
    Ok(())
}
