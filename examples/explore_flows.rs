//! Multi-flow exploration demo, exhaustive and budgeted: run flow
//! *architectures* concurrently from one spec and print the (accuracy,
//! DSP, LUT, latency) Pareto front — first the full grid of
//! `explore_jet.json`, then the budgeted NSGA-II search of
//! `search_jet.json` (half the evaluations, plus a continuous
//! clock-period dimension no grid could enumerate).
//!
//! Uses the in-memory synthetic jet manifest (scale grid included), so
//! it runs on any machine — no `make artifacts` needed:
//!
//!     cargo run --release --example explore_flows
//!
//! The equivalent CLI invocations:
//!
//!     cargo run --release -- explore \
//!         --flow examples/specs/explore_jet.json --synthetic
//!     cargo run --release -- explore \
//!         --flow examples/specs/search_jet.json --synthetic \
//!         --strategy evolve --budget 4 --seed 7

use metaml::bench_support::synthetic_jet_manifest_scales;
use metaml::config::FlowSpec;
use metaml::error::Result;
use metaml::flow::explore::{expand_variants, explore_variants, front_table};
use metaml::flow::{Session, TaskRegistry};
use metaml::runtime::Runtime;
use metaml::search::run_search;

fn main() -> Result<()> {
    let session = Session::with_backend(
        Runtime::cpu()?,
        synthetic_jet_manifest_scales(&[1.0, 0.75, 0.5]),
    );
    let registry = TaskRegistry::builtin();
    let jobs = metaml::dse::default_jobs();

    // 1. the exhaustive grid
    let spec = FlowSpec::load("examples/specs/explore_jet.json")?;
    let variants = expand_variants(&spec)?;
    println!("exploring {} flow variants (jobs={jobs}):", variants.len());
    for v in &variants {
        println!("  - {}", v.label);
    }

    let outcome = explore_variants(&session, &registry, &variants, &[], jobs)?;

    println!("\n{}", front_table(&outcome).render());
    println!("Pareto front:");
    for &i in &outcome.front {
        let r = &outcome.results[i];
        println!(
            "  * {} (acc {:.4}, {} DSP, {} LUT)",
            r.label,
            r.metric("accuracy").unwrap_or(0.0),
            r.metric("dsp").unwrap_or(0.0) as u64,
            r.metric("lut").unwrap_or(0.0) as u64,
        );
    }

    // 2. the budgeted search: the spec's `search` section asks for
    // NSGA-II evolution with a hardware-prefiltered seeding generation
    // and a continuous hls.clock_period range dimension
    let spec = FlowSpec::load("examples/specs/search_jet.json")?;
    let search = spec.search.clone().expect("search_jet.json declares a search section");
    println!(
        "\nbudgeted search: strategy '{}', budget {}, seed {}",
        search.strategy,
        search
            .budget
            .map(|b| b.to_string())
            .unwrap_or_else(|| "grid".into()),
        search.seed,
    );
    let out = run_search(&session, &registry, &spec, &search, &[], jobs)?;
    println!(
        "evaluated {} of {} grid variants ({} training probes issued, {} hardware)\n",
        out.evaluations(),
        out.grid_size,
        out.probes.train_issued,
        out.probes.hw_issued,
    );
    println!("{}", front_table(&out.outcome).render());
    Ok(())
}
