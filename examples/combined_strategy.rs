//! Combined cross-stage strategy (paper Fig 2b): SCALING → PRUNING →
//! HLS4ML → QUANTIZATION → VIVADO-HLS, and its reordered variant
//! (Fig 2c).  Demonstrates the paper's key claim that O-task order
//! matters: swapping the order is an edge-list change, nothing else.
//!
//!     cargo run --release --example combined_strategy

use metaml::config::builtin_flow;
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::metamodel::{Abstraction, MetaModel};

fn run(flow_name: &str, session: &Session, registry: &TaskRegistry) -> metaml::Result<()> {
    let spec = builtin_flow(flow_name)?;
    let mut meta = MetaModel::new();
    spec.apply_cfg(&mut meta.cfg);
    meta.cfg.set("model", "jet_dnn");
    meta.cfg.set("quantize.tolerate_acc_loss", 0.01); // α_q = 1%

    println!("=== flow {flow_name} ===");
    Engine::new(session, registry).run(&spec.graph, &mut meta)?;

    let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
    println!(
        "{:<8} acc {:.2}%  scale {:.3}  prune {:.1}%  DSP {}  LUT {}  {} cyc = {:.0} ns  {:.3} W\n",
        flow_name,
        100.0 * rtl.metric("accuracy").unwrap_or(0.0),
        rtl.metric("scale").unwrap_or(1.0),
        100.0 * rtl.metric("pruning_rate").unwrap_or(0.0),
        rtl.metric("dsp").unwrap_or(0.0) as u64,
        rtl.metric("lut").unwrap_or(0.0) as u64,
        rtl.metric("latency_cycles").unwrap_or(0.0) as u64,
        rtl.metric("latency_ns").unwrap_or(0.0),
        rtl.metric("power_w").unwrap_or(0.0),
    );
    Ok(())
}

fn main() -> metaml::Result<()> {
    let artifacts =
        std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let session = Session::open(&artifacts)?;
    let registry = TaskRegistry::builtin();

    // Fig 2(b): scaling → pruning → quantization
    run("s_p_q", &session, &registry)?;
    // Fig 2(c): different O-task order
    run("p_s_q", &session, &registry)?;
    // single-task reference
    run("pruning", &session, &registry)?;
    Ok(())
}
