"""L2 train/eval step builders (the functions that get AOT-lowered).

Flat-argument convention (the rust runtime marshals literals in exactly this
order — see modeldef.py):

``train_step(w0, b0, ..., m0, ..., qcfg, x, y, lr)``
    -> ``(w0', b0', ..., loss, acc)``
``eval_step(w0, b0, ..., m0, ..., qcfg, x, y)``
    -> ``(loss, acc)``

Plain SGD keeps the I/O surface small (no optimizer-state round-trips); the
rust trainer owns the schedule.  Gradients are masked inside the kernel VJP,
so pruned weights stay exactly zero across updates.
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .layers import softmax_cross_entropy
from .modeldef import ModelDef


def split_args(model: ModelDef, args) -> Tuple[list, list, jax.Array]:
    n_params = 2 * model.n_qcfg_rows
    n_masks = model.n_qcfg_rows
    params = list(args[:n_params])
    masks = list(args[n_params:n_params + n_masks])
    rest = args[n_params + n_masks:]
    return params, masks, rest


def make_loss_fn(model: ModelDef) -> Callable:
    def loss_fn(params, masks, qcfg, x, y):
        logits = model.forward(params, masks, qcfg, x)
        return softmax_cross_entropy(logits, y, model.n_classes)
    return loss_fn


def make_train_step(model: ModelDef) -> Callable:
    loss_fn = make_loss_fn(model)

    def train_step(*args):
        params, masks, rest = split_args(model, args)
        qcfg, x, y, lr = rest

        def scalar_loss(params):
            loss, acc = loss_fn(params, masks, qcfg, x, y)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(scalar_loss, has_aux=True)(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss, acc)

    return train_step


def make_eval_step(model: ModelDef) -> Callable:
    loss_fn = make_loss_fn(model)

    def eval_step(*args):
        params, masks, rest = split_args(model, args)
        qcfg, x, y = rest
        loss, acc = loss_fn(params, masks, qcfg, x, y)
        return (loss, acc)

    return eval_step


def example_args(model: ModelDef, fn: str) -> List[jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs in the flat-argument order, for jit().lower()."""
    f32 = jnp.float32
    specs: List[jax.ShapeDtypeStruct] = []
    for _, shape in model.param_shapes():
        specs.append(jax.ShapeDtypeStruct(shape, f32))
    for _, shape in model.mask_shapes():
        specs.append(jax.ShapeDtypeStruct(shape, f32))
    specs.append(jax.ShapeDtypeStruct((model.n_qcfg_rows, 2), f32))
    batch = model.train_batch if fn == "train" else model.eval_batch
    specs.append(jax.ShapeDtypeStruct((batch, *model.input_shape), f32))
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    if fn == "train":
        specs.append(jax.ShapeDtypeStruct((), f32))
    return specs
