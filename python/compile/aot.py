"""AOT exporter: lower every (model, scale, fn) to HLO text + manifest.

HLO *text* (NOT ``lowered.compile().serialize()`` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Run from python/:  ``python -m compile.aot --out-dir ../artifacts``
(make target ``artifacts`` does exactly this, and is a no-op when inputs are
unchanged).  Python never runs after this point — the rust binary is
self-contained given artifacts/.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

from . import models
from .modeldef import ModelDef
from .train import example_args, make_eval_step, make_train_step

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model_fn(model: ModelDef, fn: str) -> str:
    step = make_train_step(model) if fn == "train" else make_eval_step(model)
    specs = example_args(model, fn)
    lowered = jax.jit(step).lower(*specs)
    return to_hlo_text(lowered)


def export_one(model: ModelDef, out_dir: str, verbose: bool = True) -> dict:
    entry = model.manifest_entry()
    for fn in ("train", "eval"):
        t0 = time.time()
        text = lower_model_fn(model, fn)
        path = os.path.join(out_dir, entry["artifacts"][fn])
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(
                f"  {entry['artifacts'][fn]}: {len(text) / 1e6:.2f} MB "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models", default="all",
        help="comma-separated model names or 'all'",
    )
    args = ap.parse_args(argv)

    names = (
        list(models.BUILDERS) if args.models == "all" else args.models.split(",")
    )
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": MANIFEST_VERSION, "models": []}
    for name in names:
        for scale in models.SCALE_GRID[name]:
            model = models.build(name, scale)
            print(f"[aot] {model.tag}", flush=True)
            manifest["models"].append(export_one(model, args.out_dir))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['models'])} model variants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
