"""VGG7-mini: width-reduced VGG7 for the synthetic-MNIST workload.

The paper evaluates VGG7 [Simonyan & Zisserman] on MNIST; with a 1-core CPU
budget we keep the VGG topology (stacked 3x3 convs + pools + dense head) at
reduced width and 12x12x1 inputs.  DESIGN.md §1 documents the substitution:
the pruning/scaling searches only need over-parameterization, which the mini
retains (>10x params vs. task difficulty).
"""

from __future__ import annotations

from ..modeldef import LayerSpec, ModelDef, scale_dim

INPUT = (12, 12, 1)
N_CLASSES = 10
C1, C2, FC = 8, 16, 32


def build(scale: float = 1.0) -> ModelDef:
    c1 = scale_dim(C1, scale)
    c2 = scale_dim(C2, scale)
    fc = scale_dim(FC, scale)
    h, w, cin = INPUT
    m = ModelDef(
        name="vgg7_mini",
        scale=scale,
        input_shape=INPUT,
        n_classes=N_CLASSES,
        train_batch=64,
        eval_batch=256,
    )
    m.layers += [
        LayerSpec(kind="conv2d", activation="relu", in_dim=cin, out_dim=c1,
                  kernel=3, h=h, w=w, name="conv1"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c1, out_dim=c1,
                  kernel=3, h=h, w=w, name="conv2"),
        LayerSpec(kind="maxpool2"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c1, out_dim=c2,
                  kernel=3, h=h // 2, w=w // 2, name="conv3"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c2, out_dim=c2,
                  kernel=3, h=h // 2, w=w // 2, name="conv4"),
        LayerSpec(kind="maxpool2"),
        LayerSpec(kind="flatten"),
        LayerSpec(kind="dense", activation="relu",
                  in_dim=(h // 4) * (w // 4) * c2, out_dim=fc, name="fc1"),
        LayerSpec(kind="dense", activation="linear", in_dim=fc,
                  out_dim=N_CLASSES, name="output"),
    ]
    return m.finalize()
