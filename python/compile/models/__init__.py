"""L2 model zoo: the paper's three benchmark networks."""

from . import jet_dnn, resnet9_mini, vgg7_mini  # noqa: F401

BUILDERS = {
    "jet_dnn": jet_dnn.build,
    "vgg7_mini": vgg7_mini.build,
    "resnet9_mini": resnet9_mini.build,
}

# Scale grids pre-lowered at AOT time; the SCALING O-task walks these.
SCALE_GRID = {
    "jet_dnn": [1.0, 0.75, 0.5, 0.375, 0.25],
    "vgg7_mini": [1.0, 0.75, 0.5, 0.25],
    "resnet9_mini": [1.0, 0.75, 0.5, 0.25],
}


def build(name: str, scale: float = 1.0):
    return BUILDERS[name](scale)
