"""Jet-DNN: the hls4ml LHC jet-tagging MLP (Duarte et al., JINST 2018).

Architecture 16 -> 64 -> 32 -> 32 -> 5 (relu, softmax head), exactly the
network MetaML's Table II compares on VU9P.  ``scale`` shrinks the hidden
widths (the SCALING O-task selects among pre-lowered scale variants).
"""

from __future__ import annotations

from ..modeldef import LayerSpec, ModelDef, scale_dim

INPUT_FEATURES = 16
N_CLASSES = 5
HIDDEN = (64, 32, 32)


def build(scale: float = 1.0) -> ModelDef:
    dims = [INPUT_FEATURES] + [scale_dim(h, scale) for h in HIDDEN]
    m = ModelDef(
        name="jet_dnn",
        scale=scale,
        input_shape=(INPUT_FEATURES,),
        n_classes=N_CLASSES,
        train_batch=128,
        eval_batch=1024,
    )
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        m.layers.append(
            LayerSpec(kind="dense", activation="relu", in_dim=din, out_dim=dout,
                      name=f"fc{i + 1}")
        )
    m.layers.append(
        LayerSpec(kind="dense", activation="linear", in_dim=dims[-1],
                  out_dim=N_CLASSES, name="output")
    )
    return m.finalize()
