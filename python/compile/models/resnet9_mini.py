"""ResNet9-mini: width-reduced ResNet9 for the synthetic-SVHN workload.

Topology: stem conv -> residual block -> pool -> conv -> residual block ->
pool -> conv -> pool -> dense head = 9 weight layers (hence ResNet9), with
identity skips (He et al.).  16x16x3 inputs; widths reduced for the 1-core
budget (substitution documented in DESIGN.md §1).
"""

from __future__ import annotations

from ..modeldef import LayerSpec, ModelDef, scale_dim

INPUT = (16, 16, 3)
N_CLASSES = 10
C1, C2, C3 = 8, 16, 32


def build(scale: float = 1.0) -> ModelDef:
    c1 = scale_dim(C1, scale)
    c2 = scale_dim(C2, scale)
    c3 = scale_dim(C3, scale)
    h, w, cin = INPUT
    m = ModelDef(
        name="resnet9_mini",
        scale=scale,
        input_shape=INPUT,
        n_classes=N_CLASSES,
        train_batch=64,
        eval_batch=256,
    )
    m.layers += [
        LayerSpec(kind="conv2d", activation="relu", in_dim=cin, out_dim=c1,
                  kernel=3, h=h, w=w, name="stem"),
        # residual block 1 (16x16, c1)
        LayerSpec(kind="residual_begin"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c1, out_dim=c1,
                  kernel=3, h=h, w=w, name="res1a"),
        LayerSpec(kind="conv2d", activation="linear", in_dim=c1, out_dim=c1,
                  kernel=3, h=h, w=w, name="res1b"),
        LayerSpec(kind="residual_add"),
        LayerSpec(kind="maxpool2"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c1, out_dim=c2,
                  kernel=3, h=h // 2, w=w // 2, name="conv2"),
        # residual block 2 (8x8, c2)
        LayerSpec(kind="residual_begin"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c2, out_dim=c2,
                  kernel=3, h=h // 2, w=w // 2, name="res2a"),
        LayerSpec(kind="conv2d", activation="linear", in_dim=c2, out_dim=c2,
                  kernel=3, h=h // 2, w=w // 2, name="res2b"),
        LayerSpec(kind="residual_add"),
        LayerSpec(kind="maxpool2"),
        LayerSpec(kind="conv2d", activation="relu", in_dim=c2, out_dim=c3,
                  kernel=3, h=h // 4, w=w // 4, name="conv3"),
        LayerSpec(kind="maxpool2"),
        LayerSpec(kind="flatten"),
        LayerSpec(kind="dense", activation="linear",
                  in_dim=(h // 8) * (w // 8) * c3, out_dim=N_CLASSES,
                  name="output"),
    ]
    return m.finalize()
