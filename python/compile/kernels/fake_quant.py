"""L1 Pallas kernel: ap_fixed<W,I> fake quantization.

MetaML's QUANTIZATION O-task instruments ``ap_fixed<W, I>`` types into the
HLS C++ kernel and evaluates accuracy through co-simulation.  Here the
co-simulation *is* the AOT-compiled graph: this kernel emulates Vivado HLS
``ap_fixed`` round-to-nearest / saturate semantics on the TPU-style datapath
so the rust coordinator can probe any per-layer precision at runtime without
re-lowering.

The precision is a *runtime* operand ``q = (total_bits W, integer_bits I)``
(f32[2]): scale = 2^(W-I) is computed in-kernel (exp2), so one AOT artifact
serves every precision the search visits.  ``W == 0`` disables quantization
(identity) — that is how un-quantized baseline flows run through the same
executable.

Gradient: straight-through estimator clipped to the representable range,
matching QKeras' quantized_bits STE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fake_quant_kernel(x_ref, q_ref, o_ref):
    x = x_ref[...]
    w_bits = q_ref[0]
    i_bits = q_ref[1]
    frac = w_bits - i_bits
    scale = jnp.exp2(frac)
    # ap_fixed<W, I> (signed): representable range [-2^(I-1), 2^(I-1) - 2^-f].
    hi = jnp.exp2(i_bits - 1.0) - 1.0 / scale
    lo = -jnp.exp2(i_bits - 1.0)
    q = jnp.clip(jnp.round(x * scale) / scale, lo, hi)
    o_ref[...] = jnp.where(w_bits > 0.0, q, x)


def fake_quant_raw(x: jax.Array, q: jax.Array) -> jax.Array:
    """ap_fixed<W,I> round/saturate on a 2-D tensor; ``q = [W, I]`` (f32)."""
    if x.ndim != 2:
        raise ValueError(f"fake_quant expects 2-D input, got {x.shape}")
    return pl.pallas_call(
        _fake_quant_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, q)


@jax.custom_vjp
def fake_quant(x, q):
    return fake_quant_raw(x, q)


def _fq_fwd(x, q):
    return fake_quant_raw(x, q), (x, q)


def _fq_bwd(res, g):
    x, q = res
    # Straight-through inside the representable range, zero outside
    # (QKeras quantized_bits STE), identity when quantization is disabled.
    w_bits, i_bits = q[0], q[1]
    hi = jnp.exp2(i_bits - 1.0)
    enabled = w_bits > 0.0
    inside = jnp.logical_or(jnp.abs(x) <= hi, jnp.logical_not(enabled))
    return g * inside.astype(g.dtype), jnp.zeros_like(q)


fake_quant.defvjp(_fq_fwd, _fq_bwd)
