"""Pure-jnp oracles for the Pallas kernels.

These are the build-time correctness contract: pytest (and hypothesis
sweeps) assert the Pallas kernels match these to float tolerance across
shapes, masks and precisions.  They contain NO pallas — plain jnp only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for kernels.masked_matmul.matmul."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """Reference for kernels.masked_matmul.masked_matmul."""
    return jnp.matmul(x, w * mask, preferred_element_type=jnp.float32)


def fake_quant_ref(x: jax.Array, q: jax.Array) -> jax.Array:
    """Reference ap_fixed<W,I> round-to-nearest + saturate; q = [W, I]."""
    w_bits, i_bits = q[0], q[1]
    frac = w_bits - i_bits
    scale = jnp.exp2(frac)
    hi = jnp.exp2(i_bits - 1.0) - 1.0 / scale
    lo = -jnp.exp2(i_bits - 1.0)
    quantized = jnp.clip(jnp.round(x * scale) / scale, lo, hi)
    return jnp.where(w_bits > 0.0, quantized, x)


def qmm_ref(x: jax.Array, w: jax.Array, mask: jax.Array, q: jax.Array) -> jax.Array:
    """Reference for kernels.masked_matmul.qmm (fused quant+mask+matmul)."""
    return jnp.matmul(
        fake_quant_ref(x, q),
        fake_quant_ref(w, q) * mask,
        preferred_element_type=jnp.float32,
    )
