"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .masked_matmul import (  # noqa: F401
    DISABLED_Q,
    masked_matmul,
    masked_matmul_vjp,
    matmul,
    matmul_vjp,
    qmm,
    qmm_masked,
    qmm_plain,
)
from .fake_quant import fake_quant, fake_quant_raw  # noqa: F401
