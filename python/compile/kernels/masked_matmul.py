"""L1 Pallas kernels: fused quantize+mask+matmul — MetaML's compute hot-spot.

Every O-task probe (pruning fine-tune step, scaling trial, quantization
evaluation) is dominated by pruning/quantization-aware matrix multiplies:

    y = fq(x, q) @ (fq(w, q) * m)

where ``m`` is a {0,1} magnitude-pruning mask and ``fq`` emulates Vivado
HLS ``ap_fixed<W,I>`` round/saturate with *runtime* precision ``q = [W, I]``
(W == 0 disables quantization, so one artifact serves every precision the
search visits).

The paper's FPGA hot path is the fully-unrolled MAC array emitted by HLS;
the TPU rethink (DESIGN.md §Hardware-Adaptation) maps it onto the MXU:

* quantization and masking are applied to the operand tiles *inside* the
  kernel, in VMEM — the quantized/pruned weight never round-trips to HBM
  (this fusion is also what makes interpret-mode execution tractable: one
  pallas_call per matmul instead of separate quant + mask + matmul calls);
* BlockSpecs tile (M, K) x (K, N) into MXU-friendly blocks (128x128
  default, clamped to the problem) with K innermost so each (i, j) output
  tile accumulates in a VMEM f32 scratch accumulator;
* conv layers lower to the same kernel via im2col (TPU conv == MXU matmul).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the AOT
artifact executes on the rust CPU client.  Real-TPU VMEM/MXU estimates
live in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Tile selection is target-dependent:
#
# * Real TPU (compile-only target here): MXU_BLOCK — 128x128 tiles matching
#   the systolic array, VMEM-bounded K-accumulation.  This is the BlockSpec
#   schedule DESIGN.md §Perf analyzes (VMEM footprint, MXU utilization).
# * CPU interpret mode (what the AOT artifacts run): every grid step costs
#   ~1.3 ms of dynamic-slice loop machinery, so tiles are inflated until
#   each hot matmul is a single block (measured 160 ms -> 0.6 ms for the
#   conv1 im2col matmul; see EXPERIMENTS.md §Perf L1).  The kernel code is
#   identical — only the block edges change.
MXU_BLOCK = (128, 128, 128)
INTERPRET_BLOCK = (16384, 16384, 16384)
DEFAULT_BLOCK = INTERPRET_BLOCK

DISABLED_Q = (0.0, 0.0)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _fq_tile(t, q_ref):
    """ap_fixed<W,I> round/saturate of a VMEM tile; identity when W == 0."""
    w_bits = q_ref[0, 0]
    i_bits = q_ref[0, 1]
    scale = jnp.exp2(w_bits - i_bits)
    hi = jnp.exp2(i_bits - 1.0) - 1.0 / scale
    lo = -jnp.exp2(i_bits - 1.0)
    quant = jnp.clip(jnp.round(t * scale) / scale, lo, hi)
    return jnp.where(w_bits > 0.0, quant, t)


def _qmm_masked_kernel(x_ref, w_ref, m_ref, qa_ref, qb_ref, o_ref, acc_ref, *, n_k):
    """Grid (i, j, k): o[i,j] += fq(x[i,k]) @ (fq(w[k,j]) * m[k,j])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _fq_tile(x_ref[...], qa_ref)
    b = _fq_tile(w_ref[...], qb_ref) * m_ref[...]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _qmm_plain_kernel(x_ref, w_ref, qa_ref, qb_ref, o_ref, acc_ref, *, n_k):
    """Unmasked variant (used by the dw backward pass)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _fq_tile(x_ref[...], qa_ref)
    b = _fq_tile(w_ref[...], qb_ref)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, requested: int) -> int:
    """Clamp a requested block edge to the (padded) problem size."""
    return min(requested, max(_ceil_to(dim, 8), 8))


def _as_q(q) -> jax.Array:
    """Normalize a precision spec to the (1, 2) f32 operand layout."""
    q = jnp.asarray(q, jnp.float32)
    return q.reshape(1, 2)


def _run(kernel, xw, kn_operands, qs, block):
    """Launch a tiled kernel: ``xw`` = (M,K) operand, ``kn_operands`` =
    (K,N) operands (weight [, mask]), ``qs`` = precision operands."""
    x = xw
    w = kn_operands[0]
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m_dim, k_dim = x.shape
    _, n_dim = w.shape

    bm = _pick_block(m_dim, block[0])
    bn = _pick_block(n_dim, block[1])
    bk = _pick_block(k_dim, block[2])
    mp, kp, np_ = _ceil_to(m_dim, bm), _ceil_to(k_dim, bk), _ceil_to(n_dim, bn)

    def pad(a, rows, cols):
        if a.shape == (rows, cols):
            return a
        return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))

    operands = [pad(x, mp, kp)]
    operands += [pad(op, kp, np_) for op in kn_operands]
    operands += [_as_q(q) for q in qs]

    n_k = kp // bk
    grid = (mp // bm, np_ // bn, n_k)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    in_specs += [
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)) for _ in kn_operands
    ]
    # precision operands: one (1, 2) block broadcast to every grid step
    in_specs += [pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)) for _ in qs]

    out = pl.pallas_call(
        functools.partial(kernel, n_k=n_k),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        # f32 accumulator lives in VMEM for the whole (i, j) K-sweep.
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(*operands)
    return out[:m_dim, :n_dim]


# ---------------------------------------------------------------------------
# raw kernel entry points
# ---------------------------------------------------------------------------


def qmm_masked(x, w, mask, qa, qb, *, block=DEFAULT_BLOCK):
    """``fq(x, qa) @ (fq(w, qb) * mask)`` — the fused hot-spot kernel."""
    return _run(_qmm_masked_kernel, x, [w, mask], [qa, qb], block)


def qmm_plain(x, w, qa, qb, *, block=DEFAULT_BLOCK):
    """``fq(x, qa) @ fq(w, qb)`` (no mask)."""
    return _run(_qmm_plain_kernel, x, [w], [qa, qb], block)


def matmul(x, w, *, block=DEFAULT_BLOCK):
    """Plain tiled Pallas matmul (quantization disabled)."""
    return qmm_plain(x, w, DISABLED_Q, DISABLED_Q, block=block)


def masked_matmul(x, w, mask, *, block=DEFAULT_BLOCK):
    """``x @ (w * mask)`` (quantization disabled)."""
    return qmm_masked(x, w, mask, DISABLED_Q, DISABLED_Q, block=block)


# ---------------------------------------------------------------------------
# differentiable wrapper (pallas_call has no VJP rule; backward re-uses the
# same fused kernels so fwd AND bwd stay on the MXU path)
# ---------------------------------------------------------------------------


def _ste(t, q):
    """Straight-through mask: 1 inside the representable range (or when
    quantization is disabled), 0 where the forward pass saturated."""
    q = jnp.asarray(q, jnp.float32).reshape(2)
    w_bits, i_bits = q[0], q[1]
    hi = jnp.exp2(i_bits - 1.0)
    enabled = w_bits > 0.0
    inside = jnp.logical_or(jnp.abs(t) <= hi, jnp.logical_not(enabled))
    return inside.astype(t.dtype)


@jax.custom_vjp
def qmm(x, w, mask, q):
    """Differentiable fused quantized+masked matmul with shared layer
    precision ``q = [W, I]`` for activations and weights."""
    return qmm_masked(x, w, mask, q, q)


def _qmm_fwd(x, w, mask, q):
    return qmm_masked(x, w, mask, q, q), (x, w, mask, q)


def _qmm_bwd(res, g):
    x, w, mask, q = res
    # dx = (g @ (fq(w) * m)^T) * ste(x): quantize only the weight operand.
    dx = qmm_masked(g, w.T, mask.T, DISABLED_Q, q) * _ste(x, q)
    # dw = (fq(x)^T @ g) * m * ste(w): pruned weights stay dead, saturated
    # weights get no gradient (QKeras quantized_bits STE semantics).
    dw = qmm_plain(x.T, g, q, DISABLED_Q) * mask * _ste(w, q)
    return dx, dw, jnp.zeros_like(mask), jnp.zeros_like(jnp.asarray(q, jnp.float32))


qmm.defvjp(_qmm_fwd, _qmm_bwd)


@jax.custom_vjp
def masked_matmul_vjp(x, w, mask):
    return masked_matmul(x, w, mask)


def _mmm_fwd(x, w, mask):
    return masked_matmul(x, w, mask), (x, w, mask)


def _mmm_bwd(res, g):
    x, w, mask = res
    dx = masked_matmul(g, w.T, mask.T)
    dw = matmul(x.T, g) * mask
    return dx, dw, jnp.zeros_like(mask)


masked_matmul_vjp.defvjp(_mmm_fwd, _mmm_bwd)


@jax.custom_vjp
def matmul_vjp(x, w):
    return matmul(x, w)


def _mm_fwd(x, w):
    return matmul(x, w), (x, w)


def _mm_bwd(res, g):
    x, w = res
    return matmul(g, w.T), matmul(x.T, g)


matmul_vjp.defvjp(_mm_fwd, _mm_bwd)
