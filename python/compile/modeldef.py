"""Model descriptors shared between the L2 graph builder and the manifest.

A ``ModelDef`` is a declarative layer list with concrete shapes; it drives
three consumers:

1. the JAX forward builder (``forward``),
2. the AOT manifest (param/mask/qcfg ordering the rust runtime relies on),
3. the rust HLS4ML λ-task (layer dims → HLS IR → resource estimation).

Parameter order convention (the rust side indexes by this):
``[w0, b0, w1, b1, ...]`` over *weight layers* (dense/conv) in graph order;
masks ``[m0 ... m_{L-1}]`` align 1:1 with the weight tensors; ``qcfg`` is
``f32[L, 2]`` with row l = ``[total_bits, int_bits]`` for layer l.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


def scale_dim(dim: int, scale: float, multiple: int = 4, floor: int = 4) -> int:
    """Scale a hidden dimension, rounding to a hardware-friendly multiple."""
    return max(floor, int(round(dim * scale / multiple)) * multiple)


@dataclass
class LayerSpec:
    """One weight layer (dense or conv) or a structural op."""

    kind: str  # dense | conv2d | maxpool2 | flatten | residual_begin | residual_add
    activation: str = "linear"
    in_dim: int = 0      # dense: fan-in; conv: Cin
    out_dim: int = 0     # dense: fan-out; conv: Cout
    kernel: int = 0      # conv only
    h: int = 0           # conv only: input spatial dims
    w: int = 0
    param_w: int = -1    # index into the flat param list
    param_b: int = -1
    mask_idx: int = -1   # index into the mask list / qcfg row
    name: str = ""

    @property
    def is_weight(self) -> bool:
        return self.kind in ("dense", "conv2d")

    def macs(self) -> int:
        """Multiply-accumulates for one inference (dense basis for HLS est.)."""
        if self.kind == "dense":
            return self.in_dim * self.out_dim
        if self.kind == "conv2d":
            return self.h * self.w * self.kernel * self.kernel * self.in_dim * self.out_dim
        return 0

    def weight_shape(self) -> Tuple[int, ...]:
        if self.kind == "dense":
            return (self.in_dim, self.out_dim)
        if self.kind == "conv2d":
            return (self.kernel, self.kernel, self.in_dim, self.out_dim)
        raise ValueError(f"{self.kind} has no weights")


@dataclass
class ModelDef:
    name: str
    scale: float
    input_shape: Tuple[int, ...]  # without batch; (F,) or (H, W, C)
    n_classes: int
    train_batch: int
    eval_batch: int
    layers: List[LayerSpec] = field(default_factory=list)

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    def _weight_layers(self) -> List[LayerSpec]:
        return [l for l in self.layers if l.is_weight]

    def finalize(self) -> "ModelDef":
        """Assign param / mask / qcfg indices in graph order."""
        p = 0
        m = 0
        for l in self.layers:
            if l.is_weight:
                l.param_w, l.param_b, l.mask_idx = p, p + 1, m
                p += 2
                m += 1
        return self

    @property
    def tag(self) -> str:
        return f"{self.name}_s{int(round(self.scale * 1000)):04d}"

    @property
    def n_qcfg_rows(self) -> int:
        return len(self._weight_layers())

    # ------------------------------------------------------------------
    # shapes (the contract with the rust runtime)
    # ------------------------------------------------------------------
    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        out: List[Tuple[str, Tuple[int, ...]]] = []
        for i, l in enumerate(self._weight_layers()):
            out.append((f"w{i}", l.weight_shape()))
            out.append((f"b{i}", (l.out_dim,)))
        return out

    def mask_shapes(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """(aligned param index, shape) per weight tensor."""
        return [(l.param_w, l.weight_shape()) for l in self._weight_layers()]

    # ------------------------------------------------------------------
    # forward graph
    # ------------------------------------------------------------------
    def forward(self, params, masks, qcfg, x):
        """Build the quantization/pruning-aware forward pass (logits)."""
        stack = []  # residual skip stack
        for l in self.layers:
            if l.kind == "dense":
                x = L.qdense(
                    x, params[l.param_w], params[l.param_b], masks[l.mask_idx],
                    qcfg[l.mask_idx], l.activation,
                )
            elif l.kind == "conv2d":
                x = L.qconv2d(
                    x, params[l.param_w], params[l.param_b], masks[l.mask_idx],
                    qcfg[l.mask_idx], l.activation,
                )
            elif l.kind == "maxpool2":
                x = L.maxpool2(x)
            elif l.kind == "flatten":
                x = L.flatten(x)
            elif l.kind == "residual_begin":
                stack.append(x)
            elif l.kind == "residual_add":
                x = jax.nn.relu(x + stack.pop())
            else:
                raise ValueError(f"unknown layer kind {l.kind!r}")
        return x

    # ------------------------------------------------------------------
    # manifest
    # ------------------------------------------------------------------
    def manifest_entry(self) -> dict:
        return {
            "model": self.name,
            "scale": self.scale,
            "tag": self.tag,
            "input_shape": list(self.input_shape),
            "n_classes": self.n_classes,
            "train_batch": self.train_batch,
            "eval_batch": self.eval_batch,
            "params": [
                {"name": n, "shape": list(s)} for n, s in self.param_shapes()
            ],
            "masks": [
                {"param": p, "shape": list(s)} for p, s in self.mask_shapes()
            ],
            "qcfg_rows": self.n_qcfg_rows,
            "layers": [
                {
                    "kind": l.kind,
                    "name": l.name,
                    "activation": l.activation,
                    "in_dim": l.in_dim,
                    "out_dim": l.out_dim,
                    "kernel": l.kernel,
                    "h": l.h,
                    "w": l.w,
                    "param_w": l.param_w,
                    "param_b": l.param_b,
                    "mask_idx": l.mask_idx,
                    "macs": l.macs(),
                }
                for l in self.layers
            ],
            "artifacts": {
                "train": f"{self.tag}_train.hlo.txt",
                "eval": f"{self.tag}_eval.hlo.txt",
            },
        }
