"""L2 building blocks: quantization/pruning-aware layers on the L1 kernels.

Every multiply in the model zoo routes through the single Pallas
``masked_matmul`` kernel (conv via im2col — the TPU mapping of conv onto the
MXU).  Quantization is runtime-controlled per layer through ``qcfg`` rows
``[total_bits, int_bits]`` (W == 0 disables), pruning through {0,1} masks on
the weight matrices.  See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fake_quant, qmm


def quantize(x2d: jax.Array, q: jax.Array) -> jax.Array:
    """ap_fixed fake-quantize a 2-D tensor with runtime precision ``q``."""
    return fake_quant(x2d, q)


def quantize_nd(x: jax.Array, q: jax.Array) -> jax.Array:
    """Fake-quantize an arbitrary-rank tensor (kernel is 2-D)."""
    flat = x.reshape(-1, x.shape[-1])
    return fake_quant(flat, q).reshape(x.shape)


def apply_activation(x: jax.Array, name: str) -> jax.Array:
    if name == "relu":
        return jax.nn.relu(x)
    if name == "linear":
        return x
    raise ValueError(f"unknown activation {name!r}")


def qdense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mask: jax.Array,
    q: jax.Array,
    activation: str = "relu",
) -> jax.Array:
    """Quantized, pruned dense layer: act(fq(x) @ (fq(w) * mask) + b).

    Matches the HLS dense block: inputs and weights are ap_fixed<W,I>,
    the MAC accumulates wide (f32 here ~ the wide accumulator in HLS),
    output re-quantized by the *next* layer's input quantization.  The
    quantize+mask+matmul is ONE fused Pallas kernel (see kernels/).
    """
    y = qmm(x, w, mask, q) + b
    return apply_activation(y, activation)


def im2col(x: jax.Array, k: int) -> jax.Array:
    """[B,H,W,C] -> [B*H*W, k*k*C] SAME-padded patches (stride 1)."""
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns feature dim ordered as C*k*k
    # (channel-major); weights are reshaped to match in qconv2d.
    return patches.reshape(b * h * w, c * k * k)


def qconv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mask: jax.Array,
    q: jax.Array,
    activation: str = "relu",
) -> jax.Array:
    """Quantized, pruned 3x3 SAME conv as im2col + masked matmul.

    ``w``: [k, k, Cin, Cout] (HWIO); ``mask`` matches ``w``.  The matmul
    operand is [Cin*k*k, Cout] to match conv_general_dilated_patches'
    channel-major patch ordering.
    """
    bsz, h, wd, cin = x.shape
    k = w.shape[0]
    cout = w.shape[3]
    cols = im2col(x, k)  # [B*H*W, Cin*k*k]
    # HWIO -> (Cin, k, k, Cout) -> [Cin*k*k, Cout]
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * k * k, cout)
    m2 = jnp.transpose(mask, (2, 0, 1, 3)).reshape(cin * k * k, cout)
    y = qmm(cols, w2, m2, q) + b
    y = y.reshape(bsz, h, wd, cout)
    return apply_activation(y, activation)


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 max pool, stride 2 (VALID)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def flatten(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[0], -1)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, n_classes: int):
    """Mean CE loss + accuracy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, n_classes, dtype=logits.dtype)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc
