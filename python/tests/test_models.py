"""L2 model-zoo tests: shapes, manifest contract, learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models
from compile.modeldef import scale_dim
from compile.train import example_args, make_eval_step, make_train_step

ALL_MODELS = list(models.BUILDERS)


def make_args(model, fn, seed=0, lr=0.05, glorot=False):
    """Random flat args honoring the manifest ordering."""
    key = jax.random.PRNGKey(seed)
    specs = example_args(model, fn)
    args = []
    for s in specs:
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            args.append(jax.random.randint(sub, s.shape, 0, model.n_classes))
        elif s.shape == ():
            args.append(jnp.float32(lr))
        else:
            args.append(0.1 * jax.random.normal(sub, s.shape, dtype=jnp.float32))
    n_p = 2 * model.n_qcfg_rows
    if glorot:
        key = jax.random.PRNGKey(seed + 1)
        for i in range(0, n_p, 2):
            key, sub = jax.random.split(key)
            shape = args[i].shape
            fan_in = int(np.prod(shape[:-1]))
            args[i] = jax.random.normal(sub, shape, dtype=jnp.float32) / np.sqrt(fan_in)
            args[i + 1] = jnp.zeros_like(args[i + 1])
    for i in range(n_p, n_p + model.n_qcfg_rows):
        args[i] = jnp.ones_like(args[i])  # masks = keep all
    args[n_p + model.n_qcfg_rows] = jnp.zeros(
        (model.n_qcfg_rows, 2), jnp.float32
    )  # quantization disabled
    return args


@pytest.mark.parametrize("name", ALL_MODELS)
def test_manifest_param_ordering(name):
    m = models.build(name, 1.0)
    entry = m.manifest_entry()
    assert entry["qcfg_rows"] == m.n_qcfg_rows
    assert len(entry["params"]) == 2 * m.n_qcfg_rows
    assert len(entry["masks"]) == m.n_qcfg_rows
    # masks point at the weight tensors, with matching shapes
    for mask in entry["masks"]:
        assert entry["params"][mask["param"]]["shape"] == mask["shape"]
    # weight layers carry consistent indices
    widx = [l for l in entry["layers"] if l["param_w"] >= 0]
    assert [l["mask_idx"] for l in widx] == list(range(len(widx)))


@pytest.mark.parametrize("name", ALL_MODELS)
def test_forward_shapes(name):
    m = models.build(name, 1.0)
    args = make_args(m, "eval")
    step = jax.jit(make_eval_step(m))
    loss, acc = step(*args)
    assert loss.shape == () and acc.shape == ()
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("name", ALL_MODELS)
def test_scaling_shrinks_params(name):
    big = models.build(name, 1.0)
    small = models.build(name, 0.25)
    n = lambda m: sum(int(np.prod(s)) for _, s in m.param_shapes())
    assert n(small) < n(big)
    # in/out contract preserved
    assert small.input_shape == big.input_shape
    assert small.n_classes == big.n_classes


def test_scale_dim_rounding():
    assert scale_dim(64, 1.0) == 64
    assert scale_dim(64, 0.5) == 32
    assert scale_dim(4, 0.25) == 4  # floor
    assert scale_dim(30, 0.5) % 4 == 0


def test_train_step_learns_jet():
    """SGD on a *learnable* fixed batch must drop the loss substantially."""
    m = models.build("jet_dnn", 1.0)
    args = make_args(m, "train", lr=0.5, glorot=True)
    # structured labels: a fixed random linear map of the inputs
    key = jax.random.PRNGKey(42)
    x = args[-3]
    proj = jax.random.normal(key, (x.shape[1], m.n_classes))
    args[-2] = jnp.argmax(x @ proj, axis=-1).astype(jnp.int32)
    step = jax.jit(make_train_step(m))
    n_p = 2 * m.n_qcfg_rows
    first = None
    for _ in range(100):
        out = step(*args)
        args[:n_p] = list(out[:n_p])
        loss = float(out[-2])
        first = loss if first is None else first
    assert loss < first * 0.75, (first, loss)
    assert float(out[-1]) > 0.4  # accuracy well above 20% chance


def test_train_step_respects_masks():
    """Weights pruned at step 0 must remain exactly zero after updates."""
    m = models.build("jet_dnn", 0.5)
    args = make_args(m, "train", lr=0.1)
    n_p = 2 * m.n_qcfg_rows
    key = jax.random.PRNGKey(3)
    # prune ~half of each weight matrix and zero those weights
    for i, (pidx, _) in enumerate(m.mask_shapes()):
        key, sub = jax.random.split(key)
        mask = (jax.random.uniform(sub, args[pidx].shape) < 0.5).astype(jnp.float32)
        args[n_p + i] = mask
        args[pidx] = args[pidx] * mask
    step = jax.jit(make_train_step(m))
    for _ in range(5):
        out = step(*args)
        args[:n_p] = list(out[:n_p])
    for i, (pidx, _) in enumerate(m.mask_shapes()):
        w = np.asarray(args[pidx])
        mask = np.asarray(args[n_p + i])
        np.testing.assert_array_equal(w * (1 - mask), 0.0)


def test_quantization_affects_logits():
    """Aggressive quantization must perturb the logits; 18,8 barely."""
    m = models.build("jet_dnn", 1.0)
    args = make_args(m, "eval", glorot=True)
    n_p = 2 * m.n_qcfg_rows
    params = args[:n_p]
    masks = args[n_p:n_p + m.n_qcfg_rows]
    x = args[-2]

    def logits(q):
        qcfg = jnp.tile(jnp.array([q], jnp.float32), (m.n_qcfg_rows, 1))
        return m.forward(params, masks, qcfg, x)

    base = logits([0.0, 0.0])
    hi = logits([18.0, 8.0])
    lo = logits([3.0, 2.0])
    assert float(jnp.abs(hi - base).max()) < 0.05
    assert float(jnp.abs(lo - base).max()) > 0.1


@pytest.mark.parametrize("name", ALL_MODELS)
def test_scale_grid_builds(name):
    for scale in models.SCALE_GRID[name]:
        m = models.build(name, scale)
        assert m.tag.endswith(f"s{int(round(scale * 1000)):04d}")
        assert m.n_qcfg_rows >= 4
