"""AOT exporter tests: HLO text round-trips and manifest consistency."""

import json
import os

import pytest

from compile import models
from compile.aot import lower_model_fn


def test_hlo_text_is_parseable_hlo():
    """Lowered text must be HLO (not stablehlo/MLIR): the rust loader's
    contract is HloModuleProto::from_text_file."""
    m = models.build("jet_dnn", 0.25)
    text = lower_model_fn(m, "eval")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => root is a tuple
    assert "(f32[]" in text or "tuple(" in text


def test_train_output_arity():
    """train returns params' + loss + acc => tuple arity = 2L + 2."""
    m = models.build("jet_dnn", 0.25)
    text = lower_model_fn(m, "train")
    n_out = 2 * m.n_qcfg_rows + 2
    # the ENTRY root tuple lists one shape per output
    entry = text[text.index("ENTRY"):]
    root_line = [l for l in entry.splitlines() if "ROOT" in l][0]
    assert root_line.count("f32[") >= n_out


def test_manifest_matches_scale_grid():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    manifest = json.load(open(path))
    tags = {e["tag"] for e in manifest["models"]}
    for name, grid in models.SCALE_GRID.items():
        for s in grid:
            assert models.build(name, s).tag in tags


def test_manifest_artifact_files_exist():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    path = os.path.join(root, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    manifest = json.load(open(path))
    for entry in manifest["models"]:
        for fn in ("train", "eval"):
            assert os.path.exists(os.path.join(root, entry["artifacts"][fn])), (
                entry["tag"], fn)
