"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes / masks / precisions — the CORE correctness signal
for everything the rust coordinator later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fake_quant,
    fake_quant_raw,
    masked_matmul,
    masked_matmul_vjp,
    matmul,
    matmul_vjp,
)
from compile.kernels.ref import fake_quant_ref, masked_matmul_ref, matmul_ref

DIMS = st.integers(min_value=1, max_value=40)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# plain matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    k1, k2 = keys(seed, 2)
    x, w = rand(k1, m, k), rand(k2, k, n)
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_larger_than_block():
    """Shapes crossing the 128 tile boundary exercise the K-accumulation."""
    k1, k2 = keys(7, 2)
    x, w = rand(k1, 130, 257), rand(k2, 257, 131)
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=2e-4, atol=2e-4
    )


def test_matmul_custom_block():
    k1, k2 = keys(9, 2)
    x, w = rand(k1, 48, 64), rand(k2, 64, 32)
    out = matmul(x, w, block=(16, 16, 16))
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.ones((3, 4)), jnp.ones((5, 6)))
    with pytest.raises(ValueError):
        matmul(jnp.ones((3,)), jnp.ones((3, 4)))


# ---------------------------------------------------------------------------
# masked matmul (pruning path)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=DIMS, k=DIMS, n=DIMS,
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matmul_matches_ref(m, k, n, density, seed):
    k1, k2, k3 = keys(seed, 3)
    x, w = rand(k1, m, k), rand(k2, k, n)
    mask = (jax.random.uniform(k3, (k, n)) < density).astype(jnp.float32)
    np.testing.assert_allclose(
        masked_matmul(x, w, mask),
        masked_matmul_ref(x, w, mask),
        rtol=1e-5, atol=1e-5,
    )


def test_masked_matmul_zero_mask_is_zero():
    k1, k2 = keys(3, 2)
    x, w = rand(k1, 9, 17), rand(k2, 17, 5)
    out = masked_matmul(x, w, jnp.zeros((17, 5)))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((9, 5), np.float32))


def test_masked_matmul_ones_mask_is_matmul():
    k1, k2 = keys(4, 2)
    x, w = rand(k1, 9, 17), rand(k2, 17, 5)
    np.testing.assert_allclose(
        masked_matmul(x, w, jnp.ones((17, 5))),
        matmul_ref(x, w), rtol=1e-5, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# VJP wrappers: gradients match the reference gradients
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_masked_matmul_vjp_grads(m, k, n, seed):
    k1, k2, k3 = keys(seed, 3)
    x, w = rand(k1, m, k), rand(k2, k, n)
    mask = (jax.random.uniform(k3, (k, n)) < 0.6).astype(jnp.float32)

    f = lambda x, w: (masked_matmul_vjp(x, w, mask) ** 2).sum()
    fr = lambda x, w: (masked_matmul_ref(x, w, mask) ** 2).sum()
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    gxr, gwr = jax.grad(fr, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gxr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw, gwr, rtol=1e-3, atol=1e-3)


def test_masked_grad_keeps_pruned_weights_dead():
    """The defining pruning invariant: masked entries get zero gradient."""
    k1, k2, k3 = keys(11, 3)
    x, w = rand(k1, 8, 12), rand(k2, 12, 6)
    mask = (jax.random.uniform(k3, (12, 6)) < 0.5).astype(jnp.float32)
    g = jax.grad(lambda w: masked_matmul_vjp(x, w, mask).sum())(w)
    np.testing.assert_array_equal(np.asarray(g * (1 - mask)), 0.0)


def test_matmul_vjp_matches_ref_grads():
    k1, k2 = keys(13, 2)
    x, w = rand(k1, 6, 10), rand(k2, 10, 4)
    g = jax.grad(lambda w: (matmul_vjp(x, w) ** 2).sum())(w)
    gr = jax.grad(lambda w: (matmul_ref(x, w) ** 2).sum())(w)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fake quant (ap_fixed semantics)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    rows=DIMS, cols=DIMS,
    total=st.integers(2, 18), integer=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(rows, cols, total, integer, seed):
    integer = min(integer, total)
    x = rand(jax.random.PRNGKey(seed), rows, cols) * 4.0
    q = jnp.array([float(total), float(integer)], jnp.float32)
    np.testing.assert_allclose(
        fake_quant(x, q), fake_quant_ref(x, q), rtol=1e-6, atol=1e-6
    )


def test_fake_quant_disabled_is_identity():
    x = rand(jax.random.PRNGKey(0), 5, 7)
    q = jnp.zeros((2,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(fake_quant(x, q)), np.asarray(x))


def test_fake_quant_saturates():
    x = jnp.array([[100.0, -100.0]], jnp.float32)
    q = jnp.array([8.0, 4.0], jnp.float32)  # ap_fixed<8,4>: [-8, 8 - 1/16]
    out = np.asarray(fake_quant(x, q))
    assert out[0, 0] == pytest.approx(8.0 - 1.0 / 16.0)
    assert out[0, 1] == pytest.approx(-8.0)


def test_fake_quant_values_on_grid():
    """Quantized values are integer multiples of 2^-frac."""
    x = rand(jax.random.PRNGKey(5), 16, 16)
    q = jnp.array([10.0, 3.0], jnp.float32)
    out = np.asarray(fake_quant_raw(x, q))
    lsb = 2.0 ** -(10 - 3)
    np.testing.assert_allclose(out / lsb, np.round(out / lsb), atol=1e-5)


def test_fake_quant_ste_gradient():
    x = jnp.array([[0.3, 100.0, -0.2, -50.0]], jnp.float32)
    q = jnp.array([8.0, 4.0], jnp.float32)
    g = jax.grad(lambda x: fake_quant(x, q).sum())(x)
    # in-range entries pass gradient straight through; saturated ones block it
    np.testing.assert_array_equal(np.asarray(g), [[1.0, 0.0, 1.0, 0.0]])


def test_fake_quant_monotone_error_in_bits():
    """More total bits can only reduce (or keep) quantization error."""
    x = rand(jax.random.PRNGKey(21), 32, 32)
    errs = []
    for total in (4, 6, 8, 12, 16):
        q = jnp.array([float(total), 4.0], jnp.float32)
        errs.append(float(jnp.abs(fake_quant_raw(x, q) - x).mean()))
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:]))
