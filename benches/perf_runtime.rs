//! §Perf — runtime microbenchmarks for the L3 hot path.
//!
//! Measures the pieces EXPERIMENTS.md §Perf tracks:
//!   * interpreter kernels: dense GFLOP-equivalent eval throughput,
//!     sparse-vs-dense speedup on a 90%-pruned jet model (with an
//!     assertion that the compressed path actually engaged), and
//!     naive-vs-fast probe throughput (the before/after of the kernel
//!     rewrite);
//!   * artifact compile time (cold) and cache hit (warm);
//!   * train-step dispatch latency + steps/s per model (the hot loop of
//!     every O-task probe);
//!   * eval throughput (samples/s);
//!   * DSE probe throughput, sequential vs parallel (1 / 2 / max
//!     workers), plus an end-to-end `quantize_search` jobs comparison
//!     that asserts the parallel trace is bit-identical;
//!   * hardware (synthesis) probe throughput through the same pool —
//!     reuse-factor candidate batches at 1 / 2 / max workers — plus a
//!     sequential-vs-parallel `reuse_search` trace-identity assertion;
//!   * budgeted search: exhaustive vs NSGA-II `evolve` over a hardware
//!     grid — probes spent and front hypervolume, with an assertion
//!     that evolution recovers the full front at fewer evaluations;
//!   * surrogate-guided search: `evolve` + the online ridge surrogate
//!     vs a prefilter-only `evolve` baseline at the same budget —
//!     asserts equal front hypervolume at >= 2x fewer training probes,
//!     and measures raw surrogate fit/predict throughput;
//!   * probe scheduler: the pipelined persistent-pool scheduler
//!     (`search.pipeline`, the default) vs the lock-step barrier on
//!     the same evolve+surrogate search at 1 / 4 / max workers —
//!     asserts bit-identical traces and that pipelining pays at
//!     jobs=4 (>= 1.5x in full runs, no regression in smoke);
//!   * observability overhead: the same cache-cold probe batch with
//!     span recording off vs on (asserting <= 2% traced wall-clock
//!     overhead in full runs) and the per-call cost of a disabled
//!     span (~one atomic load);
//!   * literal marshaling overhead (host→device→host round trip);
//!   * flow-engine overhead (no-op task graph traversal).
//!
//! Runs against real artifacts when present, else the in-memory
//! `jet_dnn` manifest (reference interpreter), so every machine can
//! reproduce the numbers.  Writes bench_out/perf_runtime.csv and a
//! machine-readable bench_out/perf_runtime.json.
//!
//! `--smoke` runs only the interpreter-kernel, surrogate-search,
//! scheduler and obs sections with tiny iteration counts / grids — a
//! CI-sized functional check (sparse path engages, surrogate halves
//! the probes, pipelined scheduling stays trace-identical, tracing
//! stays near-free), not a timing run.

use std::time::Instant;

use metaml::bench_support::{artifacts_dir, bench_models, bench_out, synthetic_jet_manifest};
use metaml::dse::{ProbePool, ProbeRequest};
use metaml::flow::{Engine, FlowGraph, ParamSpec, PipeTask, Session, TaskCtx, TaskOutcome, TaskRegistry, TaskRole};
use metaml::json::{self, Value};
use metaml::metamodel::MetaModel;
use metaml::model::state::Precision;
use metaml::model::ModelState;
use metaml::quant::{quantize_search, QuantConfig, QuantTrace};
use metaml::report::{CsvWriter, Table};
use metaml::runtime::Runtime;
use metaml::train::Trainer;

struct NopTask;
impl PipeTask for NopTask {
    fn name(&self) -> &str {
        "NOP"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (0, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, _ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        Ok(TaskOutcome::default())
    }
}

/// Keeps the CSV and the machine-readable JSON trajectory in sync.
struct Recorder {
    csv: CsvWriter,
    rows: Vec<Value>,
}

impl Recorder {
    fn new() -> Self {
        Recorder {
            csv: CsvWriter::new(&["metric", "model", "value", "unit"]),
            rows: Vec::new(),
        }
    }

    fn record(&mut self, metric: &str, model: &str, value: f64, unit: &str) {
        self.csv
            .row(&[metric.into(), model.into(), format!("{value}"), unit.into()]);
        let mut row = Value::object();
        row.set("metric", metric);
        row.set("model", model);
        row.set("value", value);
        row.set("unit", unit);
        self.rows.push(row);
    }

    fn save(&self) -> metaml::Result<()> {
        self.csv.save(bench_out().join("perf_runtime.csv"))?;
        let mut root = Value::object();
        root.set("bench", "perf_runtime");
        root.set("rows", Value::Array(self.rows.clone()));
        std::fs::create_dir_all(bench_out())?;
        std::fs::write(
            bench_out().join("perf_runtime.json"),
            json::to_string_pretty(&root),
        )?;
        Ok(())
    }
}

/// Probe-trace equality down to accuracy bit patterns (the parallel
/// determinism contract).
fn traces_identical(a: &QuantTrace, b: &QuantTrace) -> bool {
    a.precisions == b.precisions
        && a.bits_after == b.bits_after
        && a.probes.len() == b.probes.len()
        && a.probes.iter().zip(&b.probes).all(|(x, y)| {
            x.round == y.round
                && x.layer == y.layer
                && x.tried == y.tried
                && x.accuracy.to_bits() == y.accuracy.to_bits()
                && x.accepted == y.accepted
        })
}

/// Interpreter-kernel section: dense GFLOP-equivalent throughput,
/// sparse speedup at 90% pruning (asserting the compressed path
/// engaged), and naive-vs-fast probe throughput.  Self-contained — it
/// compares `KernelMode`s, so it builds its own reference sessions
/// instead of using the caller's.
fn interp_section(rec: &mut Recorder, table: &mut Table, smoke: bool) -> metaml::Result<()> {
    use metaml::runtime::kernels::sparse_matmul_count;
    use metaml::runtime::{HostTensor, KernelMode, RefBackend};
    use metaml::util::Prng;

    let iters = if smoke { 2 } else { 20 };
    let mode_session = |mode: KernelMode| {
        Session::with_backend(
            Runtime::from_backend(Box::new(RefBackend::with_mode(mode))),
            synthetic_jet_manifest(),
        )
    };

    let fast = mode_session(KernelMode::Fast);
    let variant = fast.manifest.variant("jet_dnn", 1.0)?.clone();
    let exec = fast.executable(&variant.tag)?;
    let data = fast.dataset("jet_dnn")?;
    let trainer = Trainer::new(&fast.runtime, &exec, &data);
    let state = ModelState::init(&variant, 77);

    // dense GFLOP-equivalent eval throughput (each weight element is
    // one multiply-add = 2 flops per sample)
    let mul_adds: usize = variant
        .param_shapes
        .iter()
        .filter(|(_, s)| s.len() == 2)
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    let t0 = Instant::now();
    let mut samples = 0usize;
    for _ in 0..iters {
        samples += trainer.evaluate(&state)?.n;
    }
    let secs = t0.elapsed().as_secs_f64();
    let gflops = (samples * mul_adds * 2) as f64 / 1e9 / secs;
    table.row_strs(&[
        "interp dense eval",
        "jet_dnn",
        &format!("{:.2} GFLOP/s equivalent", gflops),
    ]);
    rec.record("interp_dense_gflops", "jet_dnn", gflops, "gflop/s");

    // sparse speedup at 90% pruning: Fast (compressed path) vs
    // DenseOnly (same blocked kernels, sparse list disabled)
    let mut pruned = state.clone();
    let mut rng = Prng::new(4311);
    for m in &mut pruned.masks {
        if let HostTensor::F32 { data, .. } = m {
            for v in data.iter_mut() {
                *v = if rng.uniform() < 0.9 { 0.0 } else { 1.0 };
            }
        }
    }
    let engaged_before = sparse_matmul_count();
    let t0 = Instant::now();
    for _ in 0..iters {
        trainer.evaluate(&pruned)?;
    }
    let fast_secs = t0.elapsed().as_secs_f64();
    if sparse_matmul_count() == engaged_before {
        return Err(metaml::Error::other(
            "interp: sparse path never engaged on a 90%-pruned jet model",
        ));
    }

    let dense = mode_session(KernelMode::DenseOnly);
    let dexec = dense.executable(&variant.tag)?;
    let dtrainer = Trainer::new(&dense.runtime, &dexec, &data);
    let t0 = Instant::now();
    for _ in 0..iters {
        dtrainer.evaluate(&pruned)?;
    }
    let dense_secs = t0.elapsed().as_secs_f64();
    let sparse_speedup = dense_secs / fast_secs.max(1e-12);
    table.row_strs(&[
        "interp sparse eval (90% pruned)",
        "jet_dnn",
        &format!("{:.2}x vs dense path", sparse_speedup),
    ]);
    rec.record("interp_sparse_speedup_90", "jet_dnn", sparse_speedup, "x");

    // probe throughput, before vs after: the original naive kernels
    // against the fast path, over distinct cache-cold candidates
    let naive_sess = mode_session(KernelMode::Naive);
    let nexec = naive_sess.executable(&variant.tag)?;
    let ntrainer = Trainer::new(&naive_sess.runtime, &nexec, &data);

    let n_layers = state.n_weight_layers().max(1);
    let n_probes = if smoke { n_layers } else { 4 * n_layers };
    let requests: Vec<ProbeRequest> = (0..n_probes)
        .map(|i| {
            let mut cand = state.clone();
            cand.precisions[i % n_layers] =
                Precision::new(16 - 2 * (i / n_layers) as u32, 6);
            ProbeRequest::new(i, cand)
        })
        .collect();
    let run = |tr: &Trainer| -> metaml::Result<f64> {
        let pool = ProbePool::new(1);
        let t0 = Instant::now();
        pool.evaluate_batch(tr, &requests)?;
        Ok(requests.len() as f64 / t0.elapsed().as_secs_f64())
    };
    let naive_ps = run(&ntrainer)?;
    let fast_ps = run(&trainer)?;
    let probe_speedup = fast_ps / naive_ps.max(1e-12);
    table.row_strs(&[
        "interp probes/s (naive kernels)",
        "jet_dnn",
        &format!("{:.1} probes/s", naive_ps),
    ]);
    table.row_strs(&[
        "interp probes/s (fast kernels)",
        "jet_dnn",
        &format!("{:.1} probes/s ({:.2}x)", fast_ps, probe_speedup),
    ]);
    rec.record("interp_probes_s_naive", "jet_dnn", naive_ps, "probes/s");
    rec.record("interp_probes_s_fast", "jet_dnn", fast_ps, "probes/s");
    rec.record("interp_probe_speedup", "jet_dnn", probe_speedup, "x");
    Ok(())
}

/// Surrogate-guided search: `evolve` + the online ridge model vs a
/// prefilter-only `evolve` baseline at the same budget, on a
/// clock-period-only grid where the model is provably exact after its
/// two-point warmup (every non-latency objective is constant, latency
/// is linear in the period — the construction
/// rust/tests/surrogate_search.rs pins).  The baseline's budget covers
/// the whole grid, so its front doubles as the exhaustive reference
/// the hypervolume parity check compares against.  Also measures raw
/// fit/predict throughput of the ridge model on a synthetic space.
fn surrogate_section(rec: &mut Recorder, table: &mut Table, smoke: bool) -> metaml::Result<()> {
    use std::sync::Arc;

    use metaml::bench_support::synthetic_jet_mini_manifest;
    use metaml::config::FlowSpec;
    use metaml::dse::ProbeStats;
    use metaml::search::pareto::hypervolume;
    use metaml::search::{
        run_search, Candidate, SearchOutcome, SearchSpace, SearchSpec, Surrogate, SurrogateSpec,
    };

    let clocks = if smoke { "[5, 10, 15, 20]" } else { "[4, 5, 6, 8, 10, 12]" };
    let budget = if smoke { 4 } else { 6 };
    let spec = FlowSpec::parse(&format!(
        r#"{{
  "name": "bench_surrogate",
  "cfg": {{
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7
  }},
  "tasks": [
    {{"id": "gen", "type": "KERAS-MODEL-GEN"}},
    {{"id": "prune", "type": "PRUNING"}},
    {{"id": "hls", "type": "HLS4ML"}},
    {{"id": "quantize", "type": "QUANTIZATION"}},
    {{"id": "synth", "type": "VIVADO-HLS"}}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "synth"]],
  "explore": {{"cfg_grid": {{"hls.clock_period": {clocks}}}}},
  "search": {{"strategy": "evolve", "budget": {budget}, "seed": 9,
             "surrogate": {{"warmup": 2, "every": 8}}}}
}}"#
    ))?;
    // the reference-interpreter mini session keeps this section
    // deterministic and runnable everywhere (including --smoke on CI)
    let session = Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest());
    let registry = TaskRegistry::builtin();
    let jobs = metaml::dse::default_jobs();

    let baseline = SearchSpec {
        strategy: "evolve".into(),
        budget: Some(budget),
        seed: 9,
        prefilter: true,
        ..Default::default()
    };
    let t0 = Instant::now();
    let base = run_search(&session, &registry, &spec, &baseline, &[], jobs)?;
    let base_secs = t0.elapsed().as_secs_f64();
    let search = spec.search.clone().expect("bench spec declares a search section");
    let t0 = Instant::now();
    let sur = run_search(&session, &registry, &spec, &search, &[], jobs)?;
    let sur_secs = t0.elapsed().as_secs_f64();
    let report = sur.surrogate.clone().ok_or_else(|| {
        metaml::Error::other("surrogate search returned no surrogate accounting")
    })?;

    // one reference point over both runs so the hypervolumes compare
    let objs = |out: &SearchOutcome| -> metaml::Result<Vec<Vec<f64>>> {
        out.outcome.results.iter().map(|r| r.min_objectives()).collect()
    };
    let (base_objs, sur_objs) = (objs(&base)?, objs(&sur)?);
    let n_obj = base_objs[0].len();
    let reference: Vec<f64> = (0..n_obj)
        .map(|d| {
            base_objs
                .iter()
                .chain(&sur_objs)
                .map(|o| o[d])
                .fold(f64::NEG_INFINITY, f64::max)
                + 1.0
        })
        .collect();
    let base_hv = hypervolume(&base_objs, &reference);
    let sur_hv = hypervolume(&sur_objs, &reference);

    // baseline budget == grid size, so its front is the full-grid
    // front; the surrogate must match it with at most half the
    // training probes (the headline acceptance number)
    if (base_hv - sur_hv).abs() > 1e-9 * base_hv.abs().max(1.0) {
        return Err(metaml::Error::other(format!(
            "surrogate: front hypervolume {sur_hv} != full-grid {base_hv}"
        )));
    }
    if 2 * sur.probes.train_issued > base.probes.train_issued {
        return Err(metaml::Error::other(format!(
            "surrogate: {} train probes vs baseline {} — less than the 2x saving",
            sur.probes.train_issued, base.probes.train_issued
        )));
    }
    if report.probes_saved() == 0 {
        return Err(metaml::Error::other(
            "surrogate: no probes saved (every deferral was re-validated)",
        ));
    }

    for (name, out, secs, hv) in [
        ("baseline", &base, base_secs, base_hv),
        ("surrogate", &sur, sur_secs, sur_hv),
    ] {
        table.row_strs(&[
            &format!("search {name} evolve"),
            "jet_mini",
            &format!(
                "{:.3} s, {} evals, {} train probes, HV {:.3}",
                secs,
                out.evaluations(),
                out.probes.train_issued,
                hv
            ),
        ]);
        rec.record(&format!("surrogate_{name}_s"), "jet_mini", secs, "s");
        rec.record(
            &format!("surrogate_{name}_evals"),
            "jet_mini",
            out.evaluations() as f64,
            "flows",
        );
        rec.record(
            &format!("surrogate_{name}_train_probes"),
            "jet_mini",
            out.probes.train_issued as f64,
            "probes",
        );
        rec.record(&format!("surrogate_{name}_hypervolume"), "jet_mini", hv, "hv");
    }
    table.row_strs(&[
        "search surrogate deferrals",
        "jet_mini",
        &format!(
            "{} deferred, {} validated, {} probes saved",
            report.deferred,
            report.validated,
            report.probes_saved()
        ),
    ]);
    rec.record(
        "surrogate_probes_saved",
        "jet_mini",
        report.probes_saved() as f64,
        "probes",
    );

    // raw model throughput: refit-per-observation and predict over a
    // three-dimensional numeric space (the per-candidate costs a
    // search actually pays)
    let space = SearchSpace {
        orders: vec![None],
        grid: vec![
            ("a".to_string(), (0..8).map(|v| Value::Number(v as f64)).collect()),
            ("b".to_string(), (0..6).map(|v| Value::Number(2.0 * v as f64)).collect()),
            ("c".to_string(), (0..5).map(|v| Value::Number(3.0 * v as f64)).collect()),
        ],
        ranges: Vec::new(),
    };
    let sspec = SurrogateSpec { warmup: Some(1), ..Default::default() };
    let mut model = Surrogate::new(&space, &sspec, Arc::new(ProbeStats::default()));
    let cand = |i: usize| Candidate {
        order: 0,
        grid: vec![i % 8, (i / 2) % 6, (i / 3) % 5],
        range: Vec::new(),
    };
    let n_obs = if smoke { 64 } else { 256 };
    let t0 = Instant::now();
    for i in 0..n_obs {
        let a = (i % 8) as f64;
        let b = 2.0 * ((i / 2) % 6) as f64;
        let c = 3.0 * ((i / 3) % 5) as f64;
        model.observe_truth(
            &cand(i),
            &[1.0 + a - b + 0.5 * c, 0.1 * a * b + c, 3.0 - a, a + b + c],
        );
        model.fit_if_dirty();
    }
    let fit_secs = t0.elapsed().as_secs_f64();
    let fits_s = model.report().fits as f64 / fit_secs.max(1e-12);
    model.finish_warmup();
    let n_preds = if smoke { 10_000 } else { 100_000 };
    let t0 = Instant::now();
    let mut acc = 0.0;
    for i in 0..n_preds {
        acc += model.predict(&cand(i))[0];
    }
    let pred_secs = t0.elapsed().as_secs_f64();
    if !acc.is_finite() {
        return Err(metaml::Error::other("surrogate: non-finite prediction sum"));
    }
    let preds_s = n_preds as f64 / pred_secs.max(1e-12);
    table.row_strs(&["surrogate fit", "-", &format!("{:.0} refits/s", fits_s)]);
    table.row_strs(&["surrogate predict", "-", &format!("{:.0} predictions/s", preds_s)]);
    rec.record("surrogate_fits_s", "-", fits_s, "1/s");
    rec.record("surrogate_predictions_s", "-", preds_s, "1/s");
    Ok(())
}

/// Scheduler section: the pipelined persistent-pool scheduler (the
/// `search.pipeline` default) vs the lock-step barrier on the same
/// mispredictive evolve+surrogate search (population 2 <= jobs/2, so
/// the barrier leaves workers idle every round and validates deferrals
/// one at a time, while the pipelined scheduler keeps the pool full
/// with speculated next-round candidates and pending deferrals).
/// Asserts the determinism contract — both modes, every worker count,
/// one bit-identical trace — and that pipelining actually pays at
/// jobs=4.
fn scheduler_section(rec: &mut Recorder, table: &mut Table, smoke: bool) -> metaml::Result<()> {
    use metaml::bench_support::synthetic_jet_mini_manifest;
    use metaml::config::FlowSpec;
    use metaml::search::{SearchOutcome, SearchSpec};

    // the mispredictive space from rust/tests/surrogate_search.rs: a
    // convex resource curve vs a linear model defers plenty and
    // re-validates, which is exactly the serial tail pipelining hides
    let (grid, budget) = if smoke {
        (r#""hls.reuse_factor": [1, 4, 16], "hls.clock_period": [5, 10]"#, 6)
    } else {
        (r#""hls.reuse_factor": [1, 2, 4, 8, 16], "hls.clock_period": [5, 10]"#, 10)
    };
    let spec = FlowSpec::parse(&format!(
        r#"{{
  "name": "bench_scheduler",
  "cfg": {{
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7
  }},
  "tasks": [
    {{"id": "gen", "type": "KERAS-MODEL-GEN"}},
    {{"id": "prune", "type": "PRUNING"}},
    {{"id": "hls", "type": "HLS4ML"}},
    {{"id": "quantize", "type": "QUANTIZATION"}},
    {{"id": "synth", "type": "VIVADO-HLS"}}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "synth"]],
  "explore": {{"cfg_grid": {{{grid}}}}},
  "search": {{"strategy": "evolve", "budget": {budget}, "seed": 3, "population": 2,
             "surrogate": {{"warmup": 2, "margin": 0.05, "threshold": 0.05,
                           "every": 1}}}}
}}"#
    ))?;
    let session = Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest());
    let registry = TaskRegistry::builtin();
    let pipelined = spec.search.clone().expect("bench spec declares a search section");
    let barrier = SearchSpec { pipeline: false, ..pipelined.clone() };

    // everything the determinism contract covers; probe counters stay
    // out (computed/spec_* totals are wall-clock diagnostics)
    let trace = |out: &SearchOutcome| {
        let labels: Vec<&str> =
            out.outcome.results.iter().map(|r| r.label.as_str()).collect();
        format!(
            "{labels:?} front {:?} spent {} surrogate {:?}",
            out.outcome.front, out.spent, out.surrogate
        )
    };

    let max_jobs = metaml::dse::default_jobs();
    let mut worker_counts = vec![1usize, 4];
    if max_jobs > 4 {
        worker_counts.push(max_jobs);
    }
    let mut golden: Option<String> = None;
    for &jobs in &worker_counts {
        let t0 = Instant::now();
        let bar = metaml::search::run_search(&session, &registry, &spec, &barrier, &[], jobs)?;
        let bar_secs = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let pipe =
            metaml::search::run_search(&session, &registry, &spec, &pipelined, &[], jobs)?;
        let pipe_secs = t0.elapsed().as_secs_f64();

        let golden = golden.get_or_insert_with(|| trace(&bar));
        if trace(&bar) != *golden {
            return Err(metaml::Error::other(format!(
                "scheduler: barrier trace diverged at jobs={jobs}"
            )));
        }
        if trace(&pipe) != *golden {
            return Err(metaml::Error::other(format!(
                "scheduler: pipelined trace diverged from barrier at jobs={jobs}"
            )));
        }

        let speedup = bar_secs / pipe_secs.max(1e-12);
        let computed = (pipe.probes.train_computed + pipe.probes.hw_computed) as f64;
        table.row_strs(&[
            &format!("scheduler barrier (jobs={jobs})"),
            "jet_mini",
            &format!("{:.3} s", bar_secs),
        ]);
        table.row_strs(&[
            &format!("scheduler pipelined (jobs={jobs})"),
            "jet_mini",
            &format!(
                "{:.3} s ({:.2}x, {} speculated / {} committed, bit-identical)",
                pipe_secs, speedup, pipe.probes.spec_submitted, pipe.probes.spec_committed
            ),
        ]);
        rec.record(&format!("scheduler_barrier_jobs{jobs}_s"), "jet_mini", bar_secs, "s");
        rec.record(&format!("scheduler_pipelined_jobs{jobs}_s"), "jet_mini", pipe_secs, "s");
        rec.record(&format!("scheduler_speedup_jobs{jobs}"), "jet_mini", speedup, "x");
        rec.record(
            &format!("scheduler_pipelined_jobs{jobs}_probes_s"),
            "jet_mini",
            computed / pipe_secs.max(1e-12),
            "probes/s",
        );

        if jobs == 4 {
            if smoke {
                // functional gate, not a timing run: pipelining must
                // not regress (small absolute slack absorbs noise on
                // millisecond-scale smoke flows)
                if pipe_secs > bar_secs * 1.05 + 0.05 {
                    return Err(metaml::Error::other(format!(
                        "scheduler: pipelined {pipe_secs:.3}s slower than \
                         barrier {bar_secs:.3}s at jobs=4"
                    )));
                }
            } else if speedup < 1.5 {
                return Err(metaml::Error::other(format!(
                    "scheduler: {speedup:.2}x at jobs=4 — below the 1.5x acceptance bar"
                )));
            }
        }
    }
    Ok(())
}

/// Observability overhead: the same cache-cold probe batch with span
/// recording off vs on (best of N, asserting the traced run stays
/// within the acceptance overhead), plus the raw cost of a disabled
/// span call (a single relaxed atomic load — the "near-zero when off"
/// half of the obs contract).
fn obs_section(rec: &mut Recorder, table: &mut Table, smoke: bool) -> metaml::Result<()> {
    use metaml::obs::trace;
    use metaml::runtime::{KernelMode, RefBackend};

    let session = Session::with_backend(
        Runtime::from_backend(Box::new(RefBackend::with_mode(KernelMode::Fast))),
        synthetic_jet_manifest(),
    );
    let variant = session.manifest.variant("jet_dnn", 1.0)?.clone();
    let exec = session.executable(&variant.tag)?;
    let data = session.dataset("jet_dnn")?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    let state = ModelState::init(&variant, 77);

    let n_layers = state.n_weight_layers().max(1);
    let n_probes = if smoke { n_layers } else { 4 * n_layers };
    let requests: Vec<ProbeRequest> = (0..n_probes)
        .map(|i| {
            let mut cand = state.clone();
            cand.precisions[i % n_layers] =
                Precision::new(16 - 2 * (i / n_layers) as u32, 6);
            ProbeRequest::new(i, cand)
        })
        .collect();

    // fresh pool per run: every probe is cache-cold, so both sides
    // measure real evaluation work, not memo lookups
    let run = |enabled: bool| -> metaml::Result<f64> {
        if enabled {
            trace::enable();
            trace::reset();
        } else {
            trace::disable();
        }
        let pool = ProbePool::new(1);
        let t0 = Instant::now();
        pool.evaluate_batch(&trainer, &requests)?;
        let secs = t0.elapsed().as_secs_f64();
        if enabled && trace::drain().is_empty() {
            return Err(metaml::Error::other(
                "obs: enabled tracing recorded no spans over a probe batch",
            ));
        }
        trace::disable();
        Ok(secs)
    };
    let reps = if smoke { 1 } else { 3 };
    let best = |enabled: bool| -> metaml::Result<f64> {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            best = best.min(run(enabled)?);
        }
        Ok(best)
    };
    let off_secs = best(false)?;
    let on_secs = best(true)?;
    let off_ps = requests.len() as f64 / off_secs.max(1e-12);
    let on_ps = requests.len() as f64 / on_secs.max(1e-12);
    let overhead_pct = 100.0 * (on_secs / off_secs.max(1e-12) - 1.0);

    // the disabled fast path: one span open/drop per iteration
    let iters = if smoke { 100_000usize } else { 1_000_000 };
    trace::disable();
    let t0 = Instant::now();
    for _ in 0..iters {
        let _s = trace::span("bench", "obs.disabled");
    }
    let span_ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;

    table.row_strs(&[
        "obs probes/s (tracing off)",
        "jet_dnn",
        &format!("{:.1} probes/s", off_ps),
    ]);
    table.row_strs(&[
        "obs probes/s (tracing on)",
        "jet_dnn",
        &format!("{:.1} probes/s ({:+.2}% wall)", on_ps, overhead_pct),
    ]);
    table.row_strs(&[
        "obs disabled span",
        "-",
        &format!("{:.1} ns/call", span_ns),
    ]);
    rec.record("obs_probes_s_disabled", "jet_dnn", off_ps, "probes/s");
    rec.record("obs_probes_s_enabled", "jet_dnn", on_ps, "probes/s");
    rec.record("obs_traced_overhead_pct", "jet_dnn", overhead_pct, "%");
    rec.record("obs_disabled_span_ns", "-", span_ns, "ns");

    if span_ns > 1000.0 {
        return Err(metaml::Error::other(format!(
            "obs: disabled span costs {span_ns:.0} ns/call — not near-zero"
        )));
    }
    if smoke {
        // functional gate on millisecond-scale smoke batches: tracing
        // must not halve throughput (absolute slack absorbs noise)
        if on_secs > off_secs * 2.0 + 0.05 {
            return Err(metaml::Error::other(format!(
                "obs: traced batch {on_secs:.3}s vs untraced {off_secs:.3}s — \
                 tracing halved probe throughput in smoke"
            )));
        }
    } else if overhead_pct > 2.0 {
        return Err(metaml::Error::other(format!(
            "obs: {overhead_pct:.2}% traced overhead — above the 2% acceptance bar"
        )));
    }
    Ok(())
}

fn main() -> metaml::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = Recorder::new();
    let mut table = Table::new(&["metric", "model", "value"]);

    // interpreter kernels + surrogate search + probe scheduler +
    // observability overhead (the sections --smoke runs)
    interp_section(&mut rec, &mut table, smoke)?;
    surrogate_section(&mut rec, &mut table, smoke)?;
    scheduler_section(&mut rec, &mut table, smoke)?;
    obs_section(&mut rec, &mut table, smoke)?;
    if smoke {
        println!(
            "== §Perf: interpreter kernels + surrogate search + scheduler + obs (smoke) =="
        );
        println!("{}", table.render());
        rec.save()?;
        return Ok(());
    }

    // real artifacts when available; otherwise the in-memory jet_dnn
    // manifest keeps the bench runnable on any machine
    let session = match Session::open(&artifacts_dir()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("note: no artifacts ({e}); using the in-memory jet_dnn manifest");
            Session::with_backend(Runtime::cpu()?, synthetic_jet_manifest())
        }
    };

    // compile: cold vs warm
    {
        let t0 = Instant::now();
        let _ = session.executable("jet_dnn_s1000")?;
        let cold = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let _ = session.executable("jet_dnn_s1000")?;
        let warm = t1.elapsed().as_secs_f64();
        table.row_strs(&["compile cold", "jet_dnn", &format!("{:.3} s", cold)]);
        table.row_strs(&["compile warm (cache)", "jet_dnn", &format!("{:.6} s", warm)]);
        rec.record("compile_cold", "jet_dnn", cold, "s");
        rec.record("compile_warm", "jet_dnn", warm, "s");
    }

    for model in bench_models(&["jet_dnn", "vgg7_mini", "resnet9_mini"]) {
        if session.manifest.variants.iter().all(|v| v.model != model) {
            eprintln!("note: model {model} not in manifest; skipping");
            continue;
        }
        let variant = session.manifest.variant(&model, 1.0)?.clone();
        let exec = session.executable(&variant.tag)?;
        let data = session.dataset(&model)?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        let mut state = ModelState::init(&variant, 77);

        // train-step latency (hot loop): fit one epoch and normalize
        let mut cfg = metaml::train::TrainConfig::for_model(&model);
        cfg.epochs = 1;
        let t0 = Instant::now();
        trainer.fit(&mut state, &cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let spe = data.spec.n_train / variant.train_batch;
        let ms_per_step = 1000.0 * secs / spe as f64;
        let samples_s = (spe * variant.train_batch) as f64 / secs;
        table.row_strs(&[
            "train step",
            &model,
            &format!("{:.1} ms/step ({:.0} samples/s)", ms_per_step, samples_s),
        ]);
        rec.record("train_step_ms", &model, ms_per_step, "ms");
        rec.record("train_samples_s", &model, samples_s, "1/s");

        // eval throughput
        let t0 = Instant::now();
        let eval = trainer.evaluate(&state)?;
        let secs = t0.elapsed().as_secs_f64();
        let eps = eval.n as f64 / secs;
        table.row_strs(&["eval", &model, &format!("{:.0} samples/s", eps)]);
        rec.record("eval_samples_s", &model, eps, "1/s");
    }

    // DSE probe throughput: one quant-round-shaped candidate batch,
    // evaluated at 1 / 2 / max workers (fresh pool each, so every run
    // is cache-cold), plus the end-to-end quantize_search comparison
    {
        let variant = session.manifest.variant("jet_dnn", 1.0)?.clone();
        let exec = session.executable(&variant.tag)?;
        let data = session.dataset("jet_dnn")?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        let mut state = ModelState::init(&variant, 4242);
        let mut cfg = metaml::train::TrainConfig::for_model("jet_dnn");
        cfg.epochs = 2;
        trainer.fit(&mut state, &cfg)?;

        // 24 distinct (layer, precision) candidates — what a few rounds
        // of the quantization search submit
        let widths = [18u32, 16, 14, 12, 10, 8];
        let n_layers = state.n_weight_layers().max(1);
        let requests: Vec<ProbeRequest> = (0..n_layers * widths.len())
            .map(|i| {
                let mut cand = state.clone();
                cand.precisions[i % n_layers] =
                    Precision::new(widths[i / n_layers], 4);
                ProbeRequest::new(i, cand)
            })
            .collect();

        let max_jobs = metaml::dse::default_jobs();
        let mut worker_counts = vec![1usize, 2];
        if max_jobs > 2 {
            worker_counts.push(max_jobs);
        }
        let mut baseline: Option<Vec<f64>> = None;
        for &jobs in &worker_counts {
            let pool = ProbePool::new(jobs);
            let t0 = Instant::now();
            let results = pool.evaluate_batch(&trainer, &requests)?;
            let secs = t0.elapsed().as_secs_f64();
            let probes_s = requests.len() as f64 / secs;
            let accs: Vec<f64> = results.iter().map(|r| r.eval.accuracy).collect();
            match &baseline {
                None => baseline = Some(accs),
                Some(b) => {
                    if b.iter().zip(&accs).any(|(x, y)| x.to_bits() != y.to_bits()) {
                        return Err(metaml::Error::other(format!(
                            "dse_probe: jobs={jobs} results diverged from sequential"
                        )));
                    }
                }
            }
            table.row_strs(&[
                &format!("dse probe batch (jobs={jobs})"),
                "jet_dnn",
                &format!("{:.1} probes/s", probes_s),
            ]);
            rec.record(&format!("dse_probe_jobs{jobs}"), "jet_dnn", probes_s, "probes/s");
        }
        rec.record("dse_jobs_max", "-", max_jobs as f64, "workers");

        // end-to-end mixed-precision search, sequential vs parallel
        let qcfg = QuantConfig {
            start: Precision::new(12, 6),
            min_bits: 8,
            ..Default::default()
        };
        let mut seq_state = state.clone();
        let t0 = Instant::now();
        let seq_trace =
            quantize_search(&trainer, &mut seq_state, &qcfg, &ProbePool::new(1))?;
        let seq_secs = t0.elapsed().as_secs_f64();

        let mut par_state = state.clone();
        let t0 = Instant::now();
        let par_trace =
            quantize_search(&trainer, &mut par_state, &qcfg, &ProbePool::new(max_jobs))?;
        let par_secs = t0.elapsed().as_secs_f64();

        if !traces_identical(&seq_trace, &par_trace) {
            return Err(metaml::Error::other(
                "dse_probe: parallel quantize_search trace diverged from sequential",
            ));
        }
        let speedup = seq_secs / par_secs.max(1e-12);
        table.row_strs(&[
            "quantize_search jobs=1",
            "jet_dnn",
            &format!("{:.3} s ({} probes)", seq_secs, seq_trace.probes.len()),
        ]);
        table.row_strs(&[
            &format!("quantize_search jobs={max_jobs}"),
            "jet_dnn",
            &format!("{:.3} s ({:.2}x, bit-identical)", par_secs, speedup),
        ]);
        rec.record("dse_quant_search_jobs1_s", "jet_dnn", seq_secs, "s");
        rec.record(
            &format!("dse_quant_search_jobs{max_jobs}_s"),
            "jet_dnn",
            par_secs,
            "s",
        );
        rec.record("dse_quant_search_speedup", "jet_dnn", speedup, "x");
    }

    // hardware (synthesis) probe throughput: the FPGA-stage probe kind
    // through the same pool — per-layer reuse-factor candidates at
    // 1 / 2 / max workers (fresh pool each, cache-cold), plus the
    // end-to-end reuse_search sequential-vs-parallel comparison
    {
        use metaml::dse::HwProbeRequest;
        use metaml::hls::{HlsModel, HlsTransform, SetLayerReuse};
        use metaml::synth::{reuse_search, FpgaDevice, ReuseConfig, ReuseTrace};

        let variant = session.manifest.variant("jet_dnn", 1.0)?.clone();
        // ~60% density, what a pruned jet model hands the FPGA stage
        let nnz: Vec<usize> = variant
            .mask_shapes
            .iter()
            .map(|(_, shape)| shape.iter().product::<usize>() * 6 / 10)
            .collect();
        let base = HlsModel::from_nnz(
            &variant,
            &nnz,
            Precision::new(12, 6),
            "vu9p",
            5.0,
        )?;
        let device = FpgaDevice::by_name("vu9p").unwrap();

        // per-layer reuse candidates (every compute layer x RF grid)
        let layer_names: Vec<String> =
            base.compute_layers().map(|l| l.name.clone()).collect();
        let mut requests: Vec<HwProbeRequest> = Vec::new();
        for (li, name) in layer_names.iter().enumerate() {
            for (ri, rf) in [2usize, 4, 8, 16].iter().enumerate() {
                let mut m = base.clone();
                SetLayerReuse { layer: name.clone(), reuse_factor: *rf }
                    .apply(&mut m)?;
                requests.push(HwProbeRequest::new(li * 4 + ri, m));
            }
        }

        let max_jobs = metaml::dse::default_jobs();
        let mut worker_counts = vec![1usize, 2];
        if max_jobs > 2 {
            worker_counts.push(max_jobs);
        }
        let mut baseline: Option<Vec<(usize, usize, usize)>> = None;
        for &jobs in &worker_counts {
            let pool = ProbePool::new(jobs);
            let t0 = Instant::now();
            let results = pool.estimate_batch(device, 200.0, &requests)?;
            let secs = t0.elapsed().as_secs_f64();
            let probes_s = requests.len() as f64 / secs;
            let sums: Vec<(usize, usize, usize)> = results
                .iter()
                .map(|r| (r.eval.dsp, r.eval.lut, r.eval.latency_cycles))
                .collect();
            match &baseline {
                None => baseline = Some(sums),
                Some(b) => {
                    if *b != sums {
                        return Err(metaml::Error::other(format!(
                            "hw_probe: jobs={jobs} results diverged from sequential"
                        )));
                    }
                }
            }
            table.row_strs(&[
                &format!("hw probe batch (jobs={jobs})"),
                "jet_dnn",
                &format!("{:.0} probes/s", probes_s),
            ]);
            rec.record(&format!("hw_probe_jobs{jobs}"), "jet_dnn", probes_s, "probes/s");
        }

        // end-to-end reuse search, sequential vs parallel: the
        // REUSE_SEARCH determinism contract (trace bit-identity)
        let rcfg = ReuseConfig { latency_budget_ns: Some(200.0) };
        let t0 = Instant::now();
        let (seq_model, seq_trace) =
            reuse_search(&base, device, 200.0, &rcfg, &ProbePool::new(1))?;
        let seq_secs = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let (par_model, par_trace) =
            reuse_search(&base, device, 200.0, &rcfg, &ProbePool::new(max_jobs))?;
        let par_secs = t0.elapsed().as_secs_f64();

        let reuse_traces_identical = |a: &ReuseTrace, b: &ReuseTrace| {
            a.reuse == b.reuse
                && a.probes == b.probes
                && a.final_eval == b.final_eval
        };
        let rfs = |m: &HlsModel| -> Vec<usize> {
            m.layers.iter().map(|l| l.reuse_factor).collect()
        };
        if !reuse_traces_identical(&seq_trace, &par_trace)
            || rfs(&seq_model) != rfs(&par_model)
        {
            return Err(metaml::Error::other(
                "hw_probe: parallel reuse_search trace diverged from sequential",
            ));
        }
        table.row_strs(&[
            "reuse_search jobs=1",
            "jet_dnn",
            &format!("{:.4} s ({} probes)", seq_secs, seq_trace.probes.len()),
        ]);
        table.row_strs(&[
            &format!("reuse_search jobs={max_jobs}"),
            "jet_dnn",
            &format!("{:.4} s (bit-identical)", par_secs),
        ]);
        rec.record("hw_reuse_search_jobs1_s", "jet_dnn", seq_secs, "s");
        rec.record(
            &format!("hw_reuse_search_jobs{max_jobs}_s"),
            "jet_dnn",
            par_secs,
            "s",
        );
    }

    // budgeted search: exhaustive sweep vs NSGA-II evolution over a
    // pure hardware grid (reuse factor × clock period on the trained
    // jet model) — probes spent and front hypervolume go into the perf
    // trajectory; the evolved front must match the full-grid front at
    // half the evaluations (the clock dimension makes the dominated
    // half provable, see rust/tests/search_strategies.rs)
    {
        use metaml::config::FlowSpec;
        use metaml::search::pareto::hypervolume;
        use metaml::search::{run_search, SearchOutcome, SearchSpec};

        let spec = FlowSpec::parse(
            r#"{
  "name": "bench_search",
  "cfg": {"model": "jet_dnn", "gen.train_epochs": 1},
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "hls"], ["hls", "synth"]],
  "explore": {"cfg_grid": {
    "hls.clock_period": [5, 10],
    "hls.reuse_factor": [1, 2, 4, 8]
  }},
  "search": {"strategy": "evolve", "budget": 4, "seed": 7, "prefilter": true}
}"#,
        )?;
        let registry = TaskRegistry::builtin();
        let jobs = metaml::dse::default_jobs();

        let t0 = Instant::now();
        let full = run_search(&session, &registry, &spec, &SearchSpec::default(), &[], jobs)?;
        let full_secs = t0.elapsed().as_secs_f64();
        let search = spec.search.clone().expect("bench spec declares a search section");
        let t0 = Instant::now();
        let evolved = run_search(&session, &registry, &spec, &search, &[], jobs)?;
        let evolved_secs = t0.elapsed().as_secs_f64();

        // one reference point over both runs so the hypervolumes compare
        let objs = |out: &SearchOutcome| -> metaml::Result<Vec<Vec<f64>>> {
            out.outcome.results.iter().map(|r| r.min_objectives()).collect()
        };
        let (full_objs, evolved_objs) = (objs(&full)?, objs(&evolved)?);
        let n_obj = full_objs[0].len();
        let reference: Vec<f64> = (0..n_obj)
            .map(|d| {
                full_objs
                    .iter()
                    .chain(&evolved_objs)
                    .map(|o| o[d])
                    .fold(f64::NEG_INFINITY, f64::max)
                    + 1.0
            })
            .collect();
        let full_hv = hypervolume(&full_objs, &reference);
        let evolved_hv = hypervolume(&evolved_objs, &reference);

        if evolved.evaluations() >= full.evaluations() {
            return Err(metaml::Error::other(format!(
                "search: evolve spent {} evaluations, exhaustive {}",
                evolved.evaluations(),
                full.evaluations()
            )));
        }
        if (full_hv - evolved_hv).abs() > 1e-9 * full_hv.abs().max(1.0) {
            return Err(metaml::Error::other(format!(
                "search: evolved front hypervolume {evolved_hv} != full-grid {full_hv}"
            )));
        }

        for (name, out, secs, hv) in [
            ("exhaustive", &full, full_secs, full_hv),
            ("evolve", &evolved, evolved_secs, evolved_hv),
        ] {
            table.row_strs(&[
                &format!("search {name}"),
                "jet_dnn",
                &format!(
                    "{:.3} s, {} evals, {} train + {} hw probes, HV {:.3}",
                    secs,
                    out.evaluations(),
                    out.probes.train_issued,
                    out.probes.hw_issued,
                    hv
                ),
            ]);
            rec.record(&format!("search_{name}_s"), "jet_dnn", secs, "s");
            rec.record(
                &format!("search_{name}_evals"),
                "jet_dnn",
                out.evaluations() as f64,
                "flows",
            );
            rec.record(
                &format!("search_{name}_train_probes"),
                "jet_dnn",
                out.probes.train_issued as f64,
                "probes",
            );
            rec.record(
                &format!("search_{name}_hw_probes"),
                "jet_dnn",
                out.probes.hw_issued as f64,
                "probes",
            );
            rec.record(&format!("search_{name}_hypervolume"), "jet_dnn", hv, "hv");
        }
    }

    // literal marshaling: tensor -> literal -> tensor round trip
    // (PJRT-backend-only concern; the reference interpreter never
    // marshals literals)
    #[cfg(feature = "xla")]
    {
        let t = metaml::runtime::HostTensor::ones(&[64, 1024]);
        let n = 200;
        let t0 = Instant::now();
        for _ in 0..n {
            let lit = t.to_literal()?;
            let _ = metaml::runtime::HostTensor::from_literal(&lit)?;
        }
        let us = 1e6 * t0.elapsed().as_secs_f64() / n as f64;
        table.row_strs(&["literal round-trip 256KB", "-", &format!("{:.1} µs", us)]);
        rec.record("literal_roundtrip_us", "-", us, "us");
    }

    // flow-engine overhead: 64 independent no-op tasks
    {
        let mut registry = TaskRegistry::empty();
        registry.register("NOP", || Box::new(NopTask));
        let mut g = FlowGraph::new("nop-chain");
        for i in 0..64 {
            g.add_task(format!("n{i}"), "NOP");
        }
        let engine = Engine::new(&session, &registry);
        let mut meta = MetaModel::new();
        let t0 = Instant::now();
        engine.run(&g, &mut meta)?;
        let us_per_task = 1e6 * t0.elapsed().as_secs_f64() / 64.0;
        table.row_strs(&["engine overhead", "-", &format!("{:.1} µs/task", us_per_task)]);
        rec.record("engine_overhead_us_task", "-", us_per_task, "us");
    }

    println!("== §Perf: runtime microbenchmarks ==");
    println!("{}", table.render());
    let stats = session.runtime.stats();
    println!(
        "runtime totals: {} compiles {:.2}s, {} executions {:.2}s",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    rec.save()?;
    Ok(())
}
