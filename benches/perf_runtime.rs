//! §Perf — runtime microbenchmarks for the L3 hot path.
//!
//! Measures the pieces EXPERIMENTS.md §Perf tracks:
//!   * artifact compile time (cold) and cache hit (warm);
//!   * train-step dispatch latency + steps/s per model (the hot loop of
//!     every O-task probe);
//!   * eval throughput (samples/s);
//!   * literal marshaling overhead (host→device→host round trip);
//!   * flow-engine overhead (no-op task graph traversal).
//!
//! Writes bench_out/perf_runtime.csv.

use std::time::Instant;

use metaml::bench_support::{artifacts_dir, bench_models, bench_out};
use metaml::flow::{Engine, FlowGraph, ParamSpec, PipeTask, Session, TaskCtx, TaskOutcome, TaskRegistry, TaskRole};
use metaml::metamodel::MetaModel;
use metaml::model::ModelState;
use metaml::report::{CsvWriter, Table};
use metaml::train::Trainer;

struct NopTask;
impl PipeTask for NopTask {
    fn name(&self) -> &str {
        "NOP"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (0, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, _ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        Ok(TaskOutcome::default())
    }
}

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    let mut csv = CsvWriter::new(&["metric", "model", "value", "unit"]);
    let mut table = Table::new(&["metric", "model", "value"]);

    // compile: cold vs warm
    {
        let t0 = Instant::now();
        let _ = session.executable("jet_dnn_s1000")?;
        let cold = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let _ = session.executable("jet_dnn_s1000")?;
        let warm = t1.elapsed().as_secs_f64();
        table.row_strs(&["compile cold", "jet_dnn", &format!("{:.3} s", cold)]);
        table.row_strs(&["compile warm (cache)", "jet_dnn", &format!("{:.6} s", warm)]);
        csv.row(&["compile_cold".into(), "jet_dnn".into(), format!("{cold}"), "s".into()]);
        csv.row(&["compile_warm".into(), "jet_dnn".into(), format!("{warm}"), "s".into()]);
    }

    for model in bench_models(&["jet_dnn", "vgg7_mini", "resnet9_mini"]) {
        let variant = session.manifest.variant(&model, 1.0)?.clone();
        let exec = session.executable(&variant.tag)?;
        let data = session.dataset(&model)?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        let mut state = ModelState::init(&variant, 77);

        // train-step latency (hot loop): time N steps through fit()
        let steps = if model == "jet_dnn" { 128 } else { 16 };
        let mut cfg = metaml::train::TrainConfig::for_model(&model);
        cfg.epochs = 1;
        // fit runs one epoch = n_train/batch steps; time it and normalize
        let t0 = Instant::now();
        trainer.fit(&mut state, &cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let spe = data.spec.n_train / variant.train_batch;
        let ms_per_step = 1000.0 * secs / spe as f64;
        let samples_s = (spe * variant.train_batch) as f64 / secs;
        table.row_strs(&[
            "train step",
            &model,
            &format!("{:.1} ms/step ({:.0} samples/s)", ms_per_step, samples_s),
        ]);
        csv.row(&["train_step_ms".into(), model.clone(), format!("{ms_per_step}"), "ms".into()]);
        csv.row(&["train_samples_s".into(), model.clone(), format!("{samples_s}"), "1/s".into()]);
        let _ = steps;

        // eval throughput
        let t0 = Instant::now();
        let eval = trainer.evaluate(&state)?;
        let secs = t0.elapsed().as_secs_f64();
        let eps = eval.n as f64 / secs;
        table.row_strs(&["eval", &model, &format!("{:.0} samples/s", eps)]);
        csv.row(&["eval_samples_s".into(), model.clone(), format!("{eps}"), "1/s".into()]);
    }

    // literal marshaling: tensor -> literal -> tensor round trip
    // (PJRT-backend-only concern; the reference interpreter never
    // marshals literals)
    #[cfg(feature = "xla")]
    {
        let t = metaml::runtime::HostTensor::ones(&[64, 1024]);
        let n = 200;
        let t0 = Instant::now();
        for _ in 0..n {
            let lit = t.to_literal()?;
            let _ = metaml::runtime::HostTensor::from_literal(&lit)?;
        }
        let us = 1e6 * t0.elapsed().as_secs_f64() / n as f64;
        table.row_strs(&["literal round-trip 256KB", "-", &format!("{:.1} µs", us)]);
        csv.row(&["literal_roundtrip_us".into(), "-".into(), format!("{us}"), "us".into()]);
    }

    // flow-engine overhead: 64-node no-op chain
    {
        let mut registry = TaskRegistry::empty();
        registry.register("NOP", || Box::new(NopTask));
        let mut g = FlowGraph::new("nop-chain");
        let mut prev = None;
        for i in 0..64 {
            let n = g.add_task(format!("n{i}"), "NOP");
            if let Some(p) = prev {
                let _ = p; // chain kept acyclic but disconnected: NOP is 0-input
            }
            prev = Some(n);
        }
        let engine = Engine::new(&session, &registry);
        let mut meta = MetaModel::new();
        let t0 = Instant::now();
        engine.run(&g, &mut meta)?;
        let us_per_task = 1e6 * t0.elapsed().as_secs_f64() / 64.0;
        table.row_strs(&["engine overhead", "-", &format!("{:.1} µs/task", us_per_task)]);
        csv.row(&["engine_overhead_us_task".into(), "-".into(), format!("{us_per_task}"), "us".into()]);
    }

    println!("== §Perf: runtime microbenchmarks ==");
    println!("{}", table.render());
    let stats = session.runtime.stats();
    println!(
        "runtime totals: {} compiles {:.2}s, {} executions {:.2}s",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    csv.save(bench_out().join("perf_runtime.csv"))?;
    Ok(())
}
