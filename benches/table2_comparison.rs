//! Table II — performance comparison of Jet-DNN FPGA designs on VU9P.
//!
//! Rows, matching the paper:
//!   * HLS4ML Jet-DNN [23]  — the original hls4ml design (≈70%-pruned,
//!     18-bit, RF=1) — our baseline flow + fixed 70% pruning;
//!   * LogicNets JSC-M / JSC-L [31] — LUT-only co-designed baselines;
//!   * QKeras Q6, AutoQKeras QE / QB [6] — heterogeneous-precision QAT;
//!   * This work (same arch as [23], quantization only, α_q=1%);
//!   * This work S→P→Q, α_q = 1% and 4%.
//!
//! All "this work" rows and all baselines are *measured* through our
//! training + synthesis stack; nothing is transcribed from the paper.
//! Writes bench_out/table2.csv.

use metaml::baselines::logicnets::{logicnets_design, JSC_L, JSC_M};
use metaml::baselines::qkeras::{qkeras_design, QKerasVariant};
use metaml::bench_support::{artifacts_dir, bench_out, fast_mode};
use metaml::config::builtin_flow;
use metaml::dse::ProbePool;
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::hls::HlsModel;
use metaml::metamodel::{Abstraction, MetaModel};
use metaml::model::state::Precision;
use metaml::prune::global_magnitude_masks;
use metaml::quant::{quantize_search, QuantConfig};
use metaml::report::{CsvWriter, Table};
use metaml::synth::{estimate, FpgaDevice};
use metaml::train::Trainer;

struct Row {
    name: String,
    alpha_q: String,
    acc: f64,
    lat_ns: f64,
    lat_cycles: usize,
    dsp: usize,
    dsp_pct: f64,
    lut: usize,
    lut_pct: f64,
    power: f64,
}

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    let registry = TaskRegistry::builtin();
    let vu9p = FpgaDevice::by_name("vu9p").unwrap();
    let mut rows: Vec<Row> = Vec::new();

    // --- HLS4ML Jet-DNN [23]: 70%-pruned 18-bit original ----------------
    println!("[1/8] hls4ml original (70% pruned, 18-bit)...");
    {
        let (mut state, exec, data) =
            metaml::bench_support::trained_base(&session, "jet_dnn", 1.0, 2301)?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        state.masks = global_magnitude_masks(&state, 0.70)?;
        state.apply_masks()?;
        let mut ft = metaml::train::TrainConfig::for_model("jet_dnn");
        ft.epochs = if fast_mode() { 1 } else { 3 };
        trainer.fit(&mut state, &ft)?;
        let eval = trainer.evaluate(&state)?;
        let hls = HlsModel::from_dnn(
            &exec.variant,
            &state,
            Precision::new(18, 8),
            metaml::hls::IoType::Parallel,
            "vu9p",
            5.0,
        )?;
        let r = estimate(&hls, vu9p, 200.0)?;
        rows.push(Row {
            name: "HLS4ML Jet-DNN [23]".into(),
            alpha_q: "-".into(),
            acc: eval.accuracy,
            lat_ns: r.latency_ns,
            lat_cycles: r.latency_cycles,
            dsp: r.dsp,
            dsp_pct: r.dsp_pct(),
            lut: r.lut,
            lut_pct: r.lut_pct(),
            power: r.dynamic_power_w,
        });
    }

    // --- LogicNets JSC-M / JSC-L ----------------------------------------
    for (i, cfg) in [&JSC_M, &JSC_L].into_iter().enumerate() {
        println!("[{}/8] {}...", i + 2, cfg.name);
        let d = logicnets_design(&session, cfg)?;
        rows.push(Row {
            name: d.name,
            alpha_q: "-".into(),
            acc: d.accuracy,
            lat_ns: d.latency_ns,
            lat_cycles: d.latency_cycles,
            dsp: d.dsp,
            dsp_pct: 0.0,
            lut: d.lut,
            lut_pct: 100.0 * d.lut as f64 / vu9p.lut as f64,
            power: d.power_w,
        });
    }

    // --- QKeras Q6 / AutoQKeras QE, QB ----------------------------------
    for (i, v) in [QKerasVariant::Q6, QKerasVariant::QE, QKerasVariant::QB]
        .into_iter()
        .enumerate()
    {
        println!("[{}/8] {}...", i + 4, v.name());
        let d = qkeras_design(&session, v, vu9p)?;
        rows.push(Row {
            name: d.name,
            alpha_q: "-".into(),
            acc: d.accuracy,
            lat_ns: d.report.latency_ns,
            lat_cycles: d.report.latency_cycles,
            dsp: d.report.dsp,
            dsp_pct: d.report.dsp_pct(),
            lut: d.report.lut,
            lut_pct: d.report.lut_pct(),
            power: d.report.dynamic_power_w,
        });
    }

    // --- This work: same arch as [23], quantization only (α_q=1%) -------
    println!("[7/8] this work (same arch, Q only, α_q=1%)...");
    {
        let (mut state, exec, data) =
            metaml::bench_support::trained_base(&session, "jet_dnn", 1.0, 2307)?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        let qcfg = QuantConfig { tolerate_acc_loss: 0.01, ..Default::default() };
        let pool = ProbePool::with_default_jobs();
        let trace = quantize_search(&trainer, &mut state, &qcfg, &pool)?;
        let hls = HlsModel::from_dnn(
            &exec.variant,
            &state,
            Precision::new(18, 8),
            metaml::hls::IoType::Parallel,
            "vu9p",
            5.0,
        )?;
        let r = estimate(&hls, vu9p, 200.0)?;
        rows.push(Row {
            name: "This work (same as [23])".into(),
            alpha_q: "1%".into(),
            acc: trace.final_accuracy,
            lat_ns: r.latency_ns,
            lat_cycles: r.latency_cycles,
            dsp: r.dsp,
            dsp_pct: r.dsp_pct(),
            lut: r.lut,
            lut_pct: r.lut_pct(),
            power: r.dynamic_power_w,
        });
    }

    // --- This work: S→P→Q at α_q = 1% and 4% ----------------------------
    for (i, alpha_q) in [0.01, 0.04].into_iter().enumerate() {
        println!("[8/8] this work S->P->Q (α_q={}%)...", 100.0 * alpha_q);
        let spec = builtin_flow("s_p_q")?;
        let mut meta = MetaModel::new();
        meta.cfg.set("model", "jet_dnn");
        meta.cfg.set("hls4ml.FPGA_part_number", "vu9p");
        meta.cfg.set("quantize.tolerate_acc_loss", alpha_q);
        meta.cfg.set("gen.seed", 2308.0 + i as f64);
        Engine::new(&session, &registry).run(&spec.graph, &mut meta)?;
        let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
        let m = |k: &str| rtl.metric(k).unwrap_or(0.0);
        rows.push(Row {
            name: "This work S→P→Q".into(),
            alpha_q: format!("{}%", 100.0 * alpha_q),
            acc: m("accuracy"),
            lat_ns: m("latency_ns"),
            lat_cycles: m("latency_cycles") as usize,
            dsp: m("dsp") as usize,
            dsp_pct: m("dsp_pct"),
            lut: m("lut") as usize,
            lut_pct: m("lut_pct"),
            power: m("power_w"),
        });
    }

    // --- render ----------------------------------------------------------
    println!("\n== Table II: Jet-DNN FPGA design comparison (VU9P) ==");
    let mut table = Table::new(&[
        "Model", "α_q", "Acc (%)", "Lat (ns)", "Lat (cyc)", "DSP (%)", "LUT (%)", "Power (W)",
    ]);
    let mut csv = CsvWriter::new(&[
        "model", "alpha_q", "accuracy", "lat_ns", "lat_cycles", "dsp", "dsp_pct",
        "lut", "lut_pct", "power_w",
    ]);
    for r in &rows {
        table.row(&[
            r.name.clone(),
            r.alpha_q.clone(),
            format!("{:.1}", 100.0 * r.acc),
            format!("{:.0}", r.lat_ns),
            r.lat_cycles.to_string(),
            format!("{} ({:.1})", r.dsp, r.dsp_pct),
            format!("{} ({:.1})", r.lut, r.lut_pct),
            format!("{:.3}", r.power),
        ]);
        csv.row(&[
            r.name.clone(),
            r.alpha_q.clone(),
            format!("{}", r.acc),
            format!("{}", r.lat_ns),
            format!("{}", r.lat_cycles),
            format!("{}", r.dsp),
            format!("{}", r.dsp_pct),
            format!("{}", r.lut),
            format!("{}", r.lut_pct),
            format!("{}", r.power),
        ]);
    }
    println!("{}", table.render());

    // the paper's comparison claims, checked on our measurements
    let ours_1 = rows.iter().find(|r| r.name.contains("S→P→Q") && r.alpha_q == "1%").unwrap();
    let ours_4 = rows.iter().find(|r| r.name.contains("S→P→Q") && r.alpha_q == "4%").unwrap();
    let q6 = rows.iter().find(|r| r.name.contains("Q6")).unwrap();
    let qe = rows.iter().find(|r| r.name.contains("QE")).unwrap();
    let logic_m = rows.iter().find(|r| r.name.contains("JSC-M")).unwrap();
    println!("paper-shape checks:");
    println!(
        "  ours(1%) vs Q6:  acc {:+.1}pp, DSP {}x fewer, LUT {:.1}x fewer",
        100.0 * (ours_1.acc - q6.acc),
        if ours_1.dsp > 0 { format!("{:.1}", q6.dsp as f64 / ours_1.dsp as f64) } else { "∞".into() },
        q6.lut as f64 / ours_1.lut.max(1) as f64,
    );
    println!(
        "  ours(4%) vs QE:  acc {:+.1}pp, DSP {} vs {} (paper: 3x fewer than QE)",
        100.0 * (ours_4.acc - qe.acc),
        ours_4.dsp,
        qe.dsp,
    );
    println!(
        "  ours(1%) vs LogicNets JSC-M: acc {:+.1}pp at comparable LUT budget",
        100.0 * (ours_1.acc - logic_m.acc),
    );
    csv.save(bench_out().join("table2.csv"))?;
    Ok(())
}
