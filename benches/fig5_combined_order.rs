//! Fig 5 — combining O-tasks, and why order matters.
//!
//! Reproduces: "(a) Jet-DNN accuracy and pruning rates with scaling then
//! pruning" — the optimal pruning rate drops vs pruning alone because the
//! preceding scaling removed redundancy (paper: 84.4% vs 93.8%); and
//! "(b) Jet-DNN accuracy and layer size with pruning then scaling" — one
//! scaling step after pruning costs visible accuracy (paper: 0.7% drop).
//!
//! Writes bench_out/fig5a.csv and bench_out/fig5b.csv.

use metaml::bench_support::{artifacts_dir, bench_out, fast_mode};
use metaml::dse::ProbePool;
use metaml::flow::Session;
use metaml::prune::{autoprune, AutopruneConfig};
use metaml::report::{CsvWriter, Table};
use metaml::scale::{scale_search, ScaleConfig};
use metaml::train::Trainer;

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    let prune_cfg = AutopruneConfig {
        train_epochs: if fast_mode() { 1 } else { 2 },
        ..Default::default()
    };

    // ---- reference: pruning alone -------------------------------------
    let (mut solo, exec, data) =
        metaml::bench_support::trained_base(&session, "jet_dnn", 1.0, 1501)?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    let pool = ProbePool::with_default_jobs();
    let solo_trace = autoprune(&trainer, &mut solo, &prune_cfg, &pool)?;

    // ---- Fig 5(a): scaling THEN pruning --------------------------------
    println!("== Fig 5(a): scaling -> pruning on Jet-DNN ==");
    let (base, exec, data) =
        metaml::bench_support::trained_base(&session, "jet_dnn", 1.0, 1502)?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    let base_acc = trainer.evaluate(&base)?.accuracy;
    let scfg = ScaleConfig {
        train_epochs: if fast_mode() { 2 } else { 4 },
        ..Default::default()
    };
    let (strace, mut scaled_state, new_scale) =
        scale_search(&session, "jet_dnn", 1.0, base_acc, &scfg, &pool)?;
    let sexec = session.executable(
        &session.manifest.variant("jet_dnn", new_scale)?.tag,
    )?;
    let strainer = Trainer::new(&session.runtime, &sexec, &data);
    let strace2 = autoprune(&strainer, &mut scaled_state, &prune_cfg, &pool)?;

    let mut table = Table::new(&["step", "rate %", "accuracy %", "verdict"]);
    let mut csv = CsvWriter::new(&["step", "rate", "accuracy", "accepted"]);
    for p in &strace2.probes {
        table.row(&[
            format!("s{}", p.step),
            format!("{:.2}", 100.0 * p.rate),
            format!("{:.2}", 100.0 * p.accuracy),
            if p.accepted { "accepted".into() } else { "rejected".into() },
        ]);
        csv.row_f64(&[p.step as f64, p.rate, p.accuracy, p.accepted as u8 as f64]);
    }
    println!("{}", table.render());
    println!(
        "scaling chose scale {:.3} ({} trials); optimal pruning rate after\n\
         scaling: {:.1}%  vs  {:.1}% with pruning alone\n\
         paper shape: combined rate (84.4%) < solo rate (93.8%) because the\n\
         scaling step already removed redundancy.\n",
        new_scale,
        strace.probes.len(),
        100.0 * strace2.best_rate,
        100.0 * solo_trace.best_rate,
    );
    csv.save(bench_out().join("fig5a.csv"))?;

    // ---- Fig 5(b): pruning THEN scaling --------------------------------
    println!("== Fig 5(b): pruning -> scaling on Jet-DNN ==");
    // `solo` already holds the pruned model at the solo-optimal rate;
    // scaled candidates inherit the pruned structure
    let pruned_acc = solo_trace.best_accuracy;
    let bcfg = ScaleConfig {
        inherit_pruning_rate: solo_trace.best_rate,
        ..scfg.clone()
    };
    let (btrace, _, bscale) =
        scale_search(&session, "jet_dnn", 1.0, pruned_acc, &bcfg, &pool)?;
    let mut table_b = Table::new(&["trial", "scale", "params", "accuracy %", "Δacc %", "verdict"]);
    let mut csv_b = CsvWriter::new(&["trial", "scale", "params", "accuracy", "accepted"]);
    for p in &btrace.probes {
        table_b.row(&[
            p.trial.to_string(),
            format!("{:.3}", p.scale),
            p.params.to_string(),
            format!("{:.2}", 100.0 * p.accuracy),
            format!("{:+.2}", 100.0 * (p.accuracy - pruned_acc)),
            if p.accepted { "accepted".into() } else { "rejected (loss > α_s)".into() },
        ]);
        csv_b.row_f64(&[
            p.trial as f64,
            p.scale,
            p.params as f64,
            p.accuracy,
            p.accepted as u8 as f64,
        ]);
    }
    println!("{}", table_b.render());
    let first_drop = btrace
        .probes
        .first()
        .map(|p| 100.0 * (pruned_acc - p.accuracy))
        .unwrap_or(0.0);
    println!(
        "pruning first reached {:.1}% rate (acc {:.2}%); scaling after it\n\
         settled at scale {:.3}; first scaling step changed accuracy by {:.2}%\n\
         paper shape: scaling a pruned model costs accuracy (0.7% in the\n\
         paper) because redundancy is already gone.\n",
        100.0 * solo_trace.best_rate,
        100.0 * pruned_acc,
        bscale,
        first_drop,
    );
    csv_b.save(bench_out().join("fig5b.csv"))?;
    Ok(())
}
