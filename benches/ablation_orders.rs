//! Ablation — O-task composition order (paper §V-B "Discussion": "the
//! order in which these optimization techniques are applied plays a
//! crucial role, as different orders produce varying final results").
//!
//! Runs every built-in composition (single-task and combined, both
//! orders) on Jet-DNN and compares the final RTL design points.
//! Writes bench_out/ablation_orders.csv.

use metaml::bench_support::{artifacts_dir, bench_out};
use metaml::config::{builtin_flow, builtin_flow_names};
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::metamodel::{Abstraction, MetaModel};
use metaml::report::{CsvWriter, Table};

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    let registry = TaskRegistry::builtin();

    let mut table = Table::new(&[
        "flow", "acc %", "scale", "prune %", "DSP", "LUT", "cycles", "ns", "W", "wall s",
    ]);
    let mut csv = CsvWriter::new(&[
        "flow", "accuracy", "scale", "pruning_rate", "dsp", "lut",
        "latency_cycles", "latency_ns", "power_w", "wall_s",
    ]);

    for flow_name in builtin_flow_names() {
        println!("running flow {flow_name}...");
        let spec = builtin_flow(flow_name)?;
        let mut meta = MetaModel::new();
        meta.cfg.set("model", "jet_dnn");
        meta.cfg.set("hls4ml.FPGA_part_number", "vu9p");
        meta.cfg.set("quantize.tolerate_acc_loss", 0.01);
        let t0 = std::time::Instant::now();
        Engine::new(&session, &registry).run(&spec.graph, &mut meta)?;
        let wall = t0.elapsed().as_secs_f64();
        let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
        let m = |k: &str| rtl.metric(k).unwrap_or(0.0);
        table.row(&[
            flow_name.to_string(),
            format!("{:.2}", 100.0 * m("accuracy")),
            format!("{:.3}", if m("scale") == 0.0 { 1.0 } else { m("scale") }),
            format!("{:.1}", 100.0 * m("pruning_rate")),
            format!("{:.0}", m("dsp")),
            format!("{:.0}", m("lut")),
            format!("{:.0}", m("latency_cycles")),
            format!("{:.0}", m("latency_ns")),
            format!("{:.3}", m("power_w")),
            format!("{:.1}", wall),
        ]);
        csv.row_f64(&[
            flow_name.len() as f64, // placeholder id column replaced below
            m("accuracy"),
            m("scale"),
            m("pruning_rate"),
            m("dsp"),
            m("lut"),
            m("latency_cycles"),
            m("latency_ns"),
            m("power_w"),
            wall,
        ]);
    }

    println!("\n== Ablation: O-task composition order (Jet-DNN, VU9P) ==");
    println!("{}", table.render());
    println!(
        "paper shape: combined strategies beat single O-tasks; s_p_q and\n\
         p_s_q land on different design points (order matters)."
    );
    csv.save(bench_out().join("ablation_orders.csv"))?;
    Ok(())
}
