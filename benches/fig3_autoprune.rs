//! Fig 3 — the auto-pruning binary-search traces.
//!
//! Reproduces: "(a) Jet-DNN and (b) ResNet9, with binary search direction
//! shown.  The blue arrow indicates an accuracy loss > user threshold;
//! red denotes the optimal pruning rate."  α_p = β_p = 2%.
//!
//! Prints the per-step (rate, accuracy, direction) series and writes
//! bench_out/fig3_<model>.csv.

use metaml::bench_support::{artifacts_dir, bench_models, bench_out, fast_mode};
use metaml::dse::ProbePool;
use metaml::flow::Session;
use metaml::prune::{autoprune, AutopruneConfig};
use metaml::report::{CsvWriter, Table};
use metaml::train::Trainer;

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    // paper pairs: Jet-DNN on Zynq 7020, ResNet9 on U250
    for model in bench_models(&["jet_dnn", "resnet9_mini"]) {
        run(&session, &model)?;
    }
    Ok(())
}

fn run(session: &Session, model: &str) -> metaml::Result<()> {
    println!("== Fig 3: auto-pruning binary search on {model} (α_p=β_p=2%) ==");
    let (mut state, exec, data) =
        metaml::bench_support::trained_base(session, model, 1.0, 1301)?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);

    let cfg = AutopruneConfig {
        train_epochs: if fast_mode() { 1 } else { 2 },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let pool = ProbePool::with_default_jobs();
    let trace = autoprune(&trainer, &mut state, &cfg, &pool)?;

    let mut table = Table::new(&["step", "rate %", "accuracy %", "Δacc %", "direction", "verdict"]);
    let mut csv = CsvWriter::new(&["step", "rate", "accuracy", "accepted", "direction"]);
    for p in &trace.probes {
        table.row(&[
            format!("s{}", p.step),
            format!("{:.2}", 100.0 * p.rate),
            format!("{:.2}", 100.0 * p.accuracy),
            format!("{:+.2}", 100.0 * (p.accuracy - trace.base_accuracy)),
            if p.direction > 0 { "increase ↑".into() } else { "decrease ↓ (loss > α_p)".into() },
            if p.accepted { "accepted".into() } else { "rejected".into() },
        ]);
        csv.row_f64(&[
            p.step as f64,
            p.rate,
            p.accuracy,
            p.accepted as u8 as f64,
            p.direction as f64,
        ]);
    }
    println!("{}", table.render());
    println!(
        "optimal pruning rate: {:.2}% (accuracy {:.2}%, base {:.2}%), {} steps, {:.1}s\n\
         paper shape: 1 + log2(1/β_p) ≈ 7 steps; optimum marked red in Fig 3\n",
        100.0 * trace.best_rate,
        100.0 * trace.best_accuracy,
        100.0 * trace.base_accuracy,
        trace.probes.len(),
        t0.elapsed().as_secs_f64(),
    );
    csv.save(bench_out().join(format!("fig3_{model}.csv")))?;
    Ok(())
}
