//! Table I — the implemented pipe tasks: type, role, multiplicity,
//! parameters.  Regenerated straight from the live task registry so the
//! table can never drift from the code.

use metaml::flow::TaskRegistry;

fn main() {
    println!("== Table I: implemented pipe tasks ==\n");
    let registry = TaskRegistry::builtin();
    print!("{}", registry.table());
    println!(
        "\nparameters match the paper's Table I (α_p/β_p for PRUNING, α_s +\n\
         scale_auto/max_trials for SCALING, α_q for QUANTIZATION, precision/\n\
         IOType/part/clock for HLS4ML, project_dir for VIVADO-HLS).\n\
         KERAS-MODEL-GEN is 0-to-1 (source task); all others are 1-to-1."
    );
}
