//! Fig 4 — pruning rate/accuracy and resource utilization of design
//! candidates.
//!
//! Reproduces: "(a) Pruning rates and accuracy of Jet-DNN. (b) Resource
//! utilization of Jet-DNN design candidates with pruning on Xilinx Zynq
//! 7020. (c,d) same for ResNet9 on Xilinx U250."
//!
//! Every binary-search candidate is pushed through HLS4ML + VIVADO-HLS
//! (18-bit default precision) and its DSP/LUT/FF/BRAM utilization is
//! reported against the device.  Writes bench_out/fig4_<model>.csv.

use metaml::bench_support::{artifacts_dir, bench_models, bench_out, fast_mode};
use metaml::dse::ProbePool;
use metaml::flow::Session;
use metaml::hls::{HlsModel, HlsTransform, SetReuseFactor};
use metaml::model::state::Precision;
use metaml::prune::{autoprune, AutopruneConfig};
use metaml::report::{CsvWriter, Table};
use metaml::synth::{estimate, FpgaDevice};
use metaml::train::Trainer;

fn main() -> metaml::Result<()> {
    let session = Session::open(&artifacts_dir())?;
    for model in bench_models(&["jet_dnn", "resnet9_mini"]) {
        let device = match model.as_str() {
            "jet_dnn" => "zynq7020", // paper Fig 4(b)
            _ => "u250",             // paper Fig 4(d)
        };
        run(&session, &model, device)?;
    }
    Ok(())
}

fn run(session: &Session, model: &str, device_name: &str) -> metaml::Result<()> {
    let device = FpgaDevice::by_name(device_name).unwrap();
    println!("== Fig 4: pruning candidates of {model} on {device_name} ==");
    let (mut state, exec, data) =
        metaml::bench_support::trained_base(session, model, 1.0, 1402)?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    let variant = exec.variant.clone();

    let cfg = AutopruneConfig {
        train_epochs: if fast_mode() { 1 } else { 2 },
        ..Default::default()
    };
    let pool = ProbePool::with_default_jobs();
    let trace = autoprune(&trainer, &mut state, &cfg, &pool)?;

    // Reuse factor: the paper's edge deployments (Zynq @100 MHz) cannot
    // fully unroll; pick the smallest power-of-2 RF that fits the
    // *unpruned* design's DSPs — the same knob an hls4ml user would turn.
    let unpruned_nnz: Vec<usize> = variant
        .mask_shapes
        .iter()
        .map(|(_, s)| s.iter().product())
        .collect();
    let full = estimate(
        &HlsModel::from_nnz(
            &variant,
            &unpruned_nnz,
            Precision::new(18, 8),
            device_name,
            1000.0 / device.default_clock_mhz,
        )?,
        device,
        device.default_clock_mhz,
    )?;
    let mut rf = 1usize;
    while full.dsp / rf > device.dsp && rf < 4096 {
        rf *= 2;
    }
    println!("reuse factor {rf} (unpruned design needs {} DSP of {})", full.dsp, device.dsp);

    let mut table = Table::new(&[
        "candidate", "rate %", "acc %", "DSP %", "LUT %", "FF %", "BRAM %", "fits",
    ]);
    let mut csv = CsvWriter::new(&[
        "step", "rate", "accuracy", "dsp", "lut", "ff", "bram",
        "dsp_pct", "lut_pct", "ff_pct", "bram_pct",
    ]);
    for p in &trace.probes {
        let mut hls = HlsModel::from_nnz(
            &variant,
            &p.layer_nnz,
            Precision::new(18, 8),
            device_name,
            1000.0 / device.default_clock_mhz,
        )?;
        SetReuseFactor(rf).apply(&mut hls)?;
        let r = estimate(&hls, device, device.default_clock_mhz)?;
        table.row(&[
            format!("s{}", p.step),
            format!("{:.2}", 100.0 * p.rate),
            format!("{:.2}", 100.0 * p.accuracy),
            format!("{:.1}", r.dsp_pct()),
            format!("{:.1}", r.lut_pct()),
            format!("{:.1}", r.ff_pct()),
            format!("{:.1}", r.bram_pct()),
            if r.fits() { "yes".into() } else { "NO".into() },
        ]);
        csv.row_f64(&[
            p.step as f64,
            p.rate,
            p.accuracy,
            r.dsp as f64,
            r.lut as f64,
            r.ff as f64,
            r.bram_18k as f64,
            r.dsp_pct(),
            r.lut_pct(),
            r.ff_pct(),
            r.bram_pct(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper shape: DSP/LUT fall monotonically with pruning rate; the\n\
         selected candidate is the highest rate within α_p (here {:.1}%).\n",
        100.0 * trace.best_rate
    );
    csv.save(bench_out().join(format!("fig4_{model}.csv")))?;
    Ok(())
}
