//! Kernel-layer parity contract.
//!
//! The fast interpreter path (`runtime/kernels.rs`: blocked matmul,
//! sparse-aware masked matmul, workspace reuse, intra-probe row-panel
//! parallelism) promises *bit-identical* results to the original naive
//! implementation (`KernelMode::Naive`) — not approximately equal.
//! These tests pin that promise at every level:
//!
//! * raw kernels: blocked vs naive matmul on random data;
//! * masked matmul: sparse vs dense at 0% / 50% / 90% / 100% sparsity
//!   (random masks, fixed seed);
//! * full model steps: `Fast` and `DenseOnly` train/eval vs `Naive`
//!   over multiple SGD steps, quantization on, masks pruned;
//! * NaN / -0.0 propagation through the sparse and blocked paths;
//! * intra-probe parallelism: any thread count produces the same bits;
//! * batched eval (`eval_batches`) vs the per-batch eval loop.

use metaml::bench_support::mlp_chain_variant;
use metaml::model::state::Precision;
use metaml::model::ModelState;
use metaml::runtime::kernels::{
    self, naive, set_par_min_flops, sparse_matmul_count, with_intra_threads, MaskedWeight, Quant,
    Workspace, PAR_MIN_FLOPS_DEFAULT, SPARSE_DENSITY_THRESHOLD,
};
use metaml::runtime::{
    HostTensor, KernelMode, Manifest, ModelExecutable, ModelVariant, RefBackend, Runtime,
};
use metaml::util::Prng;

/// The jet-tagging MLP (16 → 64 → 32 → 32 → 5) the benches use.
fn jet_variant() -> ModelVariant {
    mlp_chain_variant("jet_dnn", 1.0, &[16, 64, 32, 32, 5])
}

fn exec_with_mode(variant: &ModelVariant, mode: KernelMode) -> ModelExecutable {
    let manifest = Manifest::from_variants(vec![variant.clone()]);
    let runtime = Runtime::from_backend(Box::new(RefBackend::with_mode(mode)));
    ModelExecutable::load(&runtime, &manifest, &variant.tag).unwrap()
}

fn batch(variant: &ModelVariant, rows: usize, seed: u64) -> (HostTensor, HostTensor) {
    let mut rng = Prng::new(seed);
    let d = variant.input_shape[0];
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..rows)
        .map(|_| rng.below(variant.n_classes) as i32)
        .collect();
    (
        HostTensor::F32 { shape: vec![rows, d], data: x },
        HostTensor::I32 { shape: vec![rows], data: y },
    )
}

/// Randomly zero a `sparsity` fraction of every mask (fixed seed).
fn prune_masks(state: &mut ModelState, sparsity: f64, seed: u64) {
    let mut rng = Prng::new(seed);
    for m in &mut state.masks {
        if let HostTensor::F32 { data, .. } = m {
            for v in data.iter_mut() {
                *v = if rng.uniform() < sparsity { 0.0 } else { 1.0 };
            }
        }
    }
}

fn assert_params_bit_identical(a: &[HostTensor], b: &[HostTensor], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: param count");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        let (da, db) = (pa.as_f32().unwrap(), pb.as_f32().unwrap());
        assert_eq!(da.len(), db.len(), "{ctx}: param {i} length");
        for (j, (va, vb)) in da.iter().zip(db).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{ctx}: param {i} element {j}: {va} vs {vb}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// raw kernels
// ---------------------------------------------------------------------------

#[test]
fn blocked_matmul_matches_naive_on_random_data() {
    let mut rng = Prng::new(41);
    for &(m, k, n) in &[(5, 7, 3), (64, 16, 64), (65, 33, 17), (256, 16, 64)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let want = naive::mm(&a, &b, m, k, n);
        let mut got = vec![f32::NAN; m * n];
        let mut pack = Vec::new();
        kernels::matmul(&mut got, &a, &b, m, k, n, &mut pack);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits(), "matmul {m}x{k}x{n}");
        }
    }
}

#[test]
fn sparse_masked_matmul_matches_dense_at_all_sparsities() {
    let (m, k, n) = (96, 48, 32);
    let q = Quant::new(10.0, 5.0);
    for &sparsity in &[0.0f64, 0.5, 0.9, 1.0] {
        let mut rng = Prng::new(1000 + (sparsity * 100.0) as u64);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask: Vec<f32> = (0..k * n)
            .map(|_| if rng.uniform() < sparsity { 0.0 } else { 1.0 })
            .collect();

        let mut ws = Workspace::new();
        // threshold 0.0: the sparse list is never built (dense path)
        let dense = MaskedWeight::build(&mut ws, &w, &mask, &q, k, n, 0.0);
        let mut want = vec![f32::NAN; m * n];
        kernels::matmul_masked(&mut want, &a, &dense, m, k, n, &mut ws.pack);

        let sparse = MaskedWeight::build(&mut ws, &w, &mask, &q, k, n, SPARSE_DENSITY_THRESHOLD);
        if sparsity >= 0.9 {
            assert!(
                sparse.sparse.is_some(),
                "sparsity {sparsity}: compressed index list should engage"
            );
        }
        let mut got = vec![f32::NAN; m * n];
        kernels::matmul_masked(&mut got, &a, &sparse, m, k, n, &mut ws.pack);
        for (idx, (wv, gv)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                wv.to_bits(),
                gv.to_bits(),
                "sparsity {sparsity}, element {idx}: {wv} vs {gv}"
            );
        }

        // the backward masked kernel agrees with the naive oracle too
        let wq = naive::quantized_masked(&w, &mask, 10.0, 5.0);
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let want_bt = naive::mm_bt(&g, &wq, m, n, k);
        let mut got_bt = vec![f32::NAN; m * k];
        kernels::matmul_bt_masked(&mut got_bt, &g, &sparse, m, n, k);
        for (wv, gv) in want_bt.iter().zip(&got_bt) {
            assert_eq!(wv.to_bits(), gv.to_bits(), "bt sparsity {sparsity}");
        }
    }
}

#[test]
fn nan_weights_and_negative_zero_propagate_through_sparse_path() {
    let (m, k, n) = (8, 6, 4);
    let q = Quant::new(0.0, 0.0); // quantization off: values flow raw
    let mut rng = Prng::new(77);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    a[3] = -0.0;
    let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    w[5] = f32::NAN;
    w[9] = -0.0;
    // heavily pruned mask keeping exactly four weights alive, including
    // the NaN and -0.0 ones (density 4/24 < SPARSE_DENSITY_THRESHOLD)
    let mut mask = vec![0.0f32; k * n];
    for idx in [0usize, 5, 9, 13] {
        mask[idx] = 1.0;
    }

    let mut ws = Workspace::new();
    let dense = MaskedWeight::build(&mut ws, &w, &mask, &q, k, n, 0.0);
    let sparse = MaskedWeight::build(&mut ws, &w, &mask, &q, k, n, SPARSE_DENSITY_THRESHOLD);
    assert!(sparse.sparse.is_some(), "pruned mask should engage the sparse path");

    let mut want = vec![0.0f32; m * n];
    kernels::matmul_masked(&mut want, &a, &dense, m, k, n, &mut ws.pack);
    let mut got = vec![0.0f32; m * n];
    kernels::matmul_masked(&mut got, &a, &sparse, m, k, n, &mut ws.pack);
    assert!(want.iter().any(|v| v.is_nan()), "NaN weight must reach the output");
    for (wv, gv) in want.iter().zip(&got) {
        assert_eq!(wv.to_bits(), gv.to_bits(), "{wv} vs {gv}");
    }

    // non-finite *activations* force the dense fallback — still identical
    let mut a_nan = a.clone();
    a_nan[0] = f32::NAN;
    let mut want2 = vec![0.0f32; m * n];
    kernels::matmul_masked(&mut want2, &a_nan, &dense, m, k, n, &mut ws.pack);
    let mut got2 = vec![0.0f32; m * n];
    kernels::matmul_masked(&mut got2, &a_nan, &sparse, m, k, n, &mut ws.pack);
    for (wv, gv) in want2.iter().zip(&got2) {
        assert_eq!(wv.to_bits(), gv.to_bits());
    }
}

#[test]
fn degenerate_conv_shapes_error_cleanly() {
    let mut cols = [0.0f32; 0];
    // zero batch
    assert!(kernels::im2col(&mut cols, &[], [0, 4, 4, 1], 3).is_err());
    // kernel larger than the spatial extent
    let x = [0.0f32; 2 * 2];
    let mut cols = [0.0f32; 4 * 9];
    assert!(kernels::im2col(&mut cols, &x, [1, 2, 2, 1], 5).is_err());
    let mut dx = [0.0f32; 4];
    assert!(kernels::col2im(&mut dx, &cols, [1, 2, 2, 1], 5).is_err());
}

// ---------------------------------------------------------------------------
// full model steps
// ---------------------------------------------------------------------------

#[test]
fn fast_train_and_eval_match_naive_bitwise() {
    let variant = jet_variant();
    let mut base = ModelState::init(&variant, 7);
    for p in base.precisions.iter_mut() {
        *p = Precision::new(10, 5);
    }
    prune_masks(&mut base, 0.5, 11);
    let (x, y) = batch(&variant, 64, 3);

    let naive_exec = exec_with_mode(&variant, KernelMode::Naive);
    for mode in [KernelMode::Fast, KernelMode::DenseOnly] {
        let exec = exec_with_mode(&variant, mode);
        let mut s_naive = base.clone();
        let mut s_fast = base.clone();
        for step in 0..3 {
            let (pa, la, aa) = naive_exec
                .train_step(&s_naive.train_args(x.clone(), y.clone(), 0.1))
                .unwrap();
            let (pb, lb, ab) = exec
                .train_step(&s_fast.train_args(x.clone(), y.clone(), 0.1))
                .unwrap();
            assert_eq!(la.to_bits(), lb.to_bits(), "{mode:?} step {step} loss");
            assert_eq!(aa.to_bits(), ab.to_bits(), "{mode:?} step {step} acc");
            assert_params_bit_identical(&pa, &pb, &format!("{mode:?} step {step}"));
            s_naive.params = pa;
            s_fast.params = pb;
        }
        let (la, aa) = naive_exec
            .eval_step(&s_naive.eval_args(x.clone(), y.clone()))
            .unwrap();
        let (lb, ab) = exec
            .eval_step(&s_fast.eval_args(x.clone(), y.clone()))
            .unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "{mode:?} eval loss");
        assert_eq!(aa.to_bits(), ab.to_bits(), "{mode:?} eval acc");
    }
}

#[test]
fn sparse_model_steps_match_dense_at_all_sparsities() {
    let variant = jet_variant();
    for &sparsity in &[0.0f64, 0.5, 0.9, 1.0] {
        let mut base = ModelState::init(&variant, 13);
        for p in base.precisions.iter_mut() {
            *p = Precision::new(12, 6);
        }
        prune_masks(&mut base, sparsity, 17 + (sparsity * 10.0) as u64);
        let (x, y) = batch(&variant, 64, 5);

        let fast = exec_with_mode(&variant, KernelMode::Fast);
        let dense = exec_with_mode(&variant, KernelMode::DenseOnly);

        let before = sparse_matmul_count();
        let (pf, lf, af) = fast
            .train_step(&base.train_args(x.clone(), y.clone(), 0.05))
            .unwrap();
        let (pd, ld, ad) = dense
            .train_step(&base.train_args(x.clone(), y.clone(), 0.05))
            .unwrap();
        assert_eq!(lf.to_bits(), ld.to_bits(), "sparsity {sparsity} loss");
        assert_eq!(af.to_bits(), ad.to_bits(), "sparsity {sparsity} acc");
        assert_params_bit_identical(&pf, &pd, &format!("sparsity {sparsity}"));
        if sparsity >= 0.9 {
            assert!(
                sparse_matmul_count() > before,
                "sparsity {sparsity}: the sparse path should engage"
            );
        }

        let (lf, af) = fast.eval_step(&base.eval_args(x.clone(), y.clone())).unwrap();
        let (ld, ad) = dense.eval_step(&base.eval_args(x.clone(), y.clone())).unwrap();
        assert_eq!(lf.to_bits(), ld.to_bits(), "sparsity {sparsity} eval loss");
        assert_eq!(af.to_bits(), ad.to_bits(), "sparsity {sparsity} eval acc");
    }
}

#[test]
fn intra_probe_parallelism_is_bit_identical_for_any_thread_count() {
    let variant = jet_variant();
    let mut state = ModelState::init(&variant, 23);
    for p in state.precisions.iter_mut() {
        *p = Precision::new(10, 5);
    }
    prune_masks(&mut state, 0.9, 29);
    // 256 rows = 4 row panels: large enough to split
    let (x, y) = batch(&variant, 256, 9);
    let exec = exec_with_mode(&variant, KernelMode::Fast);

    // drop the size floor so these small matmuls split panels at all
    set_par_min_flops(0);
    let (l1, a1) = with_intra_threads(1, || {
        exec.eval_step(&state.eval_args(x.clone(), y.clone())).unwrap()
    });
    let (p1, tl1, ta1) = with_intra_threads(1, || {
        exec.train_step(&state.train_args(x.clone(), y.clone(), 0.1)).unwrap()
    });
    for threads in [2usize, 3, 8] {
        let (l, a) = with_intra_threads(threads, || {
            exec.eval_step(&state.eval_args(x.clone(), y.clone())).unwrap()
        });
        assert_eq!(l1.to_bits(), l.to_bits(), "eval loss, {threads} threads");
        assert_eq!(a1.to_bits(), a.to_bits(), "eval acc, {threads} threads");
        let (p, tl, ta) = with_intra_threads(threads, || {
            exec.train_step(&state.train_args(x.clone(), y.clone(), 0.1)).unwrap()
        });
        assert_eq!(tl1.to_bits(), tl.to_bits(), "train loss, {threads} threads");
        assert_eq!(ta1.to_bits(), ta.to_bits(), "train acc, {threads} threads");
        assert_params_bit_identical(&p1, &p, &format!("{threads} threads"));
    }
    set_par_min_flops(PAR_MIN_FLOPS_DEFAULT);
}

#[test]
fn eval_batches_matches_per_batch_eval_loop() {
    let variant = jet_variant();
    let mut state = ModelState::init(&variant, 31);
    for p in state.precisions.iter_mut() {
        *p = Precision::new(10, 5);
    }
    prune_masks(&mut state, 0.9, 37);

    let mut base: Vec<HostTensor> = Vec::new();
    base.extend(state.params.iter().cloned());
    base.extend(state.masks.iter().cloned());
    base.push(state.qcfg_tensor());
    let batches: Vec<(HostTensor, HostTensor)> = (0..3)
        .map(|i| batch(&variant, 64, 100 + i))
        .collect();

    for mode in [KernelMode::Fast, KernelMode::DenseOnly, KernelMode::Naive] {
        let exec = exec_with_mode(&variant, mode);
        let batched = exec.eval_batches(&base, &batches).unwrap();
        assert_eq!(batched.len(), batches.len());
        for ((x, y), (bl, ba)) in batches.iter().zip(&batched) {
            let (l, a) = exec
                .eval_step(&state.eval_args(x.clone(), y.clone()))
                .unwrap();
            assert_eq!(l.to_bits(), bl.to_bits(), "{mode:?} batched eval loss");
            assert_eq!(a.to_bits(), ba.to_bits(), "{mode:?} batched eval acc");
        }
    }
}
