//! Hardware-stage DSE contract tests.
//!
//! Covers: the estimator's physical invariants under reuse-factor
//! sweeps (RF↑ ⇒ DSP/LUT↓, latency↑; DSP threshold; io_stream FIFO
//! BRAM), the codegen golden snapshot pinning reuse-factor/precision
//! emission for the mini-jet model, the REUSE_SEARCH O-task's
//! jobs-invariant LOG contract on a full cross-stage flow (guarded
//! VIVADO-HLS → QUANTIZATION back edge with α_q escalation), and the
//! explorer's hardware grid dimension (`hls.reuse_factor`) golden
//! Pareto front.

use metaml::bench_support::{mlp_chain_variant, synthetic_jet_mini_manifest};
use metaml::config::FlowSpec;
use metaml::flow::explore::explore;
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::hls::{codegen, HlsModel, HlsTransform, SetPrecision, SetReuseFactor};
use metaml::metamodel::{Abstraction, LogEvent, MetaModel};
use metaml::model::state::Precision;
use metaml::runtime::Runtime;
use metaml::synth::{estimate, FpgaDevice};

/// The mini-jet HLS model (16 → 16 → 8 → 5) at full density.
fn mini_jet_hls(precision: Precision) -> HlsModel {
    let variant = mlp_chain_variant("jet_mini", 1.0, &[16, 16, 8, 5]);
    HlsModel::from_nnz(&variant, &[], precision, "vu9p", 5.0).unwrap()
}

fn vu9p() -> &'static FpgaDevice {
    FpgaDevice::by_name("vu9p").unwrap()
}

// ---------------------------------------------------------------------------
// estimator physical invariants
// ---------------------------------------------------------------------------

#[test]
fn reuse_sweep_trades_resources_for_latency_monotonically() {
    let m = mini_jet_hls(Precision::new(18, 8));
    let mut prev_dsp = usize::MAX;
    let mut prev_lut = usize::MAX;
    let mut prev_cycles = 0usize;
    for rf in [1usize, 2, 4, 8] {
        let mut cand = m.clone();
        SetReuseFactor(rf).apply(&mut cand).unwrap();
        let r = estimate(&cand, vu9p(), 200.0).unwrap();
        assert!(r.dsp <= prev_dsp, "rf {rf}: dsp {} > {prev_dsp}", r.dsp);
        assert!(r.lut <= prev_lut, "rf {rf}: lut {} > {prev_lut}", r.lut);
        assert!(
            r.latency_cycles >= prev_cycles,
            "rf {rf}: cycles {} < {prev_cycles}",
            r.latency_cycles
        );
        (prev_dsp, prev_lut, prev_cycles) = (r.dsp, r.lut, r.latency_cycles);
    }
    // the sweep is a real trade overall
    let rf1 = estimate(&m, vu9p(), 200.0).unwrap();
    assert!(prev_dsp < rf1.dsp);
    assert!(prev_lut < rf1.lut);
    assert!(prev_cycles > rf1.latency_cycles);
}

#[test]
fn below_threshold_precision_uses_no_dsp() {
    // bits <= DSP_THRESHOLD_BITS (10): every multiply maps to fabric
    let m = mini_jet_hls(Precision::new(8, 3));
    let r = estimate(&m, vu9p(), 200.0).unwrap();
    assert_eq!(r.dsp, 0);
    assert!(r.lut > 0);
    // one bit above the threshold brings DSPs back
    let m11 = mini_jet_hls(Precision::new(11, 4));
    assert!(estimate(&m11, vu9p(), 200.0).unwrap().dsp > 0);
}

#[test]
fn io_stream_adds_bram_io_parallel_does_not() {
    use metaml::hls::IoType;
    let m = mini_jet_hls(Precision::new(18, 8));
    let parallel = estimate(&m, vu9p(), 200.0).unwrap();
    let mut streamed = m.clone();
    streamed.io_type = IoType::Stream;
    let stream = estimate(&streamed, vu9p(), 200.0).unwrap();
    assert_eq!(parallel.bram_18k, 0);
    assert!(stream.bram_18k >= 3, "one FIFO per compute layer");
}

#[test]
fn zero_reuse_factor_is_a_synth_error_not_a_panic() {
    let mut m = mini_jet_hls(Precision::new(18, 8));
    m.layers[0].reuse_factor = 0;
    let err = estimate(&m, vu9p(), 200.0).unwrap_err().to_string();
    assert!(err.contains("synthesis error"), "{err}");
    assert!(err.contains("reuse_factor"), "{err}");
}

// ---------------------------------------------------------------------------
// codegen golden snapshot (mini-jet): reuse factor + precision emission
// ---------------------------------------------------------------------------

#[test]
fn codegen_golden_pins_reuse_and_precision_emission() {
    let mut m = mini_jet_hls(Precision::new(8, 3));
    SetPrecision::all(Precision::new(8, 3)).apply(&mut m).unwrap();
    SetReuseFactor(4).apply(&mut m).unwrap();
    let files = codegen::emit(&m);

    let parameters = &files
        .iter()
        .find(|(name, _)| name == "parameters.h")
        .expect("parameters.h emitted")
        .1;
    let golden = "\
#ifndef PARAMETERS_H_
#define PARAMETERS_H_

#include \"defines.h\"

struct config_fc1 {
    static const unsigned n_in = 16;
    static const unsigned n_out = 16;
    static const unsigned reuse_factor = 4;
    static const unsigned n_zeros = 0;  // folded by the compiler
};

struct config_fc2 {
    static const unsigned n_in = 16;
    static const unsigned n_out = 8;
    static const unsigned reuse_factor = 4;
    static const unsigned n_zeros = 0;  // folded by the compiler
};

struct config_fc3 {
    static const unsigned n_in = 8;
    static const unsigned n_out = 5;
    static const unsigned reuse_factor = 4;
    static const unsigned n_zeros = 0;  // folded by the compiler
};

#endif
";
    assert_eq!(parameters, golden);

    let defines = &files.iter().find(|(n, _)| n == "defines.h").unwrap().1;
    assert!(defines.contains("typedef ap_fixed<8,3> fc1_t;"), "{defines}");
    assert!(defines.contains("ap_fixed<12,7> fc1_acc_t"), "{defines}");
    assert!(defines.contains("ap_fixed<11,6> fc3_acc_t"), "{defines}");

    let top = &files.iter().find(|(n, _)| n.ends_with(".cpp")).unwrap().1;
    assert!(top.contains("#pragma HLS PIPELINE II=4"), "{top}");
}

// ---------------------------------------------------------------------------
// cross-stage flow: REUSE_SEARCH + guarded VIVADO-HLS -> QUANTIZATION
// back edge, jobs-invariant LOG
// ---------------------------------------------------------------------------

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

fn crossstage_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_crossstage",
  "cfg": {
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "hls.FPGA_part_number": "zynq7020",
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7,
    "quantize.tolerate_acc_loss": 0.02,
    "quantize.tolerate_acc_loss_step": 0.02,
    "reuse.latency_budget_ns": 200.0
  },
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "quantize", "type": "QUANTIZATION"},
    {"id": "reuse", "type": "REUSE_SEARCH"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "hls"], ["hls", "quantize"], ["quantize", "reuse"],
             ["reuse", "synth"]],
  "back_edges": [
    {"from": "synth", "to": "quantize", "max_iters": 1,
     "when": {"metric": "synth.lut", "op": ">", "value": 1.0}}
  ]
}"#,
    )
    .unwrap()
}

fn run_crossstage(jobs: usize) -> (Vec<LogEvent>, MetaModel) {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    let spec = crossstage_spec();
    let mut meta = MetaModel::new();
    spec.apply_cfg(&mut meta.cfg);
    meta.cfg.set("jobs", jobs);
    Engine::new(&session, &registry).run_spec(&spec, &mut meta).unwrap();
    let events = meta.log.events().cloned().collect();
    (events, meta)
}

#[test]
fn crossstage_back_edge_fires_and_escalates_quantization() {
    let (events, meta) = run_crossstage(1);

    // the guarded back edge evaluated true and the sub-path re-ran once
    assert!(events.iter().any(|e| matches!(
        e,
        LogEvent::EdgeEvaluated { from, to, taken, .. }
            if from == "synth" && to == "quantize" && *taken
    )));
    for task in ["quantize", "reuse", "synth"] {
        assert_eq!(meta.log.count_task_started(task), 2, "{task}");
    }
    assert_eq!(meta.log.count_task_started("gen"), 1);

    // the re-run searched with a widened tolerance (cross-stage
    // feedback actually changed the DNN-stage configuration)
    let alphas = meta.log.metric_series("quantize", "tolerate_acc_loss");
    assert_eq!(alphas.len(), 2);
    assert!((alphas[0] - 0.02).abs() < 1e-12);
    assert!((alphas[1] - 0.04).abs() < 1e-12);

    // fit/utilization are guardable LOG metrics now
    for m in ["fits", "dsp_pct", "lut_pct", "ff_pct", "bram_pct", "ii"] {
        assert!(meta.log.latest_metric("synth", m).is_some(), "{m}");
    }
    let fits = meta.log.latest_metric("synth", "fits").unwrap();
    assert!(fits == 0.0 || fits == 1.0);

    // the reuse search ran against the estimator and logged its result
    assert!(meta.log.latest_metric("reuse", "lut").is_some());
    assert!(meta.log.latest_metric("reuse", "latency_ns").is_some());

    // the flow reached RTL and the HLS lineage includes a reused model
    assert!(meta.space.latest(Abstraction::Rtl).is_some());
    let hls = meta.space.latest(Abstraction::HlsCpp).unwrap();
    assert!(hls.name.contains("reused"), "{}", hls.name);
}

#[test]
fn crossstage_flow_log_is_jobs_invariant() {
    let (ev1, _) = run_crossstage(1);
    let (ev4, _) = run_crossstage(4);
    assert_eq!(ev1.len(), ev4.len());
    for (a, b) in ev1.iter().zip(&ev4) {
        assert_eq!(a, b);
    }
}

// ---------------------------------------------------------------------------
// explorer golden: a hardware grid dimension on the Pareto front
// ---------------------------------------------------------------------------

fn hw_explore_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_hw_explore",
  "cfg": {"model": "jet_mini", "gen.train_epochs": 1},
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "hls"], ["hls", "synth"]],
  "explore": {"cfg_grid": {"hls.reuse_factor": [1, 8]}}
}"#,
    )
    .unwrap()
}

#[test]
fn explore_grid_ranges_over_reuse_factor() {
    let registry = TaskRegistry::builtin();
    let spec = hw_explore_spec();
    let run = |jobs: usize| {
        let session = mini_session();
        explore(&session, &registry, &spec, &[], jobs).unwrap()
    };
    let seq = run(1);
    let par = run(4);

    assert_eq!(seq.results.len(), 2);
    let labels: Vec<&str> = seq.results.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, vec!["hls.reuse_factor=1", "hls.reuse_factor=8"]);

    // the hardware dimension moved the objectives: same accuracy (same
    // DNN flow), strictly fewer resources and more latency at RF = 8
    let (r1, r8) = (&seq.results[0], &seq.results[1]);
    assert_eq!(
        r1.metric("accuracy").unwrap().to_bits(),
        r8.metric("accuracy").unwrap().to_bits()
    );
    assert!(r8.metric("dsp").unwrap() < r1.metric("dsp").unwrap());
    assert!(r8.metric("lut").unwrap() < r1.metric("lut").unwrap());
    assert!(r8.metric("latency_ns").unwrap() > r1.metric("latency_ns").unwrap());

    // golden front: at equal accuracy the two variants trade resources
    // against latency, so BOTH are non-dominated — the hardware grid
    // dimension genuinely widens the front instead of collapsing it to
    // its cheapest point (latency is a first-class objective)
    assert_eq!(seq.front, vec![0, 1]);

    // jobs-invariant: front, metrics and full LOG streams identical
    assert_eq!(seq.front, par.front);
    for (a, b) in seq.results.iter().zip(&par.results) {
        assert_eq!(a.label, b.label);
        for (k, v) in &a.metrics {
            let w = b.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", a.label);
        }
        assert_eq!(a.events, b.events, "{}", a.label);
    }
}
