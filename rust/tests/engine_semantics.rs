//! Flow-engine integration tests with mock tasks (no AOT artifacts).
//!
//! Covers the engine's contract: deterministic topological execution,
//! multiplicity enforcement, back-edge iteration bounds, LOG events,
//! error attribution, and (via the mini property harness) invariants
//! over randomly generated DAGs.

use std::sync::{Arc, Mutex};

use metaml::flow::{
    Engine, FlowGraph, ParamSpec, PipeTask, Session, TaskCtx, TaskOutcome,
    TaskRegistry, TaskRole,
};
use metaml::metamodel::{LogEvent, MetaModel};
use metaml::prop_assert;
use metaml::testutil::check;

/// Mock task that appends its instance name to a shared trace.
struct TraceTask {
    trace: Arc<Mutex<Vec<String>>>,
    inputs: usize,
    iterate_times: usize,
    fail: bool,
}

impl PipeTask for TraceTask {
    fn name(&self) -> &str {
        "TRACE"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (self.inputs, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        if self.fail {
            return Err(metaml::Error::other("boom"));
        }
        self.trace.lock().unwrap().push(ctx.instance.clone());
        let count = self
            .trace
            .lock().unwrap()
            .iter()
            .filter(|t| **t == ctx.instance)
            .count();
        Ok(TaskOutcome {
            produced: vec![],
            request_iteration: count <= self.iterate_times,
        })
    }
}

fn registry_with(
    trace: &Arc<Mutex<Vec<String>>>,
    inputs_by_type: &[(&'static str, usize, usize, bool)],
) -> TaskRegistry {
    let mut r = TaskRegistry::empty();
    for &(name, inputs, iterate, fail) in inputs_by_type {
        let t = trace.clone();
        r.register(name, move || {
            Box::new(TraceTask {
                trace: t.clone(),
                inputs,
                iterate_times: iterate,
                fail,
            })
        });
    }
    r
}

fn session() -> Session {
    Session::without_artifacts().expect("reference backend session")
}

#[test]
fn chain_executes_in_order() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("MID", 1, 0, false)]);
    let mut g = FlowGraph::new("chain");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    let c = g.add_task("c", "MID");
    g.connect(a, b).unwrap();
    g.connect(b, c).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b", "c"]);

    // LOG contains started/finished pairs per task + flow markers
    let events = meta.log.entries();
    assert!(matches!(events.first().unwrap().event, LogEvent::FlowStarted { .. }));
    assert!(matches!(events.last().unwrap().event, LogEvent::FlowFinished { .. }));
    let starts = events
        .iter()
        .filter(|e| matches!(e.event, LogEvent::TaskStarted { .. }))
        .count();
    assert_eq!(starts, 3);
}

#[test]
fn multiplicity_violations_rejected() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("MID", 1, 0, false)]);
    // MID with zero inputs
    let mut g = FlowGraph::new("bad");
    g.add_task("m", "MID");
    let session = session();
    let mut meta = MetaModel::new();
    let err = Engine::new(&session, &registry).run(&g, &mut meta);
    assert!(err.is_err());
    assert!(err.unwrap_err().to_string().contains("1-input"));

    // SRC with one input
    let mut g2 = FlowGraph::new("bad2");
    let a = g2.add_task("a", "SRC");
    let b = g2.add_task("b", "SRC");
    g2.connect(a, b).unwrap();
    let mut meta2 = MetaModel::new();
    assert!(Engine::new(&session, &registry).run(&g2, &mut meta2).is_err());
}

#[test]
fn back_edge_iterates_subpath_bounded() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    // "b" asks for iteration twice; the budget of 3 re-executions is
    // not the binding limit here
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("LOOP", 1, 2, false)]);
    let mut g = FlowGraph::new("loop");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "LOOP");
    g.connect(a, b).unwrap();
    g.connect_back(b, a, 3).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    // a,b then back to a,b then a,b — 3 passes of the subpath
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b", "a", "b", "a", "b"]);
    let iter_events = meta
        .log
        .entries()
        .iter()
        .filter(|e| matches!(e.event, LogEvent::IterationAdvanced { .. }))
        .count();
    assert_eq!(iter_events, 2);
}

#[test]
fn back_edge_budget_caps_runaway_iteration() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    // task ALWAYS asks to iterate: budget must stop it
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("LOOP", 1, 999, false)]);
    let mut g = FlowGraph::new("runaway");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "LOOP");
    g.connect(a, b).unwrap();
    g.connect_back(b, a, 4).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    // max_iters bounds RE-executions: initial pass + 4 re-executions
    // = 5 passes x 2 tasks
    assert_eq!(trace.lock().unwrap().len(), 10);
    let iter_events = meta
        .log
        .entries()
        .iter()
        .filter(|e| matches!(e.event, LogEvent::IterationAdvanced { .. }))
        .count();
    assert_eq!(iter_events, 4);
}

/// Regression for the back-edge off-by-one: a `max_iters == 1` back edge
/// must re-execute its sub-path exactly once (it used to be a silent
/// no-op because the budget check required a budget strictly above 1).
#[test]
fn back_edge_with_unit_budget_reexecutes_exactly_once() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    // task ALWAYS asks to iterate, so only the budget limits re-execution
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("LOOP", 1, 999, false)]);
    let mut g = FlowGraph::new("single-iteration");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "LOOP");
    g.connect(a, b).unwrap();
    g.connect_back(b, a, 1).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    // initial pass + exactly one re-execution of the a..b sub-path
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b", "a", "b"]);
    let iter_events = meta
        .log
        .entries()
        .iter()
        .filter(|e| matches!(e.event, LogEvent::IterationAdvanced { .. }))
        .count();
    assert_eq!(iter_events, 1);
}

#[test]
fn task_errors_are_attributed() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = registry_with(&trace, &[("SRC", 0, 0, false), ("FAIL", 1, 0, true)]);
    let mut g = FlowGraph::new("failing");
    let a = g.add_task("ok", "SRC");
    let b = g.add_task("broken", "FAIL");
    g.connect(a, b).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    let err = Engine::new(&session, &registry)
        .run(&g, &mut meta)
        .unwrap_err()
        .to_string();
    assert!(err.contains("broken"), "{err}");
    assert!(err.contains("boom"), "{err}");
}

#[test]
fn property_random_dags_execute_all_nodes_in_topo_order() {
    check(60, |rng| {
        let trace = Arc::new(Mutex::new(Vec::new()));
        let registry =
            registry_with(&trace, &[("SRC", 0, 0, false), ("MID", 1, 0, false)]);

        // random layered DAG: sources + chain/merge-free 1-input nodes
        let n = 2 + rng.below(10);
        let mut g = FlowGraph::new("prop");
        let mut kinds = Vec::new();
        for i in 0..n {
            // node 0 is always a source; later nodes choose a parent
            if i == 0 || rng.below(4) == 0 {
                g.add_task(format!("n{i}"), "SRC");
                kinds.push(0usize);
            } else {
                let node = g.add_task(format!("n{i}"), "MID");
                // parent strictly earlier => forward edges acyclic
                let parent = rng.below(i);
                g.connect(parent, node).map_err(|e| e.to_string())?;
                kinds.push(1);
                let _ = node;
            }
        }

        let session = Session::without_artifacts().map_err(|e| e.to_string())?;
        let mut meta = MetaModel::new();
        Engine::new(&session, &registry)
            .run(&g, &mut meta)
            .map_err(|e| e.to_string())?;

        let executed = trace.lock().unwrap();
        prop_assert!(
            executed.len() == n,
            "executed {} of {n} nodes",
            executed.len()
        );
        // every node runs after its parent: trace order must respect ids
        // (lowest-id tie-break makes the order exactly sorted here, since
        // each node's parent has a smaller id)
        let order = g.topo_order().map_err(|e| e.to_string())?;
        let names: Vec<String> = order
            .iter()
            .map(|&id| g.node(id).unwrap().instance.clone())
            .collect();
        prop_assert!(*executed == names, "trace {executed:?} != topo {names:?}");
        Ok(())
    });
}
