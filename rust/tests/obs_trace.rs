//! Observability contract tests: the span recorder and metrics
//! registry are strictly side-band.
//!
//! Three pins over full searches of `examples/specs/surrogate_jet.json`
//! on the synthetic jet manifest:
//!
//! - **Span-tree structure is jobs-invariant.**  The deterministic part
//!   of every span (`id`/`parent`/`name` — position-in-parent paths,
//!   never wall clock) is bit-identical between `--jobs 1` and
//!   `--jobs 4` for the flow and search layers under the barrier
//!   scheduler.  Probe-layer *volume* is allowed to differ (speculation
//!   is jobs-dependent by design), but the spans that do appear use
//!   caller-assigned slots, so the batch shapes match too.
//! - **Cache-tier counters are exact.**  A cold run against a fresh
//!   `--cache-dir` writes exactly `DiskStore::inspect` (= `metaml
//!   cache stats`) entries through the disk tier; a warm run with
//!   fresh memos resolves every probe at the disk tier (zero misses,
//!   zero recomputes, zero new bytes).
//! - **Disabled tracing records nothing and changes nothing.**  With
//!   tracing off the snapshot is empty; enabling it leaves LOG event
//!   streams, fronts and metrics bit-identical.
//!
//! The trace recorder and metrics registry are process-global, so every
//! test here serializes on one gate and resets both before measuring.

use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};

use metaml::bench_support::synthetic_jet_manifest;
use metaml::config::FlowSpec;
use metaml::dse::{DiskStore, ProbeTiers};
use metaml::flow::{Session, TaskRegistry};
use metaml::obs::{metrics, trace};
use metaml::runtime::Runtime;
use metaml::search::{run_search, run_search_tiered, SearchSpec};

static GATE: Mutex<()> = Mutex::new(());

fn jet_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_manifest())
}

/// The CI exemplar spec, pinned to the barrier scheduler: pipelined
/// speculation volume is wall-clock-dependent, so only barrier-mode
/// span structure is replay-comparable.
fn jet_spec() -> (FlowSpec, SearchSpec) {
    let spec = FlowSpec::load("examples/specs/surrogate_jet.json").unwrap();
    let mut search = spec.search.clone().unwrap();
    search.pipeline = false;
    (spec, search)
}

/// The deterministic structure of a span list, restricted to the given
/// layers: `(id, parent, name)` in drain order (paths sort
/// numerically, so this is also deterministic).
fn structure(spans: &[trace::SpanRecord], cats: &[&str]) -> Vec<(String, String, String)> {
    spans
        .iter()
        .filter(|s| cats.contains(&s.cat))
        .map(|s| (s.id.clone(), s.parent.clone(), s.name.clone()))
        .collect()
}

#[test]
fn span_tree_structure_is_jobs_invariant() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let (spec, search) = jet_spec();
    let registry = TaskRegistry::builtin();

    let mut runs = Vec::new();
    for jobs in [1usize, 4] {
        trace::enable();
        trace::reset();
        let session = jet_session();
        let out = run_search(&session, &registry, &spec, &search, &[], jobs).unwrap();
        assert_eq!(out.spent, 6);
        runs.push(trace::drain());
    }
    trace::disable();

    // flow + search layers: bit-identical ids whatever the worker count
    let a = structure(&runs[0], &["flow", "search"]);
    let b = structure(&runs[1], &["flow", "search"]);
    assert!(!a.is_empty());
    assert_eq!(a, b);

    // every layer the tentpole promises is present, including distinct
    // queue-wait vs execute intervals per probe
    for spans in &runs {
        let names: BTreeSet<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        for expected in [
            "search.run",
            "search.warmup",
            "search.round",
            "search.propose",
            "search.eval",
            "search.observe",
            "surrogate.fit",
            "surrogate.predict",
            "flow.run",
            "flow.task",
            "probe.batch",
            "probe.wait",
            "probe.exec",
            "cache.lookup",
        ] {
            assert!(names.contains(expected), "missing span {expected:?} in {names:?}");
        }
        // queue waits and executions land on caller-assigned even/odd
        // slots under their batch envelope, so the two interval kinds
        // stay distinguishable in any viewer
        let wait = spans.iter().find(|s| s.name == "probe.wait").unwrap();
        let exec = spans.iter().find(|s| s.name == "probe.exec").unwrap();
        assert!(wait.detached, "queue waits render as async intervals");
        assert!(!exec.detached, "executions render as nested complete spans");
    }
}

#[test]
fn disk_tier_counters_match_cache_stats() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::temp_dir().join(format!("metaml-obs-disk-{}", std::process::id()));
    let _ = DiskStore::clear(&dir);
    let (spec, search) = jet_spec();
    let registry = TaskRegistry::builtin();

    // cold: the disk tier misses everything; every fresh compute is
    // written through exactly once, so the write counters equal what
    // `metaml cache stats` reports for the store
    metrics::reset();
    let tiers = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let session = jet_session();
    let cold = run_search_tiered(&session, &registry, &spec, &search, &[], 1, &tiers).unwrap();
    assert!(cold.probes.train_computed > 0);
    let stats = DiskStore::inspect(&dir);
    assert_eq!(metrics::counter("cache.train.disk.write"), stats.train_entries as u64);
    assert_eq!(metrics::counter("cache.hw.disk.write"), stats.hw_entries as u64);
    assert_eq!(metrics::counter("cache.train.disk.hit"), 0);
    assert!(metrics::counter("cache.train.disk.miss") > 0);

    // warm: fresh memos over the same store — every probe resolves at
    // the disk tier, nothing recomputes, the store stays byte-stable
    metrics::reset();
    let tiers = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let session = jet_session();
    let warm = run_search_tiered(&session, &registry, &spec, &search, &[], 1, &tiers).unwrap();
    assert_eq!(warm.probes.train_computed, 0);
    assert_eq!(metrics::counter("cache.train.disk.miss"), 0);
    assert!(metrics::counter("cache.train.disk.hit") > 0);
    assert_eq!(metrics::counter("cache.train.disk.write"), 0);
    let after = DiskStore::inspect(&dir);
    assert_eq!(after.train_entries, stats.train_entries);
    assert_eq!(after.hw_entries, stats.hw_entries);
    assert_eq!(after.bytes, stats.bytes);

    // tier resolution is top-down: every warm-run memo miss fell
    // through to exactly one disk consult
    assert_eq!(
        metrics::counter("cache.train.memo.miss"),
        metrics::counter("cache.train.disk.hit") + metrics::counter("cache.train.disk.miss"),
    );

    let _ = DiskStore::clear(&dir);
}

#[test]
fn disabled_tracing_records_nothing_and_results_are_identical() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let (spec, search) = jet_spec();
    let registry = TaskRegistry::builtin();

    trace::disable();
    trace::reset();
    let session = jet_session();
    let off = run_search(&session, &registry, &spec, &search, &[], 1).unwrap();
    assert!(trace::snapshot().is_empty(), "disabled tracing must record nothing");

    trace::enable();
    trace::reset();
    let session = jet_session();
    let on = run_search(&session, &registry, &spec, &search, &[], 1).unwrap();
    let spans = trace::drain();
    trace::disable();
    assert!(!spans.is_empty());

    // tracing is strictly side-band: candidate sequence, LOG streams
    // and every metric bit survive untouched
    assert_eq!(off.spent, on.spent);
    assert_eq!(off.outcome.front, on.outcome.front);
    assert_eq!(off.outcome.results.len(), on.outcome.results.len());
    for (x, y) in off.outcome.results.iter().zip(&on.outcome.results) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.events, y.events, "{}", x.label);
        for (k, v) in &x.metrics {
            let w = y.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", x.label);
        }
    }
}
