//! Pipelined probe scheduling semantics on the synthetic mini jet
//! manifest.
//!
//! Pins the PR's headline contract: the pipelined scheduler (the
//! `search.pipeline` default — speculative next-round candidates
//! enqueued on the persistent worker pool, committed in proposal
//! order) produces a trace **bit-identical** to the lock-step barrier
//! scheduler, for every `--jobs` value — labels, LOG streams, metric
//! bit patterns, front, budget accounting and surrogate accounting.
//! Also pins what speculation is allowed to touch: mis-speculated
//! probes never appear in the observed trace but DO land in the shared
//! probe tiers as cache fodder.

use metaml::bench_support::synthetic_jet_mini_manifest;
use metaml::config::FlowSpec;
use metaml::dse::ProbeTiers;
use metaml::flow::{Session, TaskRegistry};
use metaml::json::Value;
use metaml::runtime::Runtime;
use metaml::search::{run_search_tiered, SearchOutcome, SearchSpec};

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

/// Run against fresh tiers (cold cache per call) and hand both back so
/// tests can inspect what speculation left behind.
fn run_tiered(
    spec: &FlowSpec,
    search: &SearchSpec,
    jobs: usize,
) -> (SearchOutcome, ProbeTiers) {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    let tiers = ProbeTiers::new();
    let extra = vec![("model".to_string(), Value::String("jet_mini".into()))];
    let out =
        run_search_tiered(&session, &registry, spec, search, &extra, jobs, &tiers).unwrap();
    (out, tiers)
}

/// Bit-identity over everything the determinism contract covers:
/// labels, front, every metric's bit pattern, every LOG event stream,
/// budget spend and surrogate accounting.  Probe *counters* stay out —
/// `*_computed` and `spec_*` are wall-clock diagnostics.
fn assert_bit_identical(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.outcome.front, b.outcome.front, "{what}: front");
    assert_eq!(a.outcome.results.len(), b.outcome.results.len(), "{what}");
    for (x, y) in a.outcome.results.iter().zip(&b.outcome.results) {
        assert_eq!(x.label, y.label, "{what}");
        assert_eq!(x.events, y.events, "{what}: {} LOG", x.label);
        for (k, v) in &x.metrics {
            let w = y.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{what}: {} {k}", x.label);
        }
    }
    assert_eq!(a.spent, b.spent, "{what}: spent");
    assert_eq!(a.grid_size, b.grid_size, "{what}: grid_size");
    let sur = |o: &SearchOutcome| {
        o.surrogate.as_ref().map(|s| {
            let mae: Vec<u64> = s.mean_abs_error.iter().map(|e| e.to_bits()).collect();
            (s.fits, s.predictions, s.deferred, s.validated, mae)
        })
    };
    assert_eq!(sur(a), sur(b), "{what}: surrogate accounting");
}

/// The checked-in surrogate example spec (evolve + online surrogate
/// over a six-clock grid), retargeted at the mini model so the whole
/// flow runs on the reference interpreter.
fn surrogate_jet_spec() -> FlowSpec {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/specs/surrogate_jet.json");
    FlowSpec::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

#[test]
fn pipelined_traces_are_bit_identical_across_jobs_and_to_the_barrier() {
    let spec = surrogate_jet_spec();
    let search = spec.search.clone().expect("example spec declares a search section");
    assert!(search.pipeline, "pipelining is the default");

    let (golden, _) = run_tiered(&spec, &search, 1);
    assert!(golden.spent > 0);

    // the pipelined runs must match at every width — and actually
    // speculate at jobs > 1 (the guess stream is deterministic, so
    // submissions are too; only cancel/commit timing is not)
    for jobs in [4usize, 16] {
        let (out, _) = run_tiered(&spec, &search, jobs);
        assert_bit_identical(&golden, &out, &format!("pipelined jobs={jobs}"));
        assert!(out.probes.spec_submitted > 0, "jobs={jobs}: {:?}", out.probes);
    }

    // ... and the explicit barrier opt-out must match it bit for bit
    // while never speculating
    let barrier = SearchSpec { pipeline: false, ..search };
    let (bar, _) = run_tiered(&spec, &barrier, 4);
    assert_eq!(bar.probes.spec_submitted, 0, "{:?}", bar.probes);
    assert_eq!(bar.probes.spec_committed, 0);
    assert_bit_identical(&golden, &bar, "barrier jobs=4");
}

/// A scenario where one mis-speculation is *guaranteed*, not lucky:
/// `evolve` with population 2 on a three-point grid, budget 1.  The
/// speculation clone proposes the same shuffled two-candidate prefix
/// the real propose draws from (same PRNG state, no ranker), but the
/// budget truncates the real batch to one — so exactly two flows are
/// speculated, the first commits, and the second is pure cache fodder
/// that `finish()` drains into the tiers.
fn speculation_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_speculation",
  "cfg": {
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7,
    "reuse.latency_budget_ns": 400.0
  },
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "prune", "type": "PRUNING"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "quantize", "type": "QUANTIZATION"},
    {"id": "reuse", "type": "REUSE_SEARCH"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "reuse"], ["reuse", "synth"]],
  "explore": {
    "cfg_grid": {"hls.clock_period": [5, 10, 20]}
  },
  "search": {"strategy": "evolve", "budget": 1, "seed": 0, "population": 2}
}"#,
    )
    .unwrap()
}

#[test]
fn misspeculated_probes_never_alter_the_trace_and_land_in_the_memo_tier() {
    let spec = speculation_spec();
    let search = spec.search.clone().unwrap();
    let barrier = SearchSpec { pipeline: false, ..search.clone() };

    let (bar, bar_tiers) = run_tiered(&spec, &barrier, 4);
    let (pipe, pipe_tiers) = run_tiered(&spec, &search, 4);

    // observed trace: identical, exactly one evaluation either way
    assert_eq!(bar.evaluations(), 1);
    assert_bit_identical(&bar, &pipe, "speculation vs barrier");

    // speculation accounting is exact here for any seed: the guess
    // pair is the real batch's superset, the budget commits one, and
    // nothing is cancelled (the search ends before any guess goes
    // stale, and finish() always waits)
    assert_eq!(pipe.probes.spec_submitted, 2, "{:?}", pipe.probes);
    assert_eq!(pipe.probes.spec_committed, 1, "{:?}", pipe.probes);
    assert_eq!(pipe.probes.spec_cancelled, 0, "{:?}", pipe.probes);
    assert_eq!(bar.probes.spec_submitted, 0, "{:?}", bar.probes);

    // the mis-speculated flow ran a distinct clock period, so its
    // hardware probes landed in the shared tiers as cache fodder —
    // strictly more memo entries than the barrier run left behind
    assert!(
        pipe_tiers.hw.len() > bar_tiers.hw.len(),
        "pipelined hw memo {} vs barrier {}",
        pipe_tiers.hw.len(),
        bar_tiers.hw.len()
    );
    // and the fodder is usable: rerunning the mis-speculated point on
    // the warmed tiers computes no fresh hardware probes
    let full = SearchSpec { budget: None, ..search };
    let before = pipe_tiers.probe_counts();
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    let extra = vec![("model".to_string(), Value::String("jet_mini".into()))];
    let all = run_search_tiered(
        &session, &registry, &spec, &full, &extra, 4, &pipe_tiers,
    )
    .unwrap();
    assert_eq!(all.evaluations(), 3);
    let after = pipe_tiers.probe_counts();
    assert!(
        after.hw_computed - before.hw_computed < after.hw_issued - before.hw_issued,
        "warmed tiers must serve some hardware probes from the memo: {after:?}"
    );
}
