//! Flow-control semantics of the composable design-flow IR.
//!
//! Covers: conditional-edge truth tables (every comparison operator),
//! skip propagation, relaxed multiplicity under guarded edges,
//! LOG-determinism of identical runs, S-task (strategy) selection and
//! its jobs-invariance on a real mini flow, nested sub-flow
//! namespacing, and the multi-flow explorer's golden Pareto front for
//! `s_p_q`-vs-`p_s_q`-style order variants on the synthetic mini jet
//! manifest.

use std::sync::{Arc, Mutex};

use metaml::bench_support::synthetic_jet_mini_manifest;
use metaml::config::FlowSpec;
use metaml::flow::explore::{expand_variants, explore};
use metaml::flow::{
    CmpOp, EdgeGuard, Engine, FlowGraph, ParamSpec, PipeTask, Session, TaskCtx,
    TaskOutcome, TaskRegistry, TaskRole,
};
use metaml::metamodel::{LogEvent, MetaModel, ModelPayload};
use metaml::model::state::Precision;
use metaml::model::ModelState;
use metaml::runtime::Runtime;

/// Mock task: appends its instance to a shared trace and logs a fixed
/// `score` metric.
struct ScoreTask {
    trace: Arc<Mutex<Vec<String>>>,
    inputs: usize,
    score: f64,
}

impl PipeTask for ScoreTask {
    fn name(&self) -> &str {
        "SCORE"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (self.inputs, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        self.trace.lock().unwrap().push(ctx.instance.clone());
        let score = self.score;
        ctx.log_metric("score", score);
        Ok(TaskOutcome::default())
    }
}

/// Mock task recording a metric only on its model-space artifact (not
/// in the LOG) — exercises the guard's model-space fallback.
struct SpaceMetricTask {
    score: f64,
}

impl PipeTask for SpaceMetricTask {
    fn name(&self) -> &str {
        "SPACE-METRIC"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (0, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        let id = ctx.meta.space.store(
            "m",
            ctx.instance.clone(),
            None,
            ModelPayload::Dnn(ModelState {
                tag: "t".into(),
                params: vec![],
                masks: vec![],
                precisions: vec![Precision::DISABLED],
                weight_param_idx: vec![],
            }),
        );
        ctx.meta.space.set_metric(id, "score", self.score)?;
        Ok(TaskOutcome::produced([id]))
    }
}

fn score_registry(trace: &Arc<Mutex<Vec<String>>>, score: f64) -> TaskRegistry {
    let mut r = TaskRegistry::empty();
    let t = trace.clone();
    r.register("SRC", move || {
        Box::new(ScoreTask { trace: t.clone(), inputs: 0, score })
    });
    let t = trace.clone();
    r.register("MID", move || {
        Box::new(ScoreTask { trace: t.clone(), inputs: 1, score })
    });
    r.register("SPACE", move || Box::new(SpaceMetricTask { score }));
    r
}

fn session() -> Session {
    Session::without_artifacts().expect("reference backend session")
}

fn guard(metric: &str, op: CmpOp, value: f64) -> EdgeGuard {
    EdgeGuard { metric: metric.into(), op, value }
}

// ---------------------------------------------------------------------------
// conditional-edge truth tables
// ---------------------------------------------------------------------------

#[test]
fn conditional_edge_truth_table() {
    // source logs score = 0.5; table: (op, threshold, edge taken?)
    let cases = [
        (CmpOp::Lt, 0.6, true),
        (CmpOp::Lt, 0.5, false),
        (CmpOp::Le, 0.5, true),
        (CmpOp::Le, 0.4, false),
        (CmpOp::Gt, 0.4, true),
        (CmpOp::Gt, 0.5, false),
        (CmpOp::Ge, 0.5, true),
        (CmpOp::Ge, 0.6, false),
        (CmpOp::Eq, 0.5, true),
        (CmpOp::Eq, 0.4, false),
        (CmpOp::Ne, 0.4, true),
        (CmpOp::Ne, 0.5, false),
    ];
    for (op, threshold, expect_taken) in cases {
        let trace = Arc::new(Mutex::new(Vec::new()));
        let registry = score_registry(&trace, 0.5);
        let mut g = FlowGraph::new("truth");
        let a = g.add_task("a", "SRC");
        let b = g.add_task("b", "MID");
        g.connect_when(a, b, guard("a.score", op, threshold)).unwrap();

        let session = session();
        let mut meta = MetaModel::new();
        Engine::new(&session, &registry).run(&g, &mut meta).unwrap();

        let expected: Vec<String> = if expect_taken {
            vec!["a".into(), "b".into()]
        } else {
            vec!["a".into()]
        };
        assert_eq!(*trace.lock().unwrap(), expected, "{op} {threshold}");

        // the decision is in the LOG, with the observed value
        let eval = meta
            .log
            .events()
            .find_map(|e| match e {
                LogEvent::EdgeEvaluated { from, to, metric, value, taken } => {
                    Some((from.clone(), to.clone(), metric.clone(), *value, *taken))
                }
                _ => None,
            })
            .expect("EdgeEvaluated logged");
        assert_eq!(eval, ("a".into(), "b".into(), "a.score".into(), 0.5, expect_taken));
        let skipped = meta
            .log
            .events()
            .any(|e| matches!(e, LogEvent::TaskSkipped { task } if task == "b"));
        assert_eq!(skipped, !expect_taken);
    }
}

#[test]
fn skipping_propagates_downstream() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    // a -> b (guard false) -> c (plain): b and c both skipped
    let mut g = FlowGraph::new("prop");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    let c = g.add_task("c", "MID");
    g.connect_when(a, b, guard("a.score", CmpOp::Gt, 0.9)).unwrap();
    g.connect(b, c).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a"]);
    let skipped: Vec<String> = meta
        .log
        .events()
        .filter_map(|e| match e {
            LogEvent::TaskSkipped { task } => Some(task.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(skipped, vec!["b", "c"]);
    // no guard evaluation is logged for the edge out of a skipped node
    let evals = meta
        .log
        .events()
        .filter(|e| matches!(e, LogEvent::EdgeEvaluated { .. }))
        .count();
    assert_eq!(evals, 1);
}

#[test]
fn branch_merge_runs_target_when_any_edge_taken() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    // a -> {b if score > 0.9 (false), c if score <= 0.9 (true)} -> d
    let mut g = FlowGraph::new("merge");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    let c = g.add_task("c", "MID");
    let d = g.add_task("d", "MID");
    g.connect_when(a, b, guard("a.score", CmpOp::Gt, 0.9)).unwrap();
    g.connect_when(a, c, guard("a.score", CmpOp::Le, 0.9)).unwrap();
    g.connect_when(b, d, guard("b.score", CmpOp::Ge, 0.0)).unwrap();
    g.connect_when(c, d, guard("c.score", CmpOp::Ge, 0.0)).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "c", "d"]);
}

#[test]
fn guard_falls_back_to_model_space_metrics() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.8);
    let mut g = FlowGraph::new("space-fallback");
    let a = g.add_task("a", "SPACE");
    let b = g.add_task("b", "MID");
    g.connect_when(a, b, guard("a.score", CmpOp::Ge, 0.7)).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["b"]);
}

#[test]
fn missing_guard_metric_is_a_hard_error() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    let mut g = FlowGraph::new("missing");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    g.connect_when(a, b, guard("a.nonexistent", CmpOp::Ge, 0.5)).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    let err = Engine::new(&session, &registry)
        .run(&g, &mut meta)
        .unwrap_err()
        .to_string();
    assert!(err.contains("nonexistent"), "{err}");
}

#[test]
fn multiplicity_relaxed_for_guarded_edges() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    // 1-input b with TWO guarded in-edges is legal (range check) …
    let mut g = FlowGraph::new("relaxed");
    let a = g.add_task("a", "SRC");
    let a2 = g.add_task("a2", "SRC");
    let b = g.add_task("b", "MID");
    g.connect_when(a, b, guard("a.score", CmpOp::Ge, 0.9)).unwrap();
    g.connect_when(a2, b, guard("a2.score", CmpOp::Lt, 0.9)).unwrap();
    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "a2", "b"]);

    // … but a 1-input task with no in-edges at all is still rejected
    let mut g2 = FlowGraph::new("strict");
    g2.add_task("b", "MID");
    let mut meta2 = MetaModel::new();
    let err = Engine::new(&session, &registry)
        .run(&g2, &mut meta2)
        .unwrap_err()
        .to_string();
    assert!(err.contains("1-input"), "{err}");
}

// ---------------------------------------------------------------------------
// LOG determinism
// ---------------------------------------------------------------------------

#[test]
fn identical_runs_produce_identical_logs() {
    let run = || {
        let trace = Arc::new(Mutex::new(Vec::new()));
        let registry = score_registry(&trace, 0.5);
        let mut g = FlowGraph::new("det");
        let a = g.add_task("a", "SRC");
        let b = g.add_task("b", "MID");
        let c = g.add_task("c", "MID");
        g.connect(a, b).unwrap();
        g.connect_when(b, c, guard("b.score", CmpOp::Ge, 0.4)).unwrap();
        let session = session();
        let mut meta = MetaModel::new();
        Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
        let events: Vec<LogEvent> = meta.log.events().cloned().collect();
        let notes = meta.log.side_notes().len();
        (events, notes)
    };
    let (ev1, notes1) = run();
    let (ev2, _) = run();
    // wall-clock durations live in the side table, so the event streams
    // of two identical runs compare bit-for-bit equal
    assert_eq!(ev1, ev2);
    // …and the engine did record one duration note per executed task
    assert_eq!(notes1, 3);
    assert!(!ev1.iter().any(|e| matches!(
        e,
        LogEvent::Metric { name, .. } if name == "secs"
    )));
}

/// Mock task that always requests iteration.
struct IterTask {
    trace: Arc<Mutex<Vec<String>>>,
}

impl PipeTask for IterTask {
    fn name(&self) -> &str {
        "ITER"
    }
    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }
    fn multiplicity(&self) -> (usize, usize) {
        (0, 1)
    }
    fn params(&self) -> Vec<ParamSpec> {
        vec![]
    }
    fn run(&self, ctx: &mut TaskCtx) -> metaml::Result<TaskOutcome> {
        self.trace.lock().unwrap().push(ctx.instance.clone());
        Ok(TaskOutcome { produced: vec![], request_iteration: true })
    }
}

#[test]
fn strategy_node_propagates_iteration_requests_to_back_edges() {
    use metaml::flow::StrategyArm;
    let trace = Arc::new(Mutex::new(Vec::new()));
    let mut registry = score_registry(&trace, 0.5);
    let t = trace.clone();
    registry.register("ITER", move || Box::new(IterTask { trace: t.clone() }));

    let mut arm_flow = FlowGraph::new("loop-arm");
    arm_flow.add_task("it", "ITER");
    let mut g = FlowGraph::new("strategy-loop");
    let a = g.add_task("a", "SRC");
    let s = g
        .add_strategy(
            "opt",
            vec![StrategyArm { name: "only".into(), when: None, flow: arm_flow }],
        )
        .unwrap();
    g.connect(a, s).unwrap();
    g.connect_back(s, a, 1).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    // the arm task's iteration request bubbles out of the S-task, so
    // the back edge re-executes the sub-path exactly once (budget 1)
    assert_eq!(*trace.lock().unwrap(), vec!["a", "opt.it", "a", "opt.it"]);
}

#[test]
fn guarded_back_edge_fires_on_metric_until_budget_exhausted() {
    // score 0.5 > 0.4: the guarded back edge fires on the metric alone
    // (no task iteration request), bounded by max_iters = 2
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    let mut g = FlowGraph::new("metric-loop");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    g.connect(a, b).unwrap();
    g.connect_back_when(b, a, 2, guard("b.score", CmpOp::Gt, 0.4)).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b", "a", "b", "a", "b"]);

    // every firing decision is in the LOG: two taken evaluations, and
    // none once the budget is exhausted
    let evals: Vec<bool> = meta
        .log
        .events()
        .filter_map(|e| match e {
            LogEvent::EdgeEvaluated { from, to, taken, .. }
                if from == "b" && to == "a" =>
            {
                Some(*taken)
            }
            _ => None,
        })
        .collect();
    assert_eq!(evals, vec![true, true]);
}

#[test]
fn guarded_back_edge_does_not_fire_when_predicate_fails() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.3);
    let mut g = FlowGraph::new("metric-noloop");
    let a = g.add_task("a", "SRC");
    let b = g.add_task("b", "MID");
    g.connect(a, b).unwrap();
    g.connect_back_when(b, a, 2, guard("b.score", CmpOp::Gt, 0.4)).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run(&g, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b"]);
    // the rejection is logged (guard evaluated, not taken)
    assert!(meta.log.events().any(|e| matches!(
        e,
        LogEvent::EdgeEvaluated { from, to, taken, .. }
            if from == "b" && to == "a" && !*taken
    )));
}

#[test]
fn run_spec_replans_after_graph_mutation() {
    let trace = Arc::new(Mutex::new(Vec::new()));
    let registry = score_registry(&trace, 0.5);
    let mut spec = FlowSpec::parse(
        r#"{"name": "mut", "tasks": [{"id": "a", "type": "SRC"}], "edges": []}"#,
    )
    .unwrap();
    // mutate the graph after parsing: the cached plan is stale, and
    // run_spec must replan instead of indexing out of bounds
    let a = spec.graph.node_by_instance("a").unwrap();
    let b = spec.graph.add_task("b", "MID");
    spec.graph.connect(a, b).unwrap();

    let session = session();
    let mut meta = MetaModel::new();
    Engine::new(&session, &registry).run_spec(&spec, &mut meta).unwrap();
    assert_eq!(*trace.lock().unwrap(), vec!["a", "b"]);
}

// ---------------------------------------------------------------------------
// real mini flows: S-task selection + conditional bypass, jobs-invariant
// ---------------------------------------------------------------------------

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

/// A strategy + conditional-edge spec over the mini jet family: the
/// S-task picks a quantization arm from the trained accuracy, and the
/// `refine` task is bypassed via a conditional edge pair.
fn strategy_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_strategy",
  "cfg": {
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "opt.qa.start_precision": "ap_fixed<8,4>",
    "opt.qa.min_bits": 7,
    "opt.ql.start_precision": "ap_fixed<8,4>",
    "opt.ql.min_bits": 7,
    "refine.start_precision": "ap_fixed<8,4>",
    "refine.min_bits": 7
  },
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "opt", "strategy": {"arms": [
      {"name": "aggressive",
       "when": {"metric": "gen.accuracy", "op": ">=", "value": 0.995},
       "flow": {"tasks": [{"id": "qa", "type": "QUANTIZATION"}], "edges": []}},
      {"name": "light",
       "flow": {"tasks": [{"id": "ql", "type": "QUANTIZATION"}], "edges": []}}
    ]}},
    {"id": "refine", "type": "QUANTIZATION"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [
    ["gen", "opt"],
    {"from": "opt", "to": "refine",
     "when": {"metric": "gen.accuracy", "op": "<", "value": 0.995}},
    {"from": "opt", "to": "hls",
     "when": {"metric": "gen.accuracy", "op": ">=", "value": 0.995}},
    ["refine", "hls"],
    ["hls", "synth"]
  ]
}"#,
    )
    .unwrap()
}

fn run_strategy_flow(jobs: usize) -> (Vec<LogEvent>, MetaModel) {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    let spec = strategy_spec();
    let mut meta = MetaModel::new();
    spec.apply_cfg(&mut meta.cfg);
    meta.cfg.set("jobs", jobs);
    Engine::new(&session, &registry).run_spec(&spec, &mut meta).unwrap();
    let events = meta.log.events().cloned().collect();
    (events, meta)
}

#[test]
fn strategy_selection_and_conditional_bypass_on_real_flow() {
    let (events, meta) = run_strategy_flow(1);

    // the 1-epoch model is nowhere near 0.995 accuracy => "light" arm
    let selected = events
        .iter()
        .find_map(|e| match e {
            LogEvent::StrategySelected { task, arm } => Some((task.clone(), arm.clone())),
            _ => None,
        })
        .expect("strategy selection logged");
    assert_eq!(selected, ("opt".into(), "light".into()));

    // every branch decision is in the LOG: the rejected arm guard, the
    // taken refine edge and the bypass edge that was not taken
    let evals: Vec<(String, String, bool)> = events
        .iter()
        .filter_map(|e| match e {
            LogEvent::EdgeEvaluated { from, to, taken, .. } => {
                Some((from.clone(), to.clone(), *taken))
            }
            _ => None,
        })
        .collect();
    assert!(evals.contains(&("opt".into(), "aggressive".into(), false)), "{evals:?}");
    assert!(evals.contains(&("opt".into(), "refine".into(), true)), "{evals:?}");
    assert!(evals.contains(&("opt".into(), "hls".into(), false)), "{evals:?}");

    // the arm's tasks ran under the strategy namespace
    assert!(events.iter().any(|e| matches!(
        e,
        LogEvent::TaskStarted { task } if task == "opt.ql"
    )));
    // nested sub-flow markers carry the namespaced flow name
    assert!(events.iter().any(|e| matches!(
        e,
        LogEvent::FlowStarted { flow } if flow == "opt.light"
    )));
    // refine ran (not skipped), and the flow reached RTL
    assert!(!events
        .iter()
        .any(|e| matches!(e, LogEvent::TaskSkipped { task } if task == "refine")));
    assert!(meta
        .space
        .latest(metaml::metamodel::Abstraction::Rtl)
        .is_some());
    // the quantization searches actually applied the CFG'd start
    // precision (namespaced key reached the arm task)
    let bits = meta.log.metric_series("opt.ql", "bits_total");
    assert_eq!(bits.len(), 1);
    assert!(bits[0] <= 3.0 * 8.0, "start precision not applied: {bits:?}");
}

#[test]
fn strategy_flow_log_is_jobs_invariant() {
    let (ev1, _) = run_strategy_flow(1);
    let (ev4, _) = run_strategy_flow(4);
    assert_eq!(ev1.len(), ev4.len());
    for (a, b) in ev1.iter().zip(&ev4) {
        assert_eq!(a, b);
    }
}

// ---------------------------------------------------------------------------
// multi-flow explorer golden test: order variants on the mini jet manifest
// ---------------------------------------------------------------------------

/// `s_p_q`-vs-`p_s_q`-style order permutations × two pruning
/// tolerances on the synthetic mini jet manifest.
fn explorer_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_explore",
  "cfg": {
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "scale.train_epochs": 1,
    "scale.tolerate_acc_loss": 0.05,
    "scale.max_trials_num": 2,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7
  },
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "scale", "type": "SCALING"},
    {"id": "prune", "type": "PRUNING"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "quantize", "type": "QUANTIZATION"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "scale"], ["scale", "prune"], ["prune", "hls"],
             ["hls", "quantize"], ["quantize", "synth"]],
  "explore": {
    "orders": [
      ["gen", "scale", "prune", "hls", "quantize", "synth"],
      ["gen", "prune", "scale", "hls", "quantize", "synth"]
    ],
    "cfg_grid": {"prune.tolerate_acc_loss": [0.02, 0.05]}
  }
}"#,
    )
    .unwrap()
}

#[test]
fn explorer_pareto_front_is_deterministic_and_jobs_invariant() {
    let registry = TaskRegistry::builtin();
    let spec = explorer_spec();

    let variants = expand_variants(&spec).unwrap();
    let labels: Vec<&str> = variants.iter().map(|v| v.label.as_str()).collect();
    assert_eq!(
        labels,
        vec![
            "gen-scale-prune-hls-quantize-synth prune.tolerate_acc_loss=0.02",
            "gen-scale-prune-hls-quantize-synth prune.tolerate_acc_loss=0.05",
            "gen-prune-scale-hls-quantize-synth prune.tolerate_acc_loss=0.02",
            "gen-prune-scale-hls-quantize-synth prune.tolerate_acc_loss=0.05",
        ]
    );

    let run = |jobs: usize| {
        let session = mini_session();
        explore(&session, &registry, &spec, &[], jobs).unwrap()
    };
    let seq = run(1);
    let par = run(4);

    // ≥ 4 variants ran, every one reached RTL with the three objectives
    assert_eq!(seq.results.len(), 4);
    for r in &seq.results {
        assert!(r.metric("accuracy").is_some(), "{}", r.label);
        assert!(r.metric("dsp").is_some(), "{}", r.label);
        assert!(r.metric("lut").is_some(), "{}", r.label);
        assert!(r.n_models >= 5, "{}: {} models", r.label, r.n_models);
    }

    // golden determinism: front and all per-variant results identical
    // for jobs=1 vs jobs=4, including the complete LOG event streams
    assert_eq!(seq.front, par.front);
    assert!(!seq.front.is_empty());
    for (a, b) in seq.results.iter().zip(&par.results) {
        assert_eq!(a.label, b.label);
        for (k, v) in &a.metrics {
            let w = b.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", a.label);
        }
        assert_eq!(a.events.len(), b.events.len(), "{}", a.label);
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x, y, "{}", a.label);
        }
    }

    // the front is the non-dominated set over (accuracy ↑, DSP ↓,
    // LUT ↓, latency ↓): nothing on it is dominated
    let obj = |r: &metaml::flow::VariantResult| {
        (
            r.metric("accuracy").unwrap(),
            r.metric("dsp").unwrap(),
            r.metric("lut").unwrap(),
            r.metric("latency_ns").unwrap(),
        )
    };
    for &i in &seq.front {
        let (ai, di, li, ti) = obj(&seq.results[i]);
        for (j, other) in seq.results.iter().enumerate() {
            if j == i {
                continue;
            }
            let (aj, dj, lj, tj) = obj(other);
            let dominates = aj >= ai
                && dj <= di
                && lj <= li
                && tj <= ti
                && (aj > ai || dj < di || lj < li || tj < ti);
            assert!(!dominates, "front member {i} dominated by {j}");
        }
    }
}
