//! Parallel-DSE determinism contract tests.
//!
//! The probe pool promises: results are bit-identical for every `jobs`
//! value, and the memoizing eval cache never changes a result.  These
//! tests pin that contract on real searches over the reference
//! interpreter — `quantize_search`, `autoprune` and `scale_search` are
//! each run under `jobs = 1` and `jobs = 4` from identical starting
//! states, and every trace field (including accuracy bit patterns and
//! accepted-probe sets) must match.

use metaml::bench_support::dense_layer;
use metaml::data::{Dataset, DatasetSpec};
use metaml::dse::{ProbePool, ProbeRequest};
use metaml::flow::Session;
use metaml::model::state::Precision;
use metaml::model::ModelState;
use metaml::prune::{autoprune, AutopruneConfig};
use metaml::quant::{quantize_search, QuantConfig};
use metaml::runtime::kernels::{set_par_min_flops, PAR_MIN_FLOPS_DEFAULT};
use metaml::runtime::{Manifest, ModelExecutable, ModelVariant, Runtime};
use metaml::scale::{scale_search, ScaleConfig};
use metaml::train::{TrainConfig, Trainer};

/// A 3-weight-layer MLP variant (8 → h1 → h2 → 3) at a given scale tag.
fn mlp_variant(scale: f64, tag: &str, h1: usize, h2: usize) -> ModelVariant {
    ModelVariant {
        model: "dse_mlp".into(),
        scale,
        tag: tag.into(),
        input_shape: vec![8],
        n_classes: 3,
        train_batch: 32,
        eval_batch: 64,
        param_shapes: vec![
            ("w0".into(), vec![8, h1]),
            ("b0".into(), vec![h1]),
            ("w1".into(), vec![h1, h2]),
            ("b1".into(), vec![h2]),
            ("w2".into(), vec![h2, 3]),
            ("b2".into(), vec![3]),
        ],
        mask_shapes: vec![(0, vec![8, h1]), (2, vec![h1, h2]), (4, vec![h2, 3])],
        qcfg_rows: 3,
        layers: vec![
            dense_layer("fc1", "relu", 8, h1, 0, 0),
            dense_layer("fc2", "relu", h1, h2, 2, 1),
            dense_layer("out", "linear", h2, 3, 4, 2),
        ],
        train_artifact: "unused".into(),
        eval_artifact: "unused".into(),
    }
}

/// Small, fast dataset shared by the single-variant tests.
fn small_dataset() -> Dataset {
    Dataset::generate(&DatasetSpec {
        name: "dse_sim".into(),
        input_shape: vec![8],
        n_classes: 3,
        n_train: 256,
        n_test: 128,
        noise: 0.8,
        seed: 9,
    })
}

/// Reference-backend executable + briefly trained base state.
fn trained_setup() -> (Runtime, ModelExecutable, Dataset, ModelState) {
    let variant = mlp_variant(1.0, "dse_mlp_s1000", 16, 8);
    let manifest = Manifest::from_variants(vec![variant.clone()]);
    let runtime = Runtime::reference();
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag).unwrap();
    let data = small_dataset();
    let mut state = ModelState::init(&variant, 71);
    {
        let trainer = Trainer::new(&runtime, &exec, &data);
        let cfg = TrainConfig { epochs: 3, seed: 17, ..Default::default() };
        trainer.fit(&mut state, &cfg).unwrap();
    }
    (runtime, exec, data, state)
}

#[test]
fn quantize_search_is_jobs_invariant() {
    let (runtime, exec, data, base) = trained_setup();
    let trainer = Trainer::new(&runtime, &exec, &data);
    let cfg = QuantConfig {
        tolerate_acc_loss: 0.02,
        start: Precision::new(10, 5),
        min_bits: 6,
    };

    let mut state_seq = base.clone();
    let trace_seq =
        quantize_search(&trainer, &mut state_seq, &cfg, &ProbePool::new(1)).unwrap();
    let mut state_par = base.clone();
    let trace_par =
        quantize_search(&trainer, &mut state_par, &cfg, &ProbePool::new(4)).unwrap();

    assert_eq!(trace_seq.precisions, trace_par.precisions);
    assert_eq!(trace_seq.bits_after, trace_par.bits_after);
    assert_eq!(
        trace_seq.base_accuracy.to_bits(),
        trace_par.base_accuracy.to_bits()
    );
    assert_eq!(
        trace_seq.final_accuracy.to_bits(),
        trace_par.final_accuracy.to_bits()
    );
    assert_eq!(state_seq.precisions, state_par.precisions);

    // full probe trace, including accuracy bit patterns
    assert_eq!(trace_seq.probes.len(), trace_par.probes.len());
    for (a, b) in trace_seq.probes.iter().zip(&trace_par.probes) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.layer, b.layer);
        assert_eq!(a.tried, b.tried);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accepted, b.accepted);
    }

    // accepted-probe sets match exactly
    let accepted = |t: &metaml::quant::QuantTrace| -> Vec<(usize, usize, u32, u32)> {
        t.probes
            .iter()
            .filter(|p| p.accepted)
            .map(|p| (p.round, p.layer, p.tried.total_bits, p.tried.int_bits))
            .collect()
    };
    assert_eq!(accepted(&trace_seq), accepted(&trace_par));

    // the search actually shrank something (the test would be vacuous
    // against a search that never accepts)
    assert!(trace_seq.bits_after < trace_seq.bits_before);
}

#[test]
fn eval_cache_never_changes_results() {
    let (runtime, exec, data, mut state) = trained_setup();
    let trainer = Trainer::new(&runtime, &exec, &data);
    for p in state.precisions.iter_mut() {
        *p = Precision::new(9, 4);
    }

    let direct = trainer.evaluate(&state).unwrap();
    let pool = ProbePool::new(2);

    // first time through the pool: fresh evaluation, equal to direct
    let first = pool
        .evaluate_batch(&trainer, &[ProbeRequest::new(0, state.clone())])
        .unwrap();
    assert!(!first[0].cached);
    assert_eq!(first[0].eval.loss.to_bits(), direct.loss.to_bits());
    assert_eq!(first[0].eval.accuracy.to_bits(), direct.accuracy.to_bits());
    assert_eq!(first[0].eval.n, direct.n);

    // second time: served from the cache, bit-identical
    let second = pool
        .evaluate_batch(&trainer, &[ProbeRequest::new(1, state.clone())])
        .unwrap();
    assert!(second[0].cached);
    assert_eq!(second[0].eval.loss.to_bits(), direct.loss.to_bits());
    assert_eq!(second[0].eval.accuracy.to_bits(), direct.accuracy.to_bits());
    assert_eq!(pool.cache().hits(), 1);

    // duplicates inside one batch collapse onto one evaluation
    let mut other = state.clone();
    other.precisions[0] = Precision::new(8, 4);
    let batch = pool
        .evaluate_batch(
            &trainer,
            &[
                ProbeRequest::new(0, other.clone()),
                ProbeRequest::new(1, other.clone()),
            ],
        )
        .unwrap();
    assert!(!batch[0].cached);
    assert!(batch[1].cached);
    assert_eq!(
        batch[0].eval.accuracy.to_bits(),
        batch[1].eval.accuracy.to_bits()
    );
}

#[test]
fn autoprune_is_jobs_invariant() {
    let (runtime, exec, data, base) = trained_setup();
    let trainer = Trainer::new(&runtime, &exec, &data);
    let cfg = AutopruneConfig {
        tolerate_acc_loss: 0.05,
        rate_threshold: 0.1, // 4 binary-search steps keeps the test fast
        train_epochs: 1,
        seed: 23,
    };

    let mut state_seq = base.clone();
    let trace_seq =
        autoprune(&trainer, &mut state_seq, &cfg, &ProbePool::new(1)).unwrap();
    let mut state_par = base.clone();
    let trace_par =
        autoprune(&trainer, &mut state_par, &cfg, &ProbePool::new(4)).unwrap();

    assert_eq!(trace_seq.best_rate.to_bits(), trace_par.best_rate.to_bits());
    assert_eq!(
        trace_seq.best_accuracy.to_bits(),
        trace_par.best_accuracy.to_bits()
    );
    assert_eq!(trace_seq.probes.len(), trace_par.probes.len());
    for (a, b) in trace_seq.probes.iter().zip(&trace_par.probes) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.rate.to_bits(), b.rate.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.layer_nnz, b.layer_nnz);
    }
    // the accepted states are bit-identical (params, masks, precisions)
    assert_eq!(state_seq.params, state_par.params);
    assert_eq!(state_seq.masks, state_par.masks);
}

/// One AUTOPRUNE search with intra-probe parallelism actually engaged:
/// 256-row eval batches split into four row panels, and the mul-add
/// floor is dropped to zero so the panel driver runs even on this small
/// model.  Worker lending hands single-probe batches the pool's whole
/// thread budget, and the trace must still be bit-identical between
/// `jobs = 1` and `jobs = 4`.
#[test]
fn autoprune_with_intra_probe_parallelism_is_jobs_invariant() {
    let mut variant = mlp_variant(1.0, "dse_mlp_intra", 16, 8);
    variant.train_batch = 128;
    variant.eval_batch = 256;
    let manifest = Manifest::from_variants(vec![variant.clone()]);
    let runtime = Runtime::reference();
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag).unwrap();
    let data = small_dataset();
    let trainer = Trainer::new(&runtime, &exec, &data);
    let mut base = ModelState::init(&variant, 83);
    trainer
        .fit(&mut base, &TrainConfig { epochs: 2, seed: 19, ..Default::default() })
        .unwrap();

    let cfg = AutopruneConfig {
        tolerate_acc_loss: 0.05,
        rate_threshold: 0.1,
        train_epochs: 1,
        seed: 31,
    };

    set_par_min_flops(0);
    let mut state_seq = base.clone();
    let trace_seq =
        autoprune(&trainer, &mut state_seq, &cfg, &ProbePool::new(1)).unwrap();
    let mut state_par = base.clone();
    let trace_par =
        autoprune(&trainer, &mut state_par, &cfg, &ProbePool::new(4)).unwrap();
    set_par_min_flops(PAR_MIN_FLOPS_DEFAULT);

    assert_eq!(trace_seq.best_rate.to_bits(), trace_par.best_rate.to_bits());
    assert_eq!(
        trace_seq.best_accuracy.to_bits(),
        trace_par.best_accuracy.to_bits()
    );
    assert_eq!(trace_seq.probes.len(), trace_par.probes.len());
    for (a, b) in trace_seq.probes.iter().zip(&trace_par.probes) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.rate.to_bits(), b.rate.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.layer_nnz, b.layer_nnz);
    }
    assert_eq!(state_seq.params, state_par.params);
    assert_eq!(state_seq.masks, state_par.masks);
}

#[test]
fn scale_search_is_jobs_invariant() {
    // a 3-point scale grid so the speculative walk has work to do
    let manifest = Manifest::from_variants(vec![
        mlp_variant(1.0, "dse_mlp_s1000", 16, 8),
        mlp_variant(0.75, "dse_mlp_s0750", 12, 6),
        mlp_variant(0.5, "dse_mlp_s0500", 8, 4),
    ]);
    let session = Session::with_backend(Runtime::reference(), manifest);

    // baseline at full scale
    let (base_state, exec, data) = {
        let variant = session.manifest.variant("dse_mlp", 1.0).unwrap();
        let exec = session.executable(&variant.tag).unwrap();
        let data = session.dataset("dse_mlp").unwrap();
        let mut state = ModelState::init(variant, 29);
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        trainer
            .fit(&mut state, &TrainConfig { epochs: 2, seed: 29, ..Default::default() })
            .unwrap();
        (state, exec, data)
    };
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    let base_acc = trainer.evaluate(&base_state).unwrap().accuracy;

    let cfg = ScaleConfig {
        tolerate_acc_loss: 0.10, // generous: descend at least one point
        train_epochs: 2,
        seed: 29,
        ..Default::default()
    };

    let (trace_seq, state_seq, scale_seq) =
        scale_search(&session, "dse_mlp", 1.0, base_acc, &cfg, &ProbePool::new(1))
            .unwrap();
    let (trace_par, state_par, scale_par) =
        scale_search(&session, "dse_mlp", 1.0, base_acc, &cfg, &ProbePool::new(4))
            .unwrap();

    assert_eq!(scale_seq.to_bits(), scale_par.to_bits());
    assert_eq!(
        trace_seq.best_accuracy.to_bits(),
        trace_par.best_accuracy.to_bits()
    );
    assert_eq!(trace_seq.probes.len(), trace_par.probes.len());
    for (a, b) in trace_seq.probes.iter().zip(&trace_par.probes) {
        assert_eq!(a.trial, b.trial);
        assert_eq!(a.scale.to_bits(), b.scale.to_bits());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.params, b.params);
    }
    assert_eq!(state_seq.params, state_par.params);
    assert_eq!(state_seq.masks, state_par.masks);
}
