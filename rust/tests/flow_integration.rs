//! Full-stack integration tests: real manifest + full flow execution.
//!
//! These exercise the paper's flows end to end (train → optimize → HLS →
//! RTL) against an artifacts directory, on whichever execution backend
//! `METAML_BACKEND` selects (reference interpreter by default; the
//! interpreter only needs `manifest.json`, not the HLO files).  They are
//! skipped gracefully when `make artifacts` has not run (e.g. a fresh
//! checkout without python).

use metaml::config::builtin_flow;
use metaml::flow::{Engine, Session, TaskRegistry};
use metaml::metamodel::{Abstraction, MetaModel};

fn open_session() -> Option<Session> {
    let dir = std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match Session::open(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn pruning_flow_end_to_end() {
    let Some(session) = open_session() else { return };
    let registry = TaskRegistry::builtin();
    let spec = builtin_flow("pruning").unwrap();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", "jet_dnn");

    Engine::new(&session, &registry).run(&spec.graph, &mut meta).unwrap();

    // model space holds DNN → pruned DNN → HLS → RTL with lineage
    assert_eq!(meta.space.len(), 4);
    let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
    let lineage = meta.space.lineage(rtl.id).unwrap();
    assert_eq!(lineage.len(), 4);

    // pruning found a non-trivial rate without tanking accuracy
    let pruned = meta.space.latest(Abstraction::Dnn).unwrap();
    let rate = pruned.metric("pruning_rate").unwrap();
    assert!(rate > 0.3, "rate {rate}");
    let base_acc = meta.space.get(lineage[0]).unwrap().metric("accuracy").unwrap();
    let final_acc = pruned.metric("accuracy").unwrap();
    assert!(base_acc - final_acc <= 0.02 + 1e-9, "{base_acc} -> {final_acc}");

    // resources must have dropped vs an unpruned estimate of same arch
    assert!(rtl.metric("dsp").unwrap() < 3192.0 * 0.7);
    assert!(rtl.metric("fits").unwrap() == 1.0);

    // the HLS artifact carries generated C++ supporting files
    let hls = meta.space.latest(Abstraction::HlsCpp).unwrap();
    assert!(hls.supporting.iter().any(|(f, _)| f == "defines.h"));
    let defines = &hls.supporting.iter().find(|(f, _)| f == "defines.h").unwrap().1;
    assert!(defines.contains("ap_fixed<18,8>"));
}

#[test]
fn quantization_flow_instruments_hls_types() {
    let Some(session) = open_session() else { return };
    let registry = TaskRegistry::builtin();
    let spec = builtin_flow("quantization").unwrap();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", "jet_dnn");
    meta.cfg.set("quantize.tolerate_acc_loss", 0.02);

    Engine::new(&session, &registry).run(&spec.graph, &mut meta).unwrap();

    // the quantized HLS artifact must carry narrower types than 18,8
    let hls = meta.space.latest(Abstraction::HlsCpp).unwrap();
    assert!(hls.name.contains("quantized"));
    let bits = hls.metric("bits_total").unwrap();
    assert!(bits < 4.0 * 18.0, "no reduction: {bits}");
    let defines = &hls.supporting.iter().find(|(f, _)| f == "defines.h").unwrap().1;
    assert!(!defines.is_empty());

    // RTL report synthesized from the quantized model
    let rtl = meta.space.latest(Abstraction::Rtl).unwrap();
    assert!(rtl.metric("lut").unwrap() > 0.0);
}

#[test]
fn combined_flow_beats_baseline_resources() {
    let Some(session) = open_session() else { return };
    let registry = TaskRegistry::builtin();

    let run = |flow: &str| {
        let spec = builtin_flow(flow).unwrap();
        let mut meta = MetaModel::new();
        meta.cfg.set("model", "jet_dnn");
        Engine::new(&session, &registry).run(&spec.graph, &mut meta).unwrap();
        let rtl = meta.space.latest(Abstraction::Rtl).unwrap().clone();
        (
            rtl.metric("accuracy").unwrap(),
            rtl.metric("dsp").unwrap(),
            rtl.metric("lut").unwrap(),
        )
    };

    let (base_acc, base_dsp, base_lut) = run("baseline");
    let (spq_acc, spq_dsp, spq_lut) = run("s_p_q");

    // the paper's headline: large resource reduction at small accuracy cost
    assert!(spq_dsp <= base_dsp * 0.25, "dsp {base_dsp} -> {spq_dsp}");
    assert!(spq_lut <= base_lut * 0.6, "lut {base_lut} -> {spq_lut}");
    assert!(base_acc - spq_acc < 0.06, "acc {base_acc} -> {spq_acc}");
}

#[test]
fn scaling_flow_shrinks_params() {
    let Some(session) = open_session() else { return };
    let registry = TaskRegistry::builtin();
    let spec = builtin_flow("scaling").unwrap();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", "jet_dnn");
    // generous tolerance so the walk descends at least one grid point
    meta.cfg.set("scale.tolerate_acc_loss", 0.02);

    Engine::new(&session, &registry).run(&spec.graph, &mut meta).unwrap();
    let dnn = meta.space.latest(Abstraction::Dnn).unwrap();
    assert!(dnn.metric("scale").unwrap() < 1.0);
}

#[test]
fn run_metrics_land_in_log() {
    let Some(session) = open_session() else { return };
    let registry = TaskRegistry::builtin();
    let spec = builtin_flow("pruning").unwrap();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", "jet_dnn");
    Engine::new(&session, &registry).run(&spec.graph, &mut meta).unwrap();

    // the LOG carries the full probe series (Fig 3 is rendered from it)
    let rates = meta.log.metric_series("prune", "probe_rate");
    assert!(rates.len() >= 6, "probes {rates:?}");
    assert!(rates.windows(2).all(|w| w[0] != w[1]));
    let trace = meta.log.render_trace();
    assert!(trace.contains("auto-pruning"));
}
