//! Budgeted-search subsystem semantics on the synthetic mini jet
//! manifest.
//!
//! Covers: exhaustive-strategy equivalence with the legacy explorer
//! (labels, metrics, front — bit-for-bit), seeded reproducibility
//! goldens (same seed + budget → identical candidate sequence and
//! front), jobs=1 vs jobs=4 LOG/trace identity for `RandomSample` and
//! `Evolve`, numeric range dimensions flowing into variant CFGs, and
//! the headline budget claim: `Evolve` under a budget of *half* the
//! grid recovers the full-grid Pareto front while issuing strictly
//! fewer training probes than `Exhaustive`.
//!
//! The half-budget golden is constructed to be provable, not lucky:
//! the grid crosses `prune.tolerate_acc_loss` with `hls.clock_period`
//! ∈ {5, 10}.  The synthesis estimator's resources and cycle counts
//! are clock-independent and `latency_ns = cycles × period`, so every
//! 10 ns variant is strictly dominated by its 5 ns twin (equal
//! accuracy/DSP/LUT, double latency) — the true front lives entirely
//! in the 5 ns half.  `Evolve`'s seeding generation ranks the
//! enumerated grid through the hardware prefilter, which sees exactly
//! that dominance and spends the whole budget on the 5 ns points.

use metaml::bench_support::synthetic_jet_mini_manifest;
use metaml::config::FlowSpec;
use metaml::flow::explore::explore;
use metaml::flow::{Session, TaskRegistry};
use metaml::runtime::Runtime;
use metaml::search::{run_search, SearchOutcome, SearchSpec};

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

/// One order × (clock 5|10 ns) × (pruning tolerance 0.02|0.05): a
/// 4-point grid whose front is provably inside the clock=5 half.
fn search_spec_json(search: &str) -> String {
    format!(
        r#"{{
  "name": "mini_search",
  "cfg": {{
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7
  }},
  "tasks": [
    {{"id": "gen", "type": "KERAS-MODEL-GEN"}},
    {{"id": "prune", "type": "PRUNING"}},
    {{"id": "hls", "type": "HLS4ML"}},
    {{"id": "quantize", "type": "QUANTIZATION"}},
    {{"id": "synth", "type": "VIVADO-HLS"}}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "synth"]],
  "explore": {{
    "cfg_grid": {{
      "hls.clock_period": [5, 10],
      "prune.tolerate_acc_loss": [0.02, 0.05]
    }}
  }}{search}
}}"#
    )
}

fn grid_spec() -> FlowSpec {
    FlowSpec::parse(&search_spec_json("")).unwrap()
}

fn run(spec: &FlowSpec, search: &SearchSpec, jobs: usize) -> SearchOutcome {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    run_search(&session, &registry, spec, search, &[], jobs).unwrap()
}

fn labels(out: &SearchOutcome) -> Vec<String> {
    out.outcome.results.iter().map(|r| r.label.clone()).collect()
}

fn front_labels(out: &SearchOutcome) -> Vec<String> {
    let mut v: Vec<String> = out
        .outcome
        .front
        .iter()
        .map(|&i| out.outcome.results[i].label.clone())
        .collect();
    v.sort();
    v
}

#[test]
fn exhaustive_strategy_matches_legacy_explorer() {
    let spec = grid_spec();
    let out = run(&spec, &SearchSpec::default(), 2);
    assert_eq!(out.strategy, "exhaustive");
    assert_eq!(out.grid_size, 4);
    assert_eq!(out.spent, 4);
    assert_eq!(out.evaluations(), 4);

    let session = mini_session();
    let registry = TaskRegistry::builtin();
    let legacy = explore(&session, &registry, &spec, &[], 2).unwrap();
    assert_eq!(legacy.results.len(), 4);
    assert_eq!(out.outcome.front, legacy.front);
    for (a, b) in out.outcome.results.iter().zip(&legacy.results) {
        assert_eq!(a.label, b.label);
        for (k, v) in &a.metrics {
            let w = b.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", a.label);
        }
        assert_eq!(a.events, b.events, "{}", a.label);
        // the variant's grid point is echoed on the result
        assert_eq!(a.cfg.len(), 2, "{}", a.label);
    }
}

#[test]
fn random_sample_is_seeded_reproducible_and_jobs_invariant() {
    // a numeric range dimension only samplers can draw from
    let spec = FlowSpec::parse(&search_spec_json(
        r#",
  "search": {"strategy": "random", "budget": 2, "seed": 5,
             "range": {"quantize.tolerate_acc_loss": {"min": 0.01, "max": 0.05}}}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();

    let a = run(&spec, &search, 1);
    let b = run(&spec, &search, 1);
    let c = run(&spec, &search, 4);

    // same seed + budget → identical candidate sequence and front,
    // whatever the worker count
    assert_eq!(labels(&a), labels(&b));
    assert_eq!(labels(&a), labels(&c));
    assert!(!labels(&a).is_empty());
    assert_eq!(a.outcome.front, b.outcome.front);
    assert_eq!(a.outcome.front, c.outcome.front);
    for (x, y) in a.outcome.results.iter().zip(&c.outcome.results) {
        assert_eq!(x.events, y.events, "{}", x.label);
        for (k, v) in &x.metrics {
            let w = y.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", x.label);
        }
    }

    // the sampled range value reached the variant's CFG and label
    for r in &a.outcome.results {
        let (_, v) = r
            .cfg
            .iter()
            .find(|(k, _)| k == "quantize.tolerate_acc_loss")
            .expect("range dim in variant cfg");
        let v = v.as_f64().unwrap();
        assert!((0.01..=0.05).contains(&v), "{v}");
        assert!(r.label.contains("quantize.tolerate_acc_loss="), "{}", r.label);
    }

    // a different seed explores a different trajectory (the 2 draws
    // over a continuous dimension colliding would be astronomical)
    let other = run(&spec, &SearchSpec { seed: 6, ..search }, 1);
    assert_ne!(labels(&a), labels(&other));
}

#[test]
fn evolve_half_budget_recovers_full_grid_front_with_fewer_probes() {
    let spec = FlowSpec::parse(&search_spec_json(
        r#",
  "search": {"strategy": "evolve", "budget": 2, "seed": 9, "prefilter": true}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();

    let full = run(&spec, &SearchSpec::default(), 1);
    assert_eq!(full.evaluations(), 4);

    let evolved = run(&spec, &search, 1);
    assert_eq!(evolved.strategy, "evolve");
    // budget 2 = 50% of the grid, spent on unique evaluations
    assert_eq!(evolved.spent, 2);
    assert_eq!(evolved.evaluations(), 2);
    assert!(evolved.evaluations() < full.evaluations());

    // the full-grid Pareto front is recovered exactly
    let expected = front_labels(&full);
    assert!(!expected.is_empty());
    assert_eq!(front_labels(&evolved), expected);
    // every front member lives in the clock=5 half (the 10 ns twins
    // are dominated by construction)
    for l in &expected {
        assert!(l.contains("hls.clock_period=5"), "{l}");
    }

    // strictly fewer training probes than the exhaustive sweep, and
    // some hardware probes spent by the prefilter instead
    assert!(
        evolved.probes.train_issued < full.probes.train_issued,
        "evolve {} !< exhaustive {}",
        evolved.probes.train_issued,
        full.probes.train_issued
    );
    assert!(evolved.probes.train_issued > 0);
    assert!(evolved.probes.hw_issued > 0, "prefilter estimated candidates");

    // seeded-reproducibility golden: identical candidate sequence,
    // front and LOGs for the same seed, at any worker count
    let again = run(&spec, &search, 1);
    let par = run(&spec, &search, 4);
    for other in [&again, &par] {
        assert_eq!(labels(&evolved), labels(other));
        assert_eq!(evolved.outcome.front, other.outcome.front);
        for (x, y) in evolved.outcome.results.iter().zip(&other.outcome.results) {
            assert_eq!(x.events, y.events, "{}", x.label);
        }
    }
}

#[test]
fn evolve_with_full_budget_covers_the_whole_grid() {
    // the dry-evolution fallback sweeps unevaluated grid points, so a
    // budget equal to the grid size degenerates to exhaustive coverage
    let spec = FlowSpec::parse(&search_spec_json(
        r#",
  "search": {"strategy": "evolve", "budget": 4, "seed": 3, "population": 2}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();
    let out = run(&spec, &search, 2);
    assert_eq!(out.evaluations(), 4, "spent {} of {}", out.spent, out.budget);

    let full = run(&spec, &SearchSpec::default(), 2);
    assert_eq!(front_labels(&out), front_labels(&full));
    let mut seen = labels(&out);
    let mut all = labels(&full);
    seen.sort();
    all.sort();
    assert_eq!(seen, all);
}
