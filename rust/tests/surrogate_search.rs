//! Surrogate-guided search semantics on the synthetic mini jet
//! manifest.
//!
//! Covers the `search.surrogate` evaluation policy end to end:
//!
//! - the ridge model recovers an exactly-linear objective through the
//!   public `Surrogate` API (encode → fit → predict);
//! - jobs=1 vs jobs=4 produce bit-identical candidate sequences, LOG
//!   streams, fronts **and** surrogate accounting (the determinism
//!   contract holds with the predictor in the loop);
//! - the headline golden: surrogate-guided `Evolve` recovers the
//!   full-grid Pareto front (same labels, same hypervolume) while
//!   issuing at most **half** the training probes of a prefilter-only
//!   `Evolve` at the same budget;
//! - a deliberately mispredictive space (convex reuse-factor resource
//!   curve vs a linear model) still converges to the exhaustive front
//!   within the budget — the trust radius + final re-validation
//!   degrade gracefully instead of reporting a predicted front.
//!
//! The golden is constructed to be provable, not lucky: on a
//! clock-period-only grid every non-hardware objective is *constant*,
//! so its fitted weight is exactly zero and predictions equal the
//! truth bit-for-bit, while `latency_ns = cycles × period` is exactly
//! linear in the one varying dimension.  After a two-point warmup the
//! model is exact; every other clock is predicted dominated by the
//! fastest one and deferred, and the final re-validation confirms the
//! deferrals instead of running them.

use std::sync::Arc;

use metaml::bench_support::synthetic_jet_mini_manifest;
use metaml::config::FlowSpec;
use metaml::dse::ProbeStats;
use metaml::flow::{Session, TaskRegistry};
use metaml::json::Value;
use metaml::runtime::Runtime;
use metaml::search::pareto::hypervolume;
use metaml::search::{
    run_search, Candidate, SearchOutcome, SearchSpace, SearchSpec, Surrogate, SurrogateSpec,
};

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

/// The 5-task mini flow with a parameterized discrete grid and search
/// section (same flow as the `search_strategies` suite).
fn spec_json(cfg_grid: &str, search: &str) -> String {
    format!(
        r#"{{
  "name": "mini_surrogate",
  "cfg": {{
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7
  }},
  "tasks": [
    {{"id": "gen", "type": "KERAS-MODEL-GEN"}},
    {{"id": "prune", "type": "PRUNING"}},
    {{"id": "hls", "type": "HLS4ML"}},
    {{"id": "quantize", "type": "QUANTIZATION"}},
    {{"id": "synth", "type": "VIVADO-HLS"}}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "synth"]],
  "explore": {{
    "cfg_grid": {{{cfg_grid}}}
  }}{search}
}}"#
    )
}

fn run(spec: &FlowSpec, search: &SearchSpec, jobs: usize) -> SearchOutcome {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    run_search(&session, &registry, spec, search, &[], jobs).unwrap()
}

fn labels(out: &SearchOutcome) -> Vec<String> {
    out.outcome.results.iter().map(|r| r.label.clone()).collect()
}

fn front_labels(out: &SearchOutcome) -> Vec<String> {
    let mut v: Vec<String> = out
        .outcome
        .front
        .iter()
        .map(|&i| out.outcome.results[i].label.clone())
        .collect();
    v.sort();
    v
}

fn front_points(out: &SearchOutcome) -> Vec<Vec<f64>> {
    out.outcome
        .front
        .iter()
        .map(|&i| out.outcome.results[i].min_objectives().unwrap())
        .collect()
}

/// Hypervolume of a front against a reference dominated by every point
/// of both fronts (componentwise max + 1).
fn shared_hv(a: &[Vec<f64>], b: &[Vec<f64>]) -> (f64, f64) {
    let m = a[0].len();
    let mut reference = vec![f64::NEG_INFINITY; m];
    for p in a.iter().chain(b) {
        for (r, &v) in reference.iter_mut().zip(p) {
            *r = r.max(v);
        }
    }
    for r in &mut reference {
        *r += 1.0;
    }
    (hypervolume(a, &reference), hypervolume(b, &reference))
}

#[test]
fn surrogate_recovers_linear_objectives_through_the_public_api() {
    // y0 = 3 + 2a − b, y1 = 10 − a on a two-dimensional numeric grid
    let space = SearchSpace {
        orders: vec![None],
        grid: vec![
            ("a".to_string(), (0..4).map(|v| Value::Number(v as f64)).collect()),
            (
                "b".to_string(),
                vec![Value::Number(0.0), Value::Number(5.0), Value::Number(10.0)],
            ),
        ],
        ranges: Vec::new(),
    };
    let spec = SurrogateSpec { warmup: Some(1), ridge: 1e-9, ..Default::default() };
    let mut sur = Surrogate::new(&space, &spec, Arc::new(ProbeStats::default()));
    let cand = |a: usize, b: usize| Candidate { order: 0, grid: vec![a, b], range: Vec::new() };
    for (a, b) in [(0usize, 0usize), (1, 1), (2, 2), (3, 0), (0, 2), (2, 1)] {
        let (av, bv) = (a as f64, [0.0, 5.0, 10.0][b]);
        sur.observe_truth(&cand(a, b), &[3.0 + 2.0 * av - bv, 10.0 - av]);
    }
    sur.finish_warmup();
    sur.fit_if_dirty();
    assert!(sur.ready());
    for (a, b) in [(1usize, 0usize), (3, 2), (1, 2), (3, 1)] {
        let (av, bv) = (a as f64, [0.0, 5.0, 10.0][b]);
        let p = sur.predict(&cand(a, b));
        assert!((p[0] - (3.0 + 2.0 * av - bv)).abs() < 1e-5, "y0 at ({a},{b}): {p:?}");
        assert!((p[1] - (10.0 - av)).abs() < 1e-5, "y1 at ({a},{b}): {p:?}");
    }
    let rep = sur.report();
    assert_eq!(rep.fits, 1);
    assert_eq!(rep.predictions, 4);
    assert_eq!(rep.probes_saved(), 0);
}

#[test]
fn surrogate_evolve_matches_exhaustive_front_with_half_the_probes() {
    // Clock-period-only grid: accuracy/DSP/LUT are constant across the
    // grid (the estimator's resources and cycle counts are
    // clock-independent) and latency is exactly linear in the period,
    // so after the 2-point warmup the model is exact and every clock
    // above the fastest is provably dominated.
    let grid = r#"
      "hls.clock_period": [4, 5, 6, 8, 10, 12]"#;
    let spec = FlowSpec::parse(&spec_json(
        grid,
        r#",
  "search": {"strategy": "evolve", "budget": 6, "seed": 9,
             "surrogate": {"warmup": 2, "every": 5}}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();

    let full = run(&spec, &SearchSpec::default(), 1);
    assert_eq!(full.evaluations(), 6);

    // probe baseline: prefilter-only Evolve at the same budget runs
    // every proposal as a real flow
    let base = run(
        &spec,
        &SearchSpec {
            strategy: "evolve".into(),
            budget: Some(6),
            seed: 9,
            prefilter: true,
            ..Default::default()
        },
        1,
    );
    assert_eq!(base.evaluations(), 6);

    let sur = run(&spec, &search, 1);
    assert_eq!(sur.strategy, "evolve");
    assert_eq!(sur.grid_size, 6);
    assert_eq!(sur.budget, 6);
    assert_eq!(sur.spent, 6);
    // only the warmup pair (4 ns and 8 ns) ran as real flows; the rest
    // of the grid was answered by prediction and never validated
    assert_eq!(sur.evaluations(), 2, "evaluated {:?}", labels(&sur));
    let report = sur.surrogate.clone().expect("surrogate accounting");
    assert_eq!(report.deferred, 4);
    assert_eq!(report.validated, 0);
    assert_eq!(report.probes_saved(), 4);
    assert!(report.fits >= 1);
    assert!(report.predictions > 0);
    // the shared probe counters surface the same story
    assert!(sur.probes.sur_fits >= 1);
    assert!(sur.probes.sur_predictions > 0);
    assert_eq!(base.probes.sur_predictions, 0);

    // same front as the exhaustive sweep, label for label, and equal
    // hypervolume from a shared reference point
    let expected = front_labels(&full);
    assert!(!expected.is_empty());
    assert_eq!(front_labels(&sur), expected);
    for l in &expected {
        assert!(l.contains("hls.clock_period=4"), "{l}");
    }
    let (hv_sur, hv_full) = shared_hv(&front_points(&sur), &front_points(&full));
    assert!(hv_full > 0.0);
    assert!((hv_sur - hv_full).abs() < 1e-9, "{hv_sur} vs {hv_full}");

    // the acceptance claim: >= 2x fewer training probes than the
    // prefilter-only baseline at the same budget
    assert!(sur.probes.train_issued > 0);
    assert!(
        2 * sur.probes.train_issued <= base.probes.train_issued,
        "surrogate {} !<= half of prefilter baseline {}",
        sur.probes.train_issued,
        base.probes.train_issued
    );
}

#[test]
fn surrogate_search_is_jobs_invariant_and_seeded() {
    // a 2-D grid where the surrogate defers the dominated slow-clock
    // half and the band/validation machinery all runs
    let grid = r#"
      "hls.clock_period": [5, 10, 15],
      "prune.tolerate_acc_loss": [0.02, 0.05]"#;
    let spec = FlowSpec::parse(&spec_json(
        grid,
        r#",
  "search": {"strategy": "evolve", "budget": 6, "seed": 9,
             "surrogate": {"warmup": 3, "every": 2}}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();

    let a = run(&spec, &search, 1);
    let b = run(&spec, &search, 1);
    let c = run(&spec, &search, 4);

    // same seed + budget -> identical candidate sequence, front, LOG
    // streams and surrogate accounting, whatever the worker count
    for other in [&b, &c] {
        assert_eq!(labels(&a), labels(other));
        assert_eq!(a.outcome.front, other.outcome.front);
        assert_eq!(a.spent, other.spent);
        assert_eq!(a.surrogate, other.surrogate);
        for (x, y) in a.outcome.results.iter().zip(&other.outcome.results) {
            assert_eq!(x.events, y.events, "{}", x.label);
            for (k, v) in &x.metrics {
                let w = y.metrics.get(k).copied().unwrap_or(f64::NAN);
                assert_eq!(v.to_bits(), w.to_bits(), "{}: {k}", x.label);
            }
        }
    }
    let report = a.surrogate.clone().expect("surrogate accounting");
    assert!(report.fits >= 1);
    assert!(report.predictions > 0);
    assert!(report.deferred >= 1, "{report:?}");

    // every point the surrogate skipped was genuinely dominated: the
    // front still matches the exhaustive sweep and lives in the 5 ns
    // slice
    let full = run(&spec, &SearchSpec::default(), 2);
    let expected = front_labels(&full);
    assert!(!expected.is_empty());
    assert_eq!(front_labels(&a), expected);
    for l in &expected {
        assert!(l.contains("hls.clock_period=5"), "{l}");
    }
}

#[test]
fn mispredictive_space_still_converges_to_the_exhaustive_front() {
    // DSP/LUT fall convexly in the reuse factor (~1/RF) while the
    // model is linear, so warmup-era predictions are badly wrong.  The
    // error feedback widens the trust radius and the final
    // re-validation truth-evaluates every surviving deferral: the
    // front must equal the exhaustive one, never a predicted artifact.
    let grid = r#"
      "hls.reuse_factor": [1, 4, 16],
      "hls.clock_period": [5, 10]"#;
    let spec = FlowSpec::parse(&spec_json(
        grid,
        r#",
  "search": {"strategy": "evolve", "budget": 6, "seed": 3,
             "surrogate": {"warmup": 2, "margin": 0.05, "threshold": 0.05,
                           "every": 1}}"#,
    ))
    .unwrap();
    let search = spec.search.clone().unwrap();

    let full = run(&spec, &SearchSpec::default(), 2);
    assert_eq!(full.evaluations(), 6);
    let expected = front_labels(&full);
    assert!(!expected.is_empty());

    let sur = run(&spec, &search, 2);
    assert!(sur.evaluations() <= 6);
    assert_eq!(front_labels(&sur), expected, "evaluated {:?}", labels(&sur));
    let (hv_sur, hv_full) = shared_hv(&front_points(&sur), &front_points(&full));
    assert!((hv_sur - hv_full).abs() < 1e-9, "{hv_sur} vs {hv_full}");

    let report = sur.surrogate.clone().expect("surrogate accounting");
    assert!(report.validated <= report.deferred);
    if report.validated > 0 {
        // validated deferrals feed the error accumulator
        assert_eq!(report.mean_abs_error.len(), 4);
    }
}
