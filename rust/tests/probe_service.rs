//! Probe-service + persistent-cache semantics on the synthetic mini
//! jet manifest.
//!
//! Pins the PR's headline contract: for a fixed (spec, strategy, seed,
//! budget), per-variant LOGs and the front are bit-identical across
//! cold-cache, warm-cache and `--jobs` {1, 4} runs — and the warm run
//! issues **zero** fresh training-probe computations ([`ProbeCounts`]
//! asserts it).  Also covers the disk store surviving corruption at
//! the integration level (a damaged store degrades to recomputation,
//! never to an error or a changed trace).

use std::path::PathBuf;
use std::sync::Arc;

use metaml::bench_support::synthetic_jet_mini_manifest;
use metaml::config::FlowSpec;
use metaml::dse::{DiskStore, ProbeTiers};
use metaml::flow::{Session, TaskRegistry};
use metaml::runtime::Runtime;
use metaml::search::{run_search_tiered, SearchOutcome, SearchSpec};

fn mini_session() -> Session {
    Session::with_backend(Runtime::reference(), synthetic_jet_mini_manifest())
}

/// One order × (clock 5|10 ns) × (pruning tolerance 0.02|0.05) — the
/// same provable 4-point grid the search-strategy tests use, with a
/// QUANTIZATION task so the flow issues training probes and a
/// REUSE_SEARCH task so it issues hardware probes through the service.
fn grid_spec() -> FlowSpec {
    FlowSpec::parse(
        r#"{
  "name": "mini_cache",
  "cfg": {
    "model": "jet_mini",
    "gen.train_epochs": 1,
    "prune.train_epochs": 1,
    "prune.pruning_rate_thresh": 0.25,
    "quantize.start_precision": "ap_fixed<8,4>",
    "quantize.min_bits": 7,
    "reuse.latency_budget_ns": 400.0
  },
  "tasks": [
    {"id": "gen", "type": "KERAS-MODEL-GEN"},
    {"id": "prune", "type": "PRUNING"},
    {"id": "hls", "type": "HLS4ML"},
    {"id": "quantize", "type": "QUANTIZATION"},
    {"id": "reuse", "type": "REUSE_SEARCH"},
    {"id": "synth", "type": "VIVADO-HLS"}
  ],
  "edges": [["gen", "prune"], ["prune", "hls"], ["hls", "quantize"],
             ["quantize", "reuse"], ["reuse", "synth"]],
  "explore": {
    "cfg_grid": {
      "hls.clock_period": [5, 10],
      "prune.tolerate_acc_loss": [0.02, 0.05]
    }
  }
}"#,
    )
    .unwrap()
}

fn tmpdir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("metaml_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run the full exhaustive search against `tiers` (a fresh in-memory
/// bundle per call, so only the disk tier carries state across runs).
fn run_with(tiers: &ProbeTiers, jobs: usize) -> SearchOutcome {
    let session = mini_session();
    let registry = TaskRegistry::builtin();
    run_search_tiered(
        &session,
        &registry,
        &grid_spec(),
        &SearchSpec::default(),
        &[],
        jobs,
        tiers,
    )
    .unwrap()
}

/// Bit-identity over everything user-visible: labels, front, every
/// metric's bit pattern, every LOG event stream.
fn assert_bit_identical(a: &SearchOutcome, b: &SearchOutcome, what: &str) {
    assert_eq!(a.outcome.front, b.outcome.front, "{what}: front");
    assert_eq!(a.outcome.results.len(), b.outcome.results.len(), "{what}");
    for (x, y) in a.outcome.results.iter().zip(&b.outcome.results) {
        assert_eq!(x.label, y.label, "{what}");
        assert_eq!(x.events, y.events, "{what}: {} LOG", x.label);
        for (k, v) in &x.metrics {
            let w = y.metrics.get(k).copied().unwrap_or(f64::NAN);
            assert_eq!(v.to_bits(), w.to_bits(), "{what}: {} {k}", x.label);
        }
    }
}

#[test]
fn warm_cache_issues_zero_fresh_training_probes_and_keeps_traces() {
    let dir = tmpdir("probe_service_warm");

    // baseline: no disk tier at all
    let baseline = run_with(&ProbeTiers::new(), 1);
    assert!(baseline.probes.train_issued > 0, "flow must issue training probes");
    assert!(baseline.probes.train_computed > 0);
    assert!(baseline.probes.hw_issued > 0);

    // cold run: attaches an empty store, computes everything, persists
    let cold_tiers = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let cold = run_with(&cold_tiers, 1);
    assert_bit_identical(&baseline, &cold, "cold vs no-cache");
    let stats_after_cold = DiskStore::inspect(&dir);
    assert!(stats_after_cold.train_entries > 0, "training probes persisted");
    assert!(stats_after_cold.hw_entries > 0, "hardware probes persisted");
    assert_eq!(stats_after_cold.skipped, 0);

    // warm runs: fresh in-memory tiers + a fresh open of the same
    // store, i.e. a second process — at both worker counts
    for jobs in [1usize, 4] {
        let warm_tiers =
            ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
        let warm = run_with(&warm_tiers, jobs);
        assert_bit_identical(&cold, &warm, "warm vs cold");

        // the headline: zero fresh probe computations of either kind
        assert_eq!(
            warm.probes.train_computed, 0,
            "warm run (jobs {jobs}) recomputed training probes"
        );
        assert_eq!(
            warm.probes.hw_computed, 0,
            "warm run (jobs {jobs}) recomputed hardware probes"
        );
        assert_eq!(warm.probes.train_issued, cold.probes.train_issued);
    }

    // warm runs never append: the store is byte-stable once saturated
    let stats_after_warm = DiskStore::inspect(&dir);
    assert_eq!(stats_after_cold, stats_after_warm);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn jobs_invariance_holds_through_the_disk_tier() {
    let dir = tmpdir("probe_service_jobs");

    let t1 = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let cold_seq = run_with(&t1, 1);

    // a *different* store directory filled by a parallel run must
    // produce the same traces (parallelism changes wall-clock only)
    let dir4 = tmpdir("probe_service_jobs4");
    let t4 = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir4).unwrap()));
    let cold_par = run_with(&t4, 4);
    assert_bit_identical(&cold_seq, &cold_par, "jobs 1 vs 4 (cold)");

    // and the stores they left behind hold the same number of entries
    let s1 = DiskStore::inspect(&dir);
    let s4 = DiskStore::inspect(&dir4);
    assert_eq!(s1.train_entries, s4.train_entries);
    assert_eq!(s1.hw_entries, s4.hw_entries);

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir4);
}

#[test]
fn damaged_store_degrades_to_recomputation_not_error() {
    let dir = tmpdir("probe_service_damaged");

    let cold_tiers = ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let cold = run_with(&cold_tiers, 2);

    // vandalize the store: keep the first half of the file, then tack
    // on garbage (a torn write from a killed process)
    let path = dir.join("probes.jsonl");
    let bytes = std::fs::read(&path).unwrap();
    let mut torn = bytes[..bytes.len() / 2].to_vec();
    torn.extend_from_slice(b"\x00\xff not a record\nv1 train zz{\n");
    std::fs::write(&path, torn).unwrap();

    let damaged = DiskStore::open(&dir).unwrap();
    assert!(damaged.stats().skipped > 0, "damage was detected and skipped");

    // the run over the damaged store still succeeds with identical
    // traces — missing entries are recomputed (and persisted again)
    let warm = run_with(&ProbeTiers::with_disk(Arc::new(damaged)), 2);
    assert_bit_identical(&cold, &warm, "damaged-store run");
    assert!(
        warm.probes.train_computed + warm.probes.hw_computed > 0,
        "lost entries were recomputed"
    );

    // ... and a third run over the repaired store is fully warm again
    let healed_tiers =
        ProbeTiers::with_disk(Arc::new(DiskStore::open(&dir).unwrap()));
    let healed = run_with(&healed_tiers, 2);
    assert_bit_identical(&cold, &healed, "healed-store run");
    assert_eq!(healed.probes.train_computed, 0);
    assert_eq!(healed.probes.hw_computed, 0);

    let _ = std::fs::remove_dir_all(&dir);
}
