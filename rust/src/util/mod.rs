//! Small shared utilities: deterministic PRNG, stats helpers, timing.

pub mod prng;
pub mod stats;

pub use prng::Prng;
