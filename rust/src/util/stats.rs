//! Tiny statistics helpers used by reports and benches.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) over an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
