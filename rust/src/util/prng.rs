//! Deterministic PRNG (splitmix64 + xoshiro256**) — no external rand crate.
//!
//! Everything stochastic in the rust layer (param init, dataset synthesis,
//! batch shuffling) flows through this so runs are exactly reproducible
//! from a seed recorded in the metamodel LOG.

#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-task/per-layer seeding).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Glorot/Xavier-normal weight init for a tensor with given fan-in/out.
    pub fn glorot(&mut self, fan_in: usize, fan_out: usize, n: usize) -> Vec<f32> {
        let std = (2.0 / (fan_in + fan_out) as f64).sqrt();
        (0..n).map(|_| (self.normal() * std) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut p = Prng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut p = Prng::new(19);
        let mut a = p.fork(1);
        let mut b = p.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
