//! Whole-design synthesis estimation: HLS IR → resource/latency/power report.

use crate::error::{Error, Result};
use crate::hls::ir::{HlsLayerKind, HlsModel};
use crate::synth::cost;
use crate::synth::device::FpgaDevice;

/// Per-layer usage breakdown.
#[derive(Debug, Clone)]
pub struct LayerUsage {
    pub name: String,
    pub dsp: f64,
    pub lut: f64,
    pub ff: f64,
    pub bram_18k: f64,
    pub cycles: usize,
}

/// The "RTL model": what the VIVADO-HLS λ-task stores in the model space.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub design: String,
    pub device: FpgaDevice,
    pub clock_mhz: f64,
    pub layers: Vec<LayerUsage>,
    pub dsp: usize,
    pub lut: usize,
    pub ff: usize,
    pub bram_18k: usize,
    pub latency_cycles: usize,
    pub latency_ns: f64,
    pub dynamic_power_w: f64,
    /// Initiation interval (II=1 pipeline at RF=1).
    pub ii: usize,
}

impl SynthReport {
    pub fn dsp_pct(&self) -> f64 {
        100.0 * self.dsp as f64 / self.device.dsp as f64
    }

    pub fn lut_pct(&self) -> f64 {
        100.0 * self.lut as f64 / self.device.lut as f64
    }

    pub fn ff_pct(&self) -> f64 {
        100.0 * self.ff as f64 / self.device.ff as f64
    }

    pub fn bram_pct(&self) -> f64 {
        100.0 * self.bram_18k as f64 / self.device.bram_18k as f64
    }

    /// Does the design fit the device?
    pub fn fits(&self) -> bool {
        self.dsp <= self.device.dsp
            && self.lut <= self.device.lut
            && self.ff <= self.device.ff
            && self.bram_18k <= self.device.bram_18k
    }
}

/// Estimate a full HLS design on a device.
///
/// The hardware configuration is validated first: a reuse factor of 0
/// (or one that does not divide the layer fan-in) is an
/// [`Error::Synth`], never a silent division artifact — an IR built
/// directly (bypassing the snapping transforms) cannot reach the
/// per-layer divisions below with an illegal RF.
pub fn estimate(model: &HlsModel, device: &FpgaDevice, clock_mhz: f64) -> Result<SynthReport> {
    if clock_mhz <= 0.0 {
        return Err(Error::Synth(format!("bad clock {clock_mhz} MHz")));
    }
    model.validate()?;
    let stream = model.io_type == crate::hls::ir::IoType::Stream;
    let mut layers = Vec::new();
    let (mut dsp, mut lut, mut ff, mut bram) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut cycles = 0usize;

    for l in &model.layers {
        match l.kind {
            HlsLayerKind::Dense | HlsLayerKind::Conv2D => {
                let fan_in = l.fan_in();
                let rf = l.reuse_factor;
                let bits = cost::effective_bits(l.precision);
                // reuse factor time-multiplexes the MAC array
                let mults = (l.multipliers() as f64 / rf as f64).ceil();
                let l_dsp = mults * cost::dsp_per_mult(l.precision);
                let mut l_lut = mults * cost::lut_per_mult(l.precision);
                let n_adds = (l.multipliers()).saturating_sub(l.n_out);
                let acc_bits = cost::acc_bits(l.precision, fan_in);
                l_lut += cost::lut_adder_tree(
                    (n_adds as f64 / rf as f64).ceil() as usize,
                    acc_bits,
                );
                // outputs whose weights were all pruned away need no
                // accumulator, hence the cap at nnz
                l_lut += cost::lut_partial_sum(l.n_out.min(l.nnz), acc_bits, rf);
                let l_ff = cost::ff_estimate(l_lut, l_dsp);
                // conv line buffers: (kernel-1) rows of (width*channels)
                let mut l_bram = if l.kind == HlsLayerKind::Conv2D {
                    let bits_per_row = l.w * l.n_in * bits as usize;
                    ((l.kernel.saturating_sub(1) * bits_per_row) as f64 / 18_432.0).ceil()
                } else {
                    0.0
                };
                // RF > 1 streams weights from block RAM instead of
                // baking them into the fabric
                l_bram += cost::bram_weights(l.nnz, bits, rf);
                // io_stream inserts a dataflow FIFO on the layer's
                // output edge (io_parallel wires layers directly)
                if stream {
                    let words = if l.kind == HlsLayerKind::Conv2D {
                        l.h * l.w * l.n_out
                    } else {
                        l.n_out
                    };
                    l_bram += cost::bram_stream_fifo(words, bits);
                }
                let l_cycles = cost::layer_cycles(
                    l.precision,
                    fan_in,
                    l.density(),
                    l.spatial_iters(),
                    rf,
                );
                layers.push(LayerUsage {
                    name: l.name.clone(),
                    dsp: l_dsp,
                    lut: l_lut,
                    ff: l_ff,
                    bram_18k: l_bram,
                    cycles: l_cycles,
                });
                dsp += l_dsp;
                lut += l_lut;
                ff += l_ff;
                bram += l_bram;
                cycles += l_cycles;
            }
            HlsLayerKind::MaxPool2 => {
                // comparators: ~1 LUT per bit per output element
                cycles += 1;
                lut += 64.0;
            }
            HlsLayerKind::ResidualAdd => {
                cycles += 1;
                lut += 128.0;
            }
            HlsLayerKind::Flatten => {}
        }
    }
    cycles += cost::SOFTMAX_CYCLES;

    let latency_ns = cycles as f64 * 1000.0 / clock_mhz;
    let power = cost::power_w(dsp, lut, clock_mhz);
    Ok(SynthReport {
        design: model.name.clone(),
        device: *device,
        clock_mhz,
        layers,
        dsp: dsp.round() as usize,
        lut: lut.round() as usize,
        ff: ff.round() as usize,
        bram_18k: bram.round() as usize,
        latency_cycles: cycles,
        latency_ns,
        dynamic_power_w: power,
        // the pipelined MAC loops re-issue every RF cycles (II = RF at
        // the deepest layer; II = 1 when fully unrolled)
        ii: model.max_reuse_factor(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ir::tests::toy_model;
    use crate::hls::transform::{HlsTransform, SetPrecision};
    use crate::model::state::Precision;

    fn vu9p() -> &'static FpgaDevice {
        FpgaDevice::by_name("vu9p").unwrap()
    }

    #[test]
    fn basic_report_fields() {
        let r = estimate(&toy_model(), vu9p(), 200.0).unwrap();
        assert!(r.dsp > 0 && r.lut > 0 && r.ff > 0);
        assert!(r.latency_cycles > 2);
        assert!((r.latency_ns - r.latency_cycles as f64 * 5.0).abs() < 1e-9);
        assert!(r.fits());
        assert!(r.dsp_pct() > 0.0 && r.dsp_pct() < 100.0);
    }

    #[test]
    fn pruning_reduces_everything() {
        let m = toy_model();
        let full = estimate(&m, vu9p(), 200.0).unwrap();
        let mut pruned = m.clone();
        for l in pruned.layers.iter_mut() {
            l.nnz = l.total_weights / 10;
        }
        let r = estimate(&pruned, vu9p(), 200.0).unwrap();
        assert!(r.dsp < full.dsp);
        assert!(r.lut < full.lut);
        assert!(r.latency_cycles <= full.latency_cycles);
    }

    #[test]
    fn quantizing_below_threshold_moves_dsp_to_lut() {
        let mut m = toy_model();
        let before = estimate(&m, vu9p(), 200.0).unwrap();
        SetPrecision::all(Precision::new(8, 3)).apply(&mut m).unwrap();
        let after = estimate(&m, vu9p(), 200.0).unwrap();
        assert_eq!(after.dsp, 0);
        assert!(before.dsp > 0);
        // LUT-fabric multipliers appear
        assert!(after.lut > 0);
    }

    #[test]
    fn clock_scales_latency_ns_not_cycles() {
        let m = toy_model();
        let a = estimate(&m, vu9p(), 200.0).unwrap();
        let b = estimate(&m, vu9p(), 100.0).unwrap();
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert!((b.latency_ns / a.latency_ns - 2.0).abs() < 1e-9);
        assert!(b.dynamic_power_w < a.dynamic_power_w);
    }

    #[test]
    fn rejects_bad_clock() {
        assert!(estimate(&toy_model(), vu9p(), 0.0).is_err());
    }

    #[test]
    fn rejects_zero_reuse_factor_as_synth_error() {
        // an IR built directly (not via the snapping transforms) with
        // RF = 0 must be a clean error, not a division artifact
        let mut m = toy_model();
        m.layers[0].reuse_factor = 0;
        match estimate(&m, vu9p(), 200.0) {
            Err(crate::error::Error::Synth(msg)) => {
                assert!(msg.contains("reuse_factor"), "{msg}")
            }
            other => panic!("expected Error::Synth, got {other:?}"),
        }
        // a non-divisor RF is rejected the same way
        m.layers[0].reuse_factor = 3;
        assert!(estimate(&m, vu9p(), 200.0).is_err());
    }

    #[test]
    fn reuse_trades_resources_for_latency_monotonically() {
        let m = toy_model(); // fan-ins 16 and 64: 1/2/4/8/16 legal everywhere
        let mut prev: Option<SynthReport> = None;
        for rf in [1usize, 2, 4, 8, 16] {
            let mut cand = m.clone();
            for l in cand.layers.iter_mut() {
                l.reuse_factor = rf;
            }
            let r = estimate(&cand, vu9p(), 200.0).unwrap();
            assert_eq!(r.ii, rf);
            if let Some(p) = &prev {
                assert!(r.dsp <= p.dsp, "rf {rf}: dsp {} > {}", r.dsp, p.dsp);
                assert!(r.lut <= p.lut, "rf {rf}: lut {} > {}", r.lut, p.lut);
                assert!(
                    r.latency_cycles >= p.latency_cycles,
                    "rf {rf}: cycles {} < {}",
                    r.latency_cycles,
                    p.latency_cycles
                );
            }
            prev = Some(r);
        }
        // the whole sweep is a real trade, not a plateau
        let rf1 = estimate(&m, vu9p(), 200.0).unwrap();
        let last = prev.unwrap();
        assert!(last.dsp < rf1.dsp && last.lut < rf1.lut);
        assert!(last.latency_cycles > rf1.latency_cycles);
        // time-multiplexed weights move into block RAM
        assert!(last.bram_18k > rf1.bram_18k);
    }

    #[test]
    fn io_stream_adds_fifo_bram_io_parallel_does_not() {
        use crate::hls::ir::IoType;
        let m = toy_model();
        let parallel = estimate(&m, vu9p(), 200.0).unwrap();
        let mut streamed = m.clone();
        streamed.io_type = IoType::Stream;
        let stream = estimate(&streamed, vu9p(), 200.0).unwrap();
        assert_eq!(parallel.bram_18k, 0);
        assert!(stream.bram_18k >= 2, "one FIFO per compute layer");
        // FIFOs cost memory, not arithmetic
        assert_eq!(parallel.dsp, stream.dsp);
    }
}
