//! FPGA synthesis estimator (the Vivado HLS / place&route substitute).
//!
//! The paper's VIVADO-HLS λ-task consumes an HLS C++ project and produces
//! tool reports: resource utilization (DSP/LUT/FF/BRAM), latency and
//! power.  Offline we replace the tool with an analytical model of
//! hls4ml-style fully-unrolled (RF=1, io_parallel) designs, calibrated so
//! the paper's Table II magnitudes and trends hold (see DESIGN.md §1).
//!
//! The model captures exactly the effects the paper's O-tasks exploit:
//! * pruning ⇒ zero weights fold away ⇒ fewer multipliers/adders;
//! * quantization ⇒ below-threshold multiplies move from DSP to LUT
//!   fabric and shrink with bit-width;
//! * scaling ⇒ smaller layers ⇒ everything shrinks, latency drops with
//!   log2(fan-in).

pub mod cost;
pub mod device;
pub mod estimate;
pub mod report;
pub mod reuse;

pub use device::{FpgaDevice, DEVICES};
pub use estimate::{estimate, LayerUsage, SynthReport};
pub use reuse::{reuse_search, ReuseConfig, ReuseProbe, ReuseTrace};
