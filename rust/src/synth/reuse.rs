//! The REUSE_SEARCH O-task's per-layer reuse-factor search — the
//! FPGA-stage counterpart of the DNN-stage searches (quantize, prune,
//! scale), probing the synthesis estimator instead of the trainer.
//!
//! Greedy ascent over the per-layer reuse-factor legality grids
//! (divisors of each layer's fan-in): starting from the current
//! configuration, repeatedly raise the single layer reuse factor whose
//! increase buys the largest resource reduction, while the design stays
//! inside the latency budget.  Two objectives, selected by the config:
//!
//! * **latency budget set** — minimize DSP then LUT subject to
//!   `latency_ns <= budget`;
//! * **no budget** — maximize throughput under the device-fit
//!   constraint: stop at the first (smallest-reuse, hence
//!   lowest-latency) configuration that fits; raise reuse factors only
//!   while the design does not fit.
//!
//! Each round's candidates (one next-legal-step per layer) are
//! independent, so they are submitted as one batch through the
//! [`ProbeService`]'s hardware probe kind
//! ([`ProbeService::estimate_batch`], memoized by HLS-config
//! fingerprint).  Selection is deterministic for
//! any worker count: the full batch is scanned in candidate order with
//! an explicit tie-break — lowest DSP, then lowest LUT, then lowest
//! layer index — so the trace is bit-identical to sequential execution
//! (the same jobs-invariance contract as `quantize_search`).

use crate::dse::{HwEval, HwProbeRequest, ProbeService};
use crate::error::Result;
use crate::hls::ir::HlsModel;
use crate::synth::device::FpgaDevice;

#[derive(Debug, Clone, Default)]
pub struct ReuseConfig {
    /// Latency ceiling (ns).  `None` switches to the fit objective.
    pub latency_budget_ns: Option<f64>,
}

/// One evaluated candidate: compute layer `layer` stepped to reuse
/// factor `rf`.
#[derive(Debug, Clone, PartialEq)]
pub struct ReuseProbe {
    pub round: usize,
    /// Compute-layer index (position among compute layers).
    pub layer: usize,
    pub rf: usize,
    pub dsp: usize,
    pub lut: usize,
    pub latency_ns: f64,
    pub fits: bool,
    /// Admissible and resource-improving (the round's winner is chosen
    /// among accepted probes).
    pub accepted: bool,
}

#[derive(Debug)]
pub struct ReuseTrace {
    /// Whole-design estimate before the search.
    pub base: HwEval,
    /// Whole-design estimate of the chosen configuration.
    pub final_eval: HwEval,
    /// Final reuse factor per compute layer.
    pub reuse: Vec<usize>,
    pub probes: Vec<ReuseProbe>,
}

/// Run the reuse-factor search, returning the rewritten model and the
/// trace.  The input model is not mutated.
pub fn reuse_search(
    model: &HlsModel,
    device: &FpgaDevice,
    clock_mhz: f64,
    cfg: &ReuseConfig,
    pool: &dyn ProbeService,
) -> Result<(HlsModel, ReuseTrace)> {
    let mut cur = model.clone();
    let idxs = cur.compute_layer_indices();
    let base = pool
        .estimate_batch(device, clock_mhz, &[HwProbeRequest::new(0, cur.clone())])?[0]
        .eval;
    let mut cur_eval = base;

    let mut probes = Vec::new();
    let mut round = 0usize;
    loop {
        // fit objective: the smallest reuse configuration that fits is
        // the throughput-optimal one — stop as soon as we are there
        if cfg.latency_budget_ns.is_none() && cur_eval.fits {
            break;
        }
        round += 1;
        // candidates in fixed order: compute layer ascending, each
        // stepped to its next legal (divisor-of-fan-in) reuse factor
        let mut cands: Vec<(usize, usize)> = Vec::new();
        for (ci, &ir) in idxs.iter().enumerate() {
            if let Some(rf) = cur.layers[ir].next_reuse_factor() {
                cands.push((ci, rf));
            }
        }
        if cands.is_empty() {
            break; // every layer is fully time-multiplexed
        }

        let requests: Vec<HwProbeRequest> = cands
            .iter()
            .enumerate()
            .map(|(i, &(ci, rf))| {
                let mut m = cur.clone();
                m.layers[idxs[ci]].reuse_factor = rf;
                HwProbeRequest::new(i, m)
            })
            .collect();
        let results = pool.estimate_batch(device, clock_mhz, &requests)?;

        // keep the best admissible resource reduction; in fit mode a
        // candidate that makes the design fit outranks any amount of
        // further resource saving (otherwise the greedy DSP/LUT walk
        // could step past a fitting configuration it already probed and
        // strand itself behind monotonically growing weight BRAM); ties
        // break to the lowest layer index (scan order makes this
        // deterministic for every worker count)
        let fit_mode = cfg.latency_budget_ns.is_none();
        let mut best: Option<(usize, usize, HwEval)> = None;
        for (&(ci, rf), r) in cands.iter().zip(&results) {
            let e = r.eval;
            let within = cfg.latency_budget_ns.map_or(true, |b| e.latency_ns <= b);
            let improves = e.dsp < cur_eval.dsp
                || (e.dsp == cur_eval.dsp && e.lut < cur_eval.lut);
            let ok = within && (improves || (fit_mode && e.fits));
            probes.push(ReuseProbe {
                round,
                layer: ci,
                rf,
                dsp: e.dsp,
                lut: e.lut,
                latency_ns: e.latency_ns,
                fits: e.fits,
                accepted: ok,
            });
            if !ok {
                continue;
            }
            let better = match &best {
                None => true,
                Some((bci, _, be)) => {
                    (fit_mode && e.fits && !be.fits)
                        || ((e.fits == be.fits || !fit_mode)
                            && (e.dsp < be.dsp
                                || (e.dsp == be.dsp
                                    && (e.lut < be.lut
                                        || (e.lut == be.lut && ci < *bci)))))
                }
            };
            if better {
                best = Some((ci, rf, e));
            }
        }
        match best {
            Some((ci, rf, e)) => {
                cur.layers[idxs[ci]].reuse_factor = rf;
                cur_eval = e;
            }
            None => break, // no step keeps the budget / improves resources
        }
    }

    let reuse = idxs.iter().map(|&i| cur.layers[i].reuse_factor).collect();
    Ok((cur, ReuseTrace { base, final_eval: cur_eval, reuse, probes }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::ProbePool;
    use crate::hls::ir::tests::toy_model;

    fn vu9p() -> &'static FpgaDevice {
        FpgaDevice::by_name("vu9p").unwrap()
    }

    #[test]
    fn fit_mode_is_noop_when_design_already_fits() {
        let pool = ProbePool::new(2);
        let (out, trace) =
            reuse_search(&toy_model(), vu9p(), 200.0, &ReuseConfig::default(), &pool)
                .unwrap();
        assert!(trace.base.fits);
        assert_eq!(trace.reuse, vec![1, 1]);
        assert!(trace.probes.is_empty());
        assert_eq!(out.max_reuse_factor(), 1);
    }

    #[test]
    fn budget_mode_trades_resources_within_latency() {
        let pool = ProbePool::new(2);
        let cfg = ReuseConfig { latency_budget_ns: Some(100.0) };
        let (out, trace) =
            reuse_search(&toy_model(), vu9p(), 200.0, &cfg, &pool).unwrap();
        assert!(trace.final_eval.lut < trace.base.lut);
        assert!(trace.final_eval.dsp <= trace.base.dsp);
        assert!(trace.final_eval.latency_ns <= 100.0);
        assert!(out.max_reuse_factor() > 1);
        // every reuse factor the search chose is legal
        assert!(out.validate().is_ok());
        assert!(!trace.probes.is_empty());
    }

    #[test]
    fn impossible_budget_leaves_model_unchanged() {
        let pool = ProbePool::new(1);
        // RF = 1 is already the latency floor; a budget below it means
        // no admissible step exists
        let cfg = ReuseConfig { latency_budget_ns: Some(1.0) };
        let (out, trace) =
            reuse_search(&toy_model(), vu9p(), 200.0, &cfg, &pool).unwrap();
        assert_eq!(trace.reuse, vec![1, 1]);
        assert_eq!(out.max_reuse_factor(), 1);
        assert_eq!(trace.final_eval, trace.base);
    }

    #[test]
    fn search_is_jobs_invariant() {
        let cfg = ReuseConfig { latency_budget_ns: Some(120.0) };
        let run = |jobs| {
            reuse_search(&toy_model(), vu9p(), 200.0, &cfg, &ProbePool::new(jobs))
                .unwrap()
        };
        let (m1, t1) = run(1);
        let (m4, t4) = run(4);
        assert_eq!(t1.reuse, t4.reuse);
        assert_eq!(t1.probes, t4.probes);
        assert_eq!(t1.final_eval, t4.final_eval);
        let rfs = |m: &HlsModel| -> Vec<usize> {
            m.layers.iter().map(|l| l.reuse_factor).collect()
        };
        assert_eq!(rfs(&m1), rfs(&m4));
    }
}
