//! Per-layer resource/latency/power cost models.
//!
//! Calibration anchors (Table II of the paper, VU9P @ 200 MHz):
//! * jet_dnn, ~70%-pruned, 18-bit: ≈950 DSP (the [23] baseline row);
//! * jet_dnn mixed-precision α_q=1%: 638 DSP / 69.7k LUT, 14 cyc / 70 ns,
//!   2.51 W dynamic;
//! * S→P→Q α_q=1%: 50 DSP / 6.7k LUT, 9 cyc / 45 ns, 0.199 W.
//!
//! Constants below were fit to those anchors; we claim trend fidelity
//! (who wins, by roughly what factor), not absolute-LUT fidelity.

use crate::model::state::Precision;

/// Bit-width at or below which Vivado maps a multiply to LUT fabric
/// instead of a DSP48 (hls4ml's documented ~10-bit crossover).
pub const DSP_THRESHOLD_BITS: u32 = 10;

/// Fraction of above-threshold multiplies that actually consume a DSP
/// (the rest fold into shifts/adders: weights that are 0, ±1, ±2^k).
pub const DSP_SHARE: f64 = 0.75;

/// Effective bit-width of a layer (float == 32-bit datapath).
pub fn effective_bits(p: Precision) -> u32 {
    if p.enabled() {
        p.total_bits
    } else {
        32
    }
}

/// Does a multiply at this precision use DSP blocks?
pub fn uses_dsp(p: Precision) -> bool {
    effective_bits(p) > DSP_THRESHOLD_BITS
}

/// DSP blocks for one multiply (wide products cascade multiple DSP48s).
pub fn dsp_per_mult(p: Precision) -> f64 {
    let b = effective_bits(p);
    if b <= DSP_THRESHOLD_BITS {
        0.0
    } else if b <= 18 {
        DSP_SHARE
    } else if b <= 27 {
        1.6
    } else {
        3.2
    }
}

/// LUTs for one multiply.
pub fn lut_per_mult(p: Precision) -> f64 {
    let b = effective_bits(p) as f64;
    if effective_bits(p) <= DSP_THRESHOLD_BITS {
        // LUT-fabric multiplier: ~b^2/2 LUTs (Vivado small-mult cost)
        (b * b) / 2.0 + 3.0
    } else {
        // DSP-mapped multiply still burns interconnect/alignment LUTs
        6.0
    }
}

/// LUTs for the accumulation tree of one compute layer.
///
/// `n_adds` ≈ multipliers − outputs; each adder is `acc_bits` wide packed
/// ~2 bits/LUT with carry chains.
pub fn lut_adder_tree(n_adds: usize, acc_bits: u32) -> f64 {
    n_adds as f64 * (acc_bits as f64 / 2.0)
}

/// Accumulator width: datapath + log2(fan-in) headroom (see codegen).
pub fn acc_bits(p: Precision, fan_in: usize) -> u32 {
    effective_bits(p) + (fan_in.max(2) as f64).log2().ceil() as u32
}

/// Pipeline-register flip-flops, proportional to layer LUT+DSP area.
pub fn ff_estimate(luts: f64, dsps: f64) -> f64 {
    1.15 * luts + 12.0 * dsps
}

/// Latency of one compute layer in cycles.
///
/// mult stage (1 cycle; wide >18-bit products cascade DSPs, +1) plus a
/// compressed 6:1 accumulation tree over the *effective* (post-pruning)
/// fan-in — this is what makes latency drop as pruning/scaling progress
/// (Table II: 14 cycles baseline → 9 cycles after S→P→Q).
///
/// `reuse_factor` time-multiplexes the MAC array: the fan-in is split
/// into RF equal passes issued back-to-back (II = RF), each reducing
/// `fan_in / RF` products through a correspondingly shallower tree, so
/// latency grows (weakly) monotonically with RF while the multiplier
/// count shrinks.  RF = 1 reproduces the fully-unrolled depth exactly.
pub fn layer_cycles(
    p: Precision,
    fan_in: usize,
    density: f64,
    spatial_iters: usize,
    reuse_factor: usize,
) -> usize {
    let eff_fan = ((fan_in as f64 * density).ceil() as usize).max(1);
    let rf = reuse_factor.max(1);
    let per_pass = eff_fan.div_ceil(rf);
    let mult = if effective_bits(p) > 18 { 2 } else { 1 };
    let tree = if per_pass <= 1 {
        0
    } else {
        ((per_pass as f64).log2() / 6.0_f64.log2()).ceil() as usize
    };
    // RF serial passes; each pass costs at least the partial-sum
    // accumulation cycle even when its tree is degenerate
    let acc = if rf > 1 { rf * tree.max(1) } else { tree };
    // conv reuses the MAC array across positions: the positions overlap
    // in an II=RF pipeline, so each extra position re-issues every RF
    // cycles (one extra cycle each when fully unrolled)
    mult + acc + spatial_iters.saturating_sub(1) * rf
}

/// Extra LUTs for the partial-sum accumulators a time-multiplexed
/// (RF > 1) layer needs: one `acc_bits`-wide accumulating adder per
/// output, packed ~2 bits/LUT (fully-unrolled RF = 1 designs fold the
/// accumulation into the tree and pay nothing).
///
/// This fixed per-output cost means the "RF ↑ ⇒ LUT ↓" trend holds for
/// dense and moderately-pruned layers (where halving the multiplier
/// and adder-tree counts dominates) but can invert for heavily-pruned
/// DSP-mapped layers, whose per-multiplier LUT share is only the small
/// interconnect constant — on such layers raising RF buys little and
/// the greedy reuse search correctly declines to step.
pub fn lut_partial_sum(n_out: usize, acc_bits: u32, reuse_factor: usize) -> f64 {
    if reuse_factor > 1 {
        n_out as f64 * (acc_bits as f64 / 2.0)
    } else {
        0.0
    }
}

/// BRAM18K blocks for weight storage of a time-multiplexed layer.
/// Fully-unrolled (RF = 1) layers bake weights into the fabric as
/// constants; at RF > 1 the surviving weights live in block RAM and are
/// streamed into the MAC array pass by pass.
pub fn bram_weights(nnz: usize, bits: u32, reuse_factor: usize) -> f64 {
    if reuse_factor > 1 {
        ((nnz as f64 * bits as f64) / 18_432.0).ceil()
    } else {
        0.0
    }
}

/// BRAM18K blocks for one `io_stream` FIFO edge carrying `words`
/// elements of `bits` each (hls4ml dataflow FIFOs; at least one block
/// per stream).  `io_parallel` designs pay nothing here.
pub fn bram_stream_fifo(words: usize, bits: u32) -> f64 {
    ((words.max(1) as f64 * bits as f64) / 18_432.0).ceil().max(1.0)
}

/// Cycles for the softmax head (hls4ml table-based softmax).
pub const SOFTMAX_CYCLES: usize = 2;

/// Dynamic power model (W) at the reference 200 MHz clock.
pub fn power_w(dsp: f64, lut: f64, clock_mhz: f64) -> f64 {
    (1.45e-3 * dsp + 2.05e-5 * lut + 0.03) * (clock_mhz / 200.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_threshold_crossover() {
        assert!(!uses_dsp(Precision::new(8, 3)));
        assert!(!uses_dsp(Precision::new(10, 4)));
        assert!(uses_dsp(Precision::new(11, 4)));
        assert!(uses_dsp(Precision::new(18, 8)));
        assert!(uses_dsp(Precision::DISABLED)); // float = 32-bit
    }

    #[test]
    fn lut_mult_grows_with_bits() {
        let l4 = lut_per_mult(Precision::new(4, 2));
        let l8 = lut_per_mult(Precision::new(8, 3));
        let l10 = lut_per_mult(Precision::new(10, 4));
        assert!(l4 < l8 && l8 < l10);
        // DSP-mapped mult has small fixed LUT overhead
        assert!(lut_per_mult(Precision::new(18, 8)) < l8);
    }

    #[test]
    fn wide_products_cascade_dsps() {
        assert!(dsp_per_mult(Precision::new(18, 8)) < dsp_per_mult(Precision::new(24, 8)));
        assert!(dsp_per_mult(Precision::new(24, 8)) < dsp_per_mult(Precision::DISABLED));
        assert_eq!(dsp_per_mult(Precision::new(8, 3)), 0.0);
    }

    #[test]
    fn latency_drops_with_pruning() {
        let p = Precision::new(18, 8);
        let full = layer_cycles(p, 64, 1.0, 1, 1);
        let pruned = layer_cycles(p, 64, 0.1, 1, 1);
        assert!(pruned < full, "{pruned} !< {full}");
        assert!(layer_cycles(p, 1, 1.0, 1, 1) >= 1);
    }

    #[test]
    fn jet_baseline_latency_anchor() {
        // jet_dnn 18-bit unpruned: 4 dense layers fan-in 16/64/32/32
        // paper anchor: ~14-15 cycles total
        let p = Precision::new(18, 8);
        let total: usize = [16usize, 64, 32, 32]
            .iter()
            .map(|&f| layer_cycles(p, f, 1.0, 1, 1))
            .sum::<usize>()
            + SOFTMAX_CYCLES;
        assert!((13..=16).contains(&total), "total {total}");
    }

    #[test]
    fn reuse_grows_latency_monotonically() {
        let p = Precision::new(18, 8);
        let mut prev = 0usize;
        for rf in [1usize, 2, 4, 8, 16, 32, 64] {
            let c = layer_cycles(p, 64, 1.0, 1, rf);
            assert!(c >= prev, "rf {rf}: {c} < {prev}");
            prev = c;
        }
        // strictly deeper than fully unrolled at high RF
        assert!(layer_cycles(p, 64, 1.0, 1, 64) > layer_cycles(p, 64, 1.0, 1, 1));
    }

    #[test]
    fn reuse_side_costs_only_above_one() {
        assert_eq!(lut_partial_sum(10, 22, 1), 0.0);
        assert!(lut_partial_sum(10, 22, 2) > 0.0);
        assert_eq!(bram_weights(1024, 18, 1), 0.0);
        assert!(bram_weights(1024, 18, 4) >= 1.0);
        // a stream FIFO always costs at least one block
        assert!(bram_stream_fifo(1, 8) >= 1.0);
        assert!(bram_stream_fifo(4096, 18) > bram_stream_fifo(16, 18));
    }

    #[test]
    fn power_anchor_table2() {
        // 638 DSP + 69751 LUT @200MHz ≈ 2.51 W (±20%)
        let p = power_w(638.0, 69_751.0, 200.0);
        assert!((p - 2.51).abs() / 2.51 < 0.2, "power {p}");
        // 50 DSP + 6698 LUT ≈ 0.199 W (±25%)
        let p2 = power_w(50.0, 6_698.0, 200.0);
        assert!((p2 - 0.199).abs() / 0.199 < 0.3, "power {p2}");
        // clock scaling
        assert!(power_w(100.0, 1000.0, 100.0) < power_w(100.0, 1000.0, 200.0));
    }

    #[test]
    fn conv_spatial_iters_add_depth() {
        let p = Precision::new(18, 8);
        assert!(layer_cycles(p, 72, 1.0, 64, 1) > layer_cycles(p, 72, 1.0, 1, 1) + 60);
        // positions re-issue every II = RF cycles: the spatial term
        // scales with the reuse factor, consistent with the emitted
        // PIPELINE II pragma
        let rf8 = layer_cycles(p, 72, 1.0, 64, 8);
        assert!(rf8 >= 63 * 8, "conv spatial term must scale with RF: {rf8}");
        assert!(rf8 > layer_cycles(p, 72, 1.0, 64, 1));
    }
}
