//! Text rendering of synthesis reports (the "tool report" supporting file).

use crate::synth::estimate::SynthReport;

/// Render a Vivado-HLS-style utilization report.
pub fn render(r: &SynthReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== Synthesis report: {} on {} ({}) @ {:.0} MHz ==\n",
        r.design, r.device.name, r.device.part, r.clock_mhz
    ));
    out.push_str(&format!(
        "latency: {} cycles = {:.1} ns   II = {}   dynamic power: {:.3} W\n",
        r.latency_cycles, r.latency_ns, r.ii, r.dynamic_power_w
    ));
    out.push_str("\n| resource | used | available | util % |\n");
    out.push_str("|----------|------|-----------|--------|\n");
    out.push_str(&format!(
        "| DSP48    | {:>8} | {:>9} | {:>6.2} |\n",
        r.dsp, r.device.dsp, r.dsp_pct()
    ));
    out.push_str(&format!(
        "| LUT      | {:>8} | {:>9} | {:>6.2} |\n",
        r.lut, r.device.lut, r.lut_pct()
    ));
    out.push_str(&format!(
        "| FF       | {:>8} | {:>9} | {:>6.2} |\n",
        r.ff, r.device.ff, r.ff_pct()
    ));
    out.push_str(&format!(
        "| BRAM18K  | {:>8} | {:>9} | {:>6.2} |\n",
        r.bram_18k, r.device.bram_18k, r.bram_pct()
    ));
    out.push_str(&format!(
        "\nfits device: {}\n\nper-layer:\n",
        if r.fits() { "YES" } else { "NO" }
    ));
    for l in &r.layers {
        out.push_str(&format!(
            "  {:<10} dsp {:>8.1} lut {:>10.1} ff {:>10.1} bram {:>5.1} cycles {:>4}\n",
            l.name, l.dsp, l.lut, l.ff, l.bram_18k, l.cycles
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ir::tests::toy_model;
    use crate::synth::device::FpgaDevice;
    use crate::synth::estimate::estimate;

    #[test]
    fn renders_all_sections() {
        let r = estimate(&toy_model(), FpgaDevice::by_name("vu9p").unwrap(), 200.0).unwrap();
        let text = render(&r);
        assert!(text.contains("DSP48"));
        assert!(text.contains("fits device: YES"));
        assert!(text.contains("fc1"));
        assert!(text.contains("dynamic power"));
    }
}
