//! FPGA device database (the parts the paper evaluates on, §V-A).

/// Capacity record for one FPGA part.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub part: &'static str,
    pub dsp: usize,
    pub lut: usize,
    pub ff: usize,
    pub bram_18k: usize,
    /// Default clock per the paper: 100 MHz Zynq 7020, 200 MHz U250/VU9P.
    pub default_clock_mhz: f64,
}

/// The four parts used across the paper's experiments.
pub const DEVICES: &[FpgaDevice] = &[
    FpgaDevice {
        name: "zynq7020",
        part: "xc7z020clg400-1",
        dsp: 220,
        lut: 53_200,
        ff: 106_400,
        bram_18k: 280,
        default_clock_mhz: 100.0,
    },
    FpgaDevice {
        name: "ku115",
        part: "xcku115-flvb2104-2-e",
        dsp: 5_520,
        lut: 663_360,
        ff: 1_326_720,
        bram_18k: 4_320,
        default_clock_mhz: 200.0,
    },
    FpgaDevice {
        name: "vu9p",
        part: "xcvu9p-flga2104-2L-e",
        dsp: 6_840,
        lut: 1_182_240,
        ff: 2_364_480,
        bram_18k: 4_320,
        default_clock_mhz: 200.0,
    },
    FpgaDevice {
        name: "u250",
        part: "xcu250-figd2104-2L-e",
        dsp: 12_288,
        lut: 1_728_000,
        ff: 3_456_000,
        bram_18k: 5_376,
        default_clock_mhz: 200.0,
    },
];

impl FpgaDevice {
    pub fn by_name(name: &str) -> Option<&'static FpgaDevice> {
        DEVICES.iter().find(|d| d.name == name || d.part == name)
    }

    /// Resolve an HLS model's synthesis target: its device record and
    /// clock frequency (MHz) derived from the clock period.  The single
    /// source of truth for every FPGA-stage task (VIVADO-HLS,
    /// REUSE_SEARCH), including the `clock_period_ns <= 0` edge that a
    /// bare `1000.0 / period` would turn into an infinite clock.
    pub fn target_of(
        model: &crate::hls::HlsModel,
    ) -> crate::error::Result<(&'static FpgaDevice, f64)> {
        let device = FpgaDevice::by_name(&model.fpga_part).ok_or_else(|| {
            crate::error::Error::Synth(format!("unknown device {}", model.fpga_part))
        })?;
        if model.clock_period_ns <= 0.0 {
            return Err(crate::error::Error::Synth(format!(
                "bad clock period {} ns",
                model.clock_period_ns
            )));
        }
        Ok((device, 1000.0 / model.clock_period_ns))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name_and_part() {
        assert_eq!(FpgaDevice::by_name("vu9p").unwrap().dsp, 6_840);
        assert_eq!(
            FpgaDevice::by_name("xc7z020clg400-1").unwrap().name,
            "zynq7020"
        );
        assert!(FpgaDevice::by_name("nonexistent").is_none());
    }

    #[test]
    fn target_of_resolves_device_and_rejects_bad_clock() {
        let mut m = crate::hls::ir::tests::toy_model();
        m.fpga_part = "vu9p".into();
        let (d, mhz) = FpgaDevice::target_of(&m).unwrap();
        assert_eq!(d.name, "vu9p");
        assert!((mhz - 200.0).abs() < 1e-9);
        m.clock_period_ns = 0.0;
        assert!(FpgaDevice::target_of(&m).is_err());
        m.clock_period_ns = 5.0;
        m.fpga_part = "nonexistent".into();
        assert!(FpgaDevice::target_of(&m).is_err());
    }

    #[test]
    fn capacities_ordered() {
        let z = FpgaDevice::by_name("zynq7020").unwrap();
        let v = FpgaDevice::by_name("vu9p").unwrap();
        let u = FpgaDevice::by_name("u250").unwrap();
        assert!(z.dsp < v.dsp && v.dsp < u.dsp);
        assert!(z.lut < v.lut && v.lut < u.lut);
    }
}
