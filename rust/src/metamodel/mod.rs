//! The meta-model: shared state of a design flow (paper §III, Fig 1).
//!
//! Three sections, exactly as the paper describes:
//! * **CFG** — a key-value store holding the parameters of every pipe task
//!   in the flow ([cfg::Cfg]);
//! * **LOG** — the runtime execution trace, for debugging and for the
//!   experiment harness ([log::ExecLog]);
//! * **model space** — the models generated during flow execution, across
//!   abstraction levels (DNN, HLS C++, RTL), each with supporting files,
//!   tool reports and computed metrics ([space::ModelSpace]).

pub mod cfg;
pub mod log;
pub mod space;

pub use cfg::Cfg;
pub use log::{ExecLog, LogEvent};
pub use space::{Abstraction, ModelArtifact, ModelId, ModelPayload, ModelSpace};

/// The shared space pipe tasks read and write.
#[derive(Debug, Default)]
pub struct MetaModel {
    pub cfg: Cfg,
    pub log: ExecLog,
    pub space: ModelSpace,
}

impl MetaModel {
    pub fn new() -> Self {
        Self::default()
    }
}
