//! LOG section: the runtime execution trace of a design flow.

use std::time::Instant;

/// What happened at one trace point.
#[derive(Debug, Clone, PartialEq)]
pub enum LogEvent {
    FlowStarted { flow: String },
    FlowFinished { flow: String },
    TaskStarted { task: String },
    TaskFinished { task: String, secs: f64 },
    /// A named scalar a task measured (accuracy, pruning rate, DSP count…).
    Metric { task: String, name: String, value: f64 },
    /// Free-form progress message.
    Message { task: String, text: String },
    ModelStored { task: String, model_id: u64, abstraction: String },
    IterationAdvanced { task: String, iteration: usize },
}

#[derive(Debug, Clone)]
pub struct LogEntry {
    pub seq: usize,
    pub at_secs: f64,
    pub event: LogEvent,
}

/// Append-only execution trace.
#[derive(Debug)]
pub struct ExecLog {
    started: Instant,
    entries: Vec<LogEntry>,
    /// Mirror entries to stdout as they arrive.
    pub echo: bool,
}

impl Default for ExecLog {
    fn default() -> Self {
        ExecLog { started: Instant::now(), entries: Vec::new(), echo: false }
    }
}

impl ExecLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, event: LogEvent) {
        let entry = LogEntry {
            seq: self.entries.len(),
            at_secs: self.started.elapsed().as_secs_f64(),
            event,
        };
        if self.echo {
            println!("  [{:>8.3}s] {}", entry.at_secs, render(&entry.event));
        }
        self.entries.push(entry);
    }

    pub fn metric(&mut self, task: &str, name: &str, value: f64) {
        self.push(LogEvent::Metric {
            task: task.to_string(),
            name: name.to_string(),
            value,
        });
    }

    pub fn message(&mut self, task: &str, text: impl Into<String>) {
        self.push(LogEvent::Message { task: task.to_string(), text: text.into() });
    }

    pub fn entries(&self) -> &[LogEntry] {
        &self.entries
    }

    /// All metric values named `name` recorded by `task`, in order.
    pub fn metric_series(&self, task: &str, name: &str) -> Vec<f64> {
        self.entries
            .iter()
            .filter_map(|e| match &e.event {
                LogEvent::Metric { task: t, name: n, value }
                    if t == task && n == name =>
                {
                    Some(*value)
                }
                _ => None,
            })
            .collect()
    }

    /// Render the full trace as text (debugging aid per the paper).
    pub fn render_trace(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&format!("[{:>9.3}s] {}\n", e.at_secs, render(&e.event)));
        }
        out
    }
}

fn render(event: &LogEvent) -> String {
    match event {
        LogEvent::FlowStarted { flow } => format!("flow {flow}: started"),
        LogEvent::FlowFinished { flow } => format!("flow {flow}: finished"),
        LogEvent::TaskStarted { task } => format!("{task}: started"),
        LogEvent::TaskFinished { task, secs } => {
            format!("{task}: finished in {secs:.3}s")
        }
        LogEvent::Metric { task, name, value } => {
            format!("{task}: {name} = {value:.6}")
        }
        LogEvent::Message { task, text } => format!("{task}: {text}"),
        LogEvent::ModelStored { task, model_id, abstraction } => {
            format!("{task}: stored model #{model_id} [{abstraction}]")
        }
        LogEvent::IterationAdvanced { task, iteration } => {
            format!("{task}: iteration {iteration}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_seq() {
        let mut log = ExecLog::new();
        log.push(LogEvent::TaskStarted { task: "a".into() });
        log.metric("a", "acc", 0.75);
        log.push(LogEvent::TaskFinished { task: "a".into(), secs: 0.1 });
        assert_eq!(log.entries().len(), 3);
        assert_eq!(log.entries()[1].seq, 1);
    }

    #[test]
    fn metric_series_filters() {
        let mut log = ExecLog::new();
        log.metric("prune", "rate", 0.5);
        log.metric("prune", "acc", 0.8);
        log.metric("prune", "rate", 0.75);
        log.metric("other", "rate", 0.1);
        assert_eq!(log.metric_series("prune", "rate"), vec![0.5, 0.75]);
        assert!(log.metric_series("prune", "missing").is_empty());
    }

    #[test]
    fn trace_renders_every_entry() {
        let mut log = ExecLog::new();
        log.message("t", "hello");
        log.metric("t", "x", 1.0);
        let trace = log.render_trace();
        assert!(trace.contains("hello"));
        assert!(trace.contains("x = 1"));
        assert_eq!(trace.lines().count(), 2);
    }
}
