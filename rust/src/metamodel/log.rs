//! LOG section: the runtime execution trace of a design flow.
//!
//! **Determinism contract:** the event stream ([`ExecLog::events`]) is
//! bit-for-bit reproducible — two runs of the same flow with the same
//! CFG and seed produce identical `LogEvent` sequences for any worker
//! count.  Anything wall-clock-dependent (task durations, cache hit
//! counters) therefore lives in a parallel *side-note table*
//! ([`ExecLog::note`] / [`ExecLog::side_notes`]), never in the event
//! stream.  The per-entry `at_secs` timestamps are display-only
//! decoration for [`ExecLog::render_trace`]; replay comparisons use
//! [`ExecLog::events`] or [`ExecLog::render_events`].

use std::time::Instant;

/// What happened at one trace point.
#[derive(Debug, Clone, PartialEq)]
pub enum LogEvent {
    FlowStarted { flow: String },
    FlowFinished { flow: String },
    TaskStarted { task: String },
    /// Wall-clock duration intentionally absent: timings are side notes.
    TaskFinished { task: String },
    /// The engine skipped a node (no incoming edge was taken).
    TaskSkipped { task: String },
    /// A named scalar a task measured (accuracy, pruning rate, DSP count…).
    Metric { task: String, name: String, value: f64 },
    /// Free-form progress message.
    Message { task: String, text: String },
    ModelStored { task: String, model_id: u64, abstraction: String },
    IterationAdvanced { task: String, iteration: usize },
    /// A guard was evaluated: a conditional edge (`from -> to`) or a
    /// strategy arm check (`from` = strategy instance, `to` = arm name).
    EdgeEvaluated { from: String, to: String, metric: String, value: f64, taken: bool },
    /// A strategy node committed to an arm.
    StrategySelected { task: String, arm: String },
}

#[derive(Debug, Clone)]
pub struct LogEntry {
    pub seq: usize,
    /// Wall-clock offset for human-readable traces; NOT part of the
    /// reproducibility contract.
    pub at_secs: f64,
    pub event: LogEvent,
}

/// A wall-clock-dependent measurement attached to a task, kept out of
/// the replay-comparable event stream (durations, cache hit counts…).
#[derive(Debug, Clone, PartialEq)]
pub struct SideNote {
    pub task: String,
    pub name: String,
    pub value: f64,
}

/// Append-only execution trace.
#[derive(Debug)]
pub struct ExecLog {
    started: Instant,
    entries: Vec<LogEntry>,
    side: Vec<SideNote>,
    /// Mirror entries to stdout as they arrive.
    pub echo: bool,
}

impl Default for ExecLog {
    fn default() -> Self {
        ExecLog {
            started: Instant::now(),
            entries: Vec::new(),
            side: Vec::new(),
            echo: false,
        }
    }
}

impl ExecLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, event: LogEvent) {
        let entry = LogEntry {
            seq: self.entries.len(),
            at_secs: self.started.elapsed().as_secs_f64(),
            event,
        };
        if self.echo {
            println!("  [{:>8.3}s] {}", entry.at_secs, render(&entry.event));
        }
        self.entries.push(entry);
    }

    pub fn metric(&mut self, task: &str, name: &str, value: f64) {
        self.push(LogEvent::Metric {
            task: task.to_string(),
            name: name.to_string(),
            value,
        });
    }

    pub fn message(&mut self, task: &str, text: impl Into<String>) {
        self.push(LogEvent::Message { task: task.to_string(), text: text.into() });
    }

    /// Record a wall-clock-dependent measurement in the side table
    /// (never in the event stream).
    pub fn note(&mut self, task: &str, name: &str, value: f64) {
        self.side.push(SideNote {
            task: task.to_string(),
            name: name.to_string(),
            value,
        });
    }

    pub fn entries(&self) -> &[LogEntry] {
        &self.entries
    }

    /// The replay-comparable event stream (no timestamps, no side notes).
    pub fn events(&self) -> impl Iterator<Item = &LogEvent> {
        self.entries.iter().map(|e| &e.event)
    }

    pub fn side_notes(&self) -> &[SideNote] {
        &self.side
    }

    /// All metric values named `name` recorded by `task`, in order.
    pub fn metric_series(&self, task: &str, name: &str) -> Vec<f64> {
        self.entries
            .iter()
            .filter_map(|e| match &e.event {
                LogEvent::Metric { task: t, name: n, value }
                    if t == task && n == name =>
                {
                    Some(*value)
                }
                _ => None,
            })
            .collect()
    }

    /// How many times `task` has started in this flow run (back-edge
    /// re-executions increment it).  Derived purely from the
    /// replay-comparable event stream, so tasks that escalate their
    /// configuration per iteration stay deterministic.
    pub fn count_task_started(&self, task: &str) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(&e.event, LogEvent::TaskStarted { task: t } if t == task))
            .count()
    }

    /// Latest metric value named `name` recorded by `task`.
    pub fn latest_metric(&self, task: &str, name: &str) -> Option<f64> {
        self.entries.iter().rev().find_map(|e| match &e.event {
            LogEvent::Metric { task: t, name: n, value } if t == task && n == name => {
                Some(*value)
            }
            _ => None,
        })
    }

    /// Render the full trace as text (debugging aid per the paper),
    /// including wall-clock timestamps.  Not replay-comparable — use
    /// [`render_events`](Self::render_events) for that.
    pub fn render_trace(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&format!("[{:>9.3}s] {}\n", e.at_secs, render(&e.event)));
        }
        out
    }

    /// Deterministic render of the event stream alone: identical runs
    /// produce identical strings, for any worker count.
    pub fn render_events(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&render(&e.event));
            out.push('\n');
        }
        out
    }
}

fn render(event: &LogEvent) -> String {
    match event {
        LogEvent::FlowStarted { flow } => format!("flow {flow}: started"),
        LogEvent::FlowFinished { flow } => format!("flow {flow}: finished"),
        LogEvent::TaskStarted { task } => format!("{task}: started"),
        LogEvent::TaskFinished { task } => format!("{task}: finished"),
        LogEvent::TaskSkipped { task } => format!("{task}: skipped"),
        LogEvent::Metric { task, name, value } => {
            format!("{task}: {name} = {value:.6}")
        }
        LogEvent::Message { task, text } => format!("{task}: {text}"),
        LogEvent::ModelStored { task, model_id, abstraction } => {
            format!("{task}: stored model #{model_id} [{abstraction}]")
        }
        LogEvent::IterationAdvanced { task, iteration } => {
            format!("{task}: iteration {iteration}")
        }
        LogEvent::EdgeEvaluated { from, to, metric, value, taken } => {
            format!(
                "{from} -> {to}: guard {metric} = {value:.6} => {}",
                if *taken { "taken" } else { "not taken" }
            )
        }
        LogEvent::StrategySelected { task, arm } => {
            format!("{task}: selected arm {arm:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_seq() {
        let mut log = ExecLog::new();
        log.push(LogEvent::TaskStarted { task: "a".into() });
        log.metric("a", "acc", 0.75);
        log.push(LogEvent::TaskFinished { task: "a".into() });
        assert_eq!(log.entries().len(), 3);
        assert_eq!(log.entries()[1].seq, 1);
    }

    #[test]
    fn metric_series_filters() {
        let mut log = ExecLog::new();
        log.metric("prune", "rate", 0.5);
        log.metric("prune", "acc", 0.8);
        log.metric("prune", "rate", 0.75);
        log.metric("other", "rate", 0.1);
        assert_eq!(log.metric_series("prune", "rate"), vec![0.5, 0.75]);
        assert!(log.metric_series("prune", "missing").is_empty());
        assert_eq!(log.latest_metric("prune", "rate"), Some(0.75));
        assert_eq!(log.latest_metric("prune", "missing"), None);
    }

    #[test]
    fn trace_renders_every_entry() {
        let mut log = ExecLog::new();
        log.message("t", "hello");
        log.metric("t", "x", 1.0);
        let trace = log.render_trace();
        assert!(trace.contains("hello"));
        assert!(trace.contains("x = 1"));
        assert_eq!(trace.lines().count(), 2);
    }

    #[test]
    fn side_notes_stay_out_of_event_stream() {
        let mut log = ExecLog::new();
        log.push(LogEvent::TaskStarted { task: "a".into() });
        log.note("a", "secs", 0.123);
        log.push(LogEvent::TaskFinished { task: "a".into() });
        assert_eq!(log.entries().len(), 2);
        assert_eq!(log.side_notes().len(), 1);
        assert_eq!(log.side_notes()[0].name, "secs");
        assert!(!log.render_events().contains("0.123"));
    }

    #[test]
    fn event_streams_of_identical_logs_compare_equal() {
        let build = || {
            let mut log = ExecLog::new();
            log.push(LogEvent::FlowStarted { flow: "f".into() });
            log.push(LogEvent::TaskStarted { task: "a".into() });
            log.metric("a", "acc", 0.5);
            // wall-clock-dependent data goes to the side table only
            log.note("a", "secs", 42.0);
            log.push(LogEvent::TaskFinished { task: "a".into() });
            log.push(LogEvent::EdgeEvaluated {
                from: "a".into(),
                to: "b".into(),
                metric: "a.acc".into(),
                value: 0.5,
                taken: true,
            });
            log.push(LogEvent::FlowFinished { flow: "f".into() });
            log
        };
        let (a, b) = (build(), build());
        let ev_a: Vec<&LogEvent> = a.events().collect();
        let ev_b: Vec<&LogEvent> = b.events().collect();
        assert_eq!(ev_a, ev_b);
        assert_eq!(a.render_events(), b.render_events());
    }
}
