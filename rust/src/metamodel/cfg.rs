//! CFG section: key-value store for pipe-task parameters.
//!
//! Keys are namespaced `"<task-instance>.<param>"`; plain keys act as flow-
//! wide defaults.  Lookup order: instance-scoped, then global, then the
//! task's declared default.

use std::collections::BTreeMap;

use crate::json::Value;

#[derive(Debug, Default, Clone)]
pub struct Cfg {
    entries: BTreeMap<String, Value>,
}

impl Cfg {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Value>) {
        self.entries.insert(key.into(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Scoped lookup: `"{instance}.{param}"` first, then `"{param}"`.
    pub fn lookup(&self, instance: &str, param: &str) -> Option<&Value> {
        self.entries
            .get(&format!("{instance}.{param}"))
            .or_else(|| self.entries.get(param))
    }

    pub fn get_f64(&self, instance: &str, param: &str) -> Option<f64> {
        self.lookup(instance, param).and_then(Value::as_f64)
    }

    pub fn get_usize(&self, instance: &str, param: &str) -> Option<usize> {
        self.lookup(instance, param).and_then(Value::as_usize)
    }

    pub fn get_str(&self, instance: &str, param: &str) -> Option<&str> {
        self.lookup(instance, param).and_then(Value::as_str)
    }

    pub fn get_bool(&self, instance: &str, param: &str) -> Option<bool> {
        self.lookup(instance, param).and_then(Value::as_bool)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.entries.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_lookup_precedence() {
        let mut cfg = Cfg::new();
        cfg.set("train_epochs", 5usize);
        cfg.set("pruning.train_epochs", 3usize);
        assert_eq!(cfg.get_usize("pruning", "train_epochs"), Some(3));
        assert_eq!(cfg.get_usize("scaling", "train_epochs"), Some(5));
        assert_eq!(cfg.get_usize("scaling", "missing"), None);
    }

    #[test]
    fn typed_accessors() {
        let mut cfg = Cfg::new();
        cfg.set("alpha", 0.02);
        cfg.set("name", "jet_dnn");
        cfg.set("auto", true);
        assert_eq!(cfg.get_f64("t", "alpha"), Some(0.02));
        assert_eq!(cfg.get_str("t", "name"), Some("jet_dnn"));
        assert_eq!(cfg.get_bool("t", "auto"), Some(true));
        // wrong type => None
        assert_eq!(cfg.get_usize("t", "name"), None);
    }
}
