//! Model space: generated models across abstraction levels.
//!
//! Fig 1 of the paper shows the model space holding six models spanning
//! DNN, HLS C++ and RTL abstractions, each with supporting files, tool
//! reports and computed metrics.  Artifacts are immutable once stored;
//! O-tasks store *new* models (with `parent` lineage) rather than mutating.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::hls::HlsModel;
use crate::model::ModelState;
use crate::synth::SynthReport;

pub type ModelId = u64;

/// Abstraction level of a stored model (pipeline stage it belongs to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Abstraction {
    Dnn,
    HlsCpp,
    Rtl,
}

impl std::fmt::Display for Abstraction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Abstraction::Dnn => write!(f, "DNN"),
            Abstraction::HlsCpp => write!(f, "HLS-C++"),
            Abstraction::Rtl => write!(f, "RTL"),
        }
    }
}

/// The model payload at each abstraction level.
#[derive(Debug, Clone)]
pub enum ModelPayload {
    /// Trained DNN: variant tag + live state (params/masks/precisions).
    Dnn(ModelState),
    /// HLS C++ model: typed layer IR (+ generated source, see supporting).
    Hls(HlsModel),
    /// RTL-stage result: the synthesis report stands in for the netlist.
    Rtl(SynthReport),
}

impl ModelPayload {
    pub fn abstraction(&self) -> Abstraction {
        match self {
            ModelPayload::Dnn(_) => Abstraction::Dnn,
            ModelPayload::Hls(_) => Abstraction::HlsCpp,
            ModelPayload::Rtl(_) => Abstraction::Rtl,
        }
    }
}

/// One stored model: payload + metrics + supporting files + lineage.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub id: ModelId,
    pub name: String,
    pub producer: String,
    pub parent: Option<ModelId>,
    pub payload: ModelPayload,
    /// Computed metrics (accuracy, pruning_rate, dsp, lut, latency_ns, …).
    pub metrics: BTreeMap<String, f64>,
    /// Supporting files: (file name, content) — e.g. generated HLS C++.
    pub supporting: Vec<(String, String)>,
}

impl ModelArtifact {
    pub fn abstraction(&self) -> Abstraction {
        self.payload.abstraction()
    }

    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.get(name).copied()
    }

    pub fn dnn(&self) -> Result<&ModelState> {
        match &self.payload {
            ModelPayload::Dnn(s) => Ok(s),
            _ => Err(Error::ModelSpace(format!(
                "model #{} is {} not DNN",
                self.id,
                self.abstraction()
            ))),
        }
    }

    pub fn hls(&self) -> Result<&HlsModel> {
        match &self.payload {
            ModelPayload::Hls(m) => Ok(m),
            _ => Err(Error::ModelSpace(format!(
                "model #{} is {} not HLS-C++",
                self.id,
                self.abstraction()
            ))),
        }
    }

    pub fn rtl(&self) -> Result<&SynthReport> {
        match &self.payload {
            ModelPayload::Rtl(r) => Ok(r),
            _ => Err(Error::ModelSpace(format!(
                "model #{} is {} not RTL",
                self.id,
                self.abstraction()
            ))),
        }
    }
}

/// Append-only store of model artifacts.
#[derive(Debug, Default)]
pub struct ModelSpace {
    items: Vec<ModelArtifact>,
}

impl ModelSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store a model, returning its id.
    pub fn store(
        &mut self,
        name: impl Into<String>,
        producer: impl Into<String>,
        parent: Option<ModelId>,
        payload: ModelPayload,
    ) -> ModelId {
        let id = self.items.len() as ModelId;
        self.items.push(ModelArtifact {
            id,
            name: name.into(),
            producer: producer.into(),
            parent,
            payload,
            metrics: BTreeMap::new(),
            supporting: Vec::new(),
        });
        id
    }

    pub fn get(&self, id: ModelId) -> Result<&ModelArtifact> {
        self.items
            .get(id as usize)
            .ok_or_else(|| Error::ModelSpace(format!("no model #{id}")))
    }

    pub fn get_mut(&mut self, id: ModelId) -> Result<&mut ModelArtifact> {
        self.items
            .get_mut(id as usize)
            .ok_or_else(|| Error::ModelSpace(format!("no model #{id}")))
    }

    pub fn set_metric(&mut self, id: ModelId, name: &str, value: f64) -> Result<()> {
        self.get_mut(id)?.metrics.insert(name.to_string(), value);
        Ok(())
    }

    pub fn add_supporting(
        &mut self,
        id: ModelId,
        file: impl Into<String>,
        content: impl Into<String>,
    ) -> Result<()> {
        self.get_mut(id)?.supporting.push((file.into(), content.into()));
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ModelArtifact> {
        self.items.iter()
    }

    /// Most recently stored model at an abstraction level.
    pub fn latest(&self, abstraction: Abstraction) -> Option<&ModelArtifact> {
        self.items.iter().rev().find(|m| m.abstraction() == abstraction)
    }

    /// Latest value of `metric` among artifacts stored by `producer`
    /// (guard-predicate fallback when a task recorded a metric on its
    /// artifact but not in the LOG).
    pub fn latest_metric(&self, producer: &str, metric: &str) -> Option<f64> {
        self.items
            .iter()
            .rev()
            .filter(|m| m.producer == producer)
            .find_map(|m| m.metric(metric))
    }

    /// Ancestry chain of a model, oldest first (lineage for reports).
    pub fn lineage(&self, id: ModelId) -> Result<Vec<ModelId>> {
        let mut chain = vec![id];
        let mut cur = id;
        while let Some(parent) = self.get(cur)?.parent {
            if chain.contains(&parent) {
                return Err(Error::ModelSpace("lineage cycle".into()));
            }
            chain.push(parent);
            cur = parent;
        }
        chain.reverse();
        Ok(chain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::Precision;

    fn dnn_payload() -> ModelPayload {
        ModelPayload::Dnn(ModelState {
            tag: "t".into(),
            params: vec![],
            masks: vec![],
            precisions: vec![Precision::DISABLED],
            weight_param_idx: vec![],
        })
    }

    #[test]
    fn store_get_metrics() {
        let mut sp = ModelSpace::new();
        let id = sp.store("m0", "model-gen", None, dnn_payload());
        sp.set_metric(id, "accuracy", 0.76).unwrap();
        assert_eq!(sp.get(id).unwrap().metric("accuracy"), Some(0.76));
        assert_eq!(sp.get(id).unwrap().abstraction(), Abstraction::Dnn);
        assert!(sp.get(99).is_err());
    }

    #[test]
    fn latest_by_abstraction() {
        let mut sp = ModelSpace::new();
        let a = sp.store("m0", "gen", None, dnn_payload());
        let b = sp.store("m1", "prune", Some(a), dnn_payload());
        assert_eq!(sp.latest(Abstraction::Dnn).unwrap().id, b);
        assert!(sp.latest(Abstraction::Rtl).is_none());
    }

    #[test]
    fn latest_metric_by_producer() {
        let mut sp = ModelSpace::new();
        let a = sp.store("m0", "gen", None, dnn_payload());
        sp.set_metric(a, "accuracy", 0.7).unwrap();
        let b = sp.store("m1", "gen", Some(a), dnn_payload());
        sp.set_metric(b, "accuracy", 0.75).unwrap();
        let c = sp.store("m2", "prune", Some(b), dnn_payload());
        sp.set_metric(c, "accuracy", 0.74).unwrap();
        assert_eq!(sp.latest_metric("gen", "accuracy"), Some(0.75));
        assert_eq!(sp.latest_metric("prune", "accuracy"), Some(0.74));
        assert_eq!(sp.latest_metric("gen", "missing"), None);
        assert_eq!(sp.latest_metric("nope", "accuracy"), None);
    }

    #[test]
    fn lineage_chain() {
        let mut sp = ModelSpace::new();
        let a = sp.store("m0", "gen", None, dnn_payload());
        let b = sp.store("m1", "prune", Some(a), dnn_payload());
        let c = sp.store("m2", "quant", Some(b), dnn_payload());
        assert_eq!(sp.lineage(c).unwrap(), vec![a, b, c]);
        assert_eq!(sp.lineage(a).unwrap(), vec![a]);
    }

    #[test]
    fn typed_payload_accessors() {
        let mut sp = ModelSpace::new();
        let id = sp.store("m0", "gen", None, dnn_payload());
        assert!(sp.get(id).unwrap().dnn().is_ok());
        assert!(sp.get(id).unwrap().hls().is_err());
        assert!(sp.get(id).unwrap().rtl().is_err());
    }
}
