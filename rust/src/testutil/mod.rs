//! Mini property-testing helper (proptest is not in the offline crate
//! set).  Runs a closure against N seeded random cases via the crate's
//! own deterministic [crate::util::Prng]; failures report the seed so a
//! case can be replayed by construction.
//!
//! ```
//! metaml::testutil::check(100, |rng| {
//!     let n = 1 + rng.below(40);
//!     /* build a case, assert an invariant, or return Err(msg) */
//!     Ok(())
//! });
//! ```

use crate::util::Prng;

/// Run `prop` against `cases` seeded random cases; panics with the seed
/// of the first failing case.
pub fn check<F>(cases: usize, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |rng| {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_case() {
        check(10, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x = {x}");
            Ok(())
        });
    }
}
