//! Pruning substrate: magnitude masks + the auto-pruning binary search.

pub mod mask;
pub mod search;

pub use mask::global_magnitude_masks;
pub use search::{autoprune, AutopruneConfig, PruneProbe, PruneTrace};
