//! Magnitude-based pruning masks.
//!
//! Global magnitude pruning (TF-MOT-equivalent): rank every weight across
//! all maskable tensors by |w| and zero the smallest `rate` fraction.
//! Biases are never pruned (they are not mask-aligned).

use crate::error::Result;
use crate::model::ModelState;
use crate::runtime::HostTensor;

/// Build masks pruning the globally-smallest `rate` fraction of weights.
///
/// Returns one {0,1} f32 mask per weight tensor, in mask order.
pub fn global_magnitude_masks(state: &ModelState, rate: f64) -> Result<Vec<HostTensor>> {
    let rate = rate.clamp(0.0, 1.0);
    // gather |w| over all weight tensors
    let mut magnitudes: Vec<f32> = Vec::new();
    for l in 0..state.n_weight_layers() {
        magnitudes.extend(state.weight(l).as_f32()?.iter().map(|v| v.abs()));
    }
    if magnitudes.is_empty() {
        return Ok(vec![]);
    }
    let k = ((magnitudes.len() as f64) * rate).round() as usize;
    let threshold = if k == 0 {
        -1.0f32 // keep everything (all |w| >= 0 > -1)
    } else if k >= magnitudes.len() {
        f32::INFINITY
    } else {
        // k-th smallest magnitude = pruning threshold
        let mut sorted = magnitudes.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[k - 1]
    };

    let mut masks = Vec::with_capacity(state.n_weight_layers());
    let mut pruned_so_far = 0usize;
    let target = k;
    for l in 0..state.n_weight_layers() {
        let w = state.weight(l).as_f32()?;
        let mut data = Vec::with_capacity(w.len());
        for &v in w {
            // strict threshold with tie-budget: prune while |w| <= thr and
            // budget remains (exact-rate invariant under ties)
            if v.abs() <= threshold && pruned_so_far < target {
                data.push(0.0);
                pruned_so_far += 1;
            } else {
                data.push(1.0);
            }
        }
        masks.push(HostTensor::F32 {
            shape: state.weight(l).shape().to_vec(),
            data,
        });
    }
    Ok(masks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::Precision;

    fn state_with_weights(w0: Vec<f32>, w1: Vec<f32>) -> ModelState {
        ModelState {
            tag: "t".into(),
            params: vec![
                HostTensor::F32 { shape: vec![w0.len()], data: w0 },
                HostTensor::F32 { shape: vec![w1.len()], data: w1 },
            ],
            masks: vec![
                HostTensor::ones(&[4]),
                HostTensor::ones(&[4]),
            ],
            precisions: vec![Precision::DISABLED; 2],
            weight_param_idx: vec![0, 1],
        }
    }

    #[test]
    fn rate_zero_keeps_all() {
        let s = state_with_weights(vec![0.0, 0.1, 0.2, 0.3], vec![1.0, 2.0, 3.0, 4.0]);
        let masks = global_magnitude_masks(&s, 0.0).unwrap();
        assert!(masks.iter().all(|m| m.zero_fraction() == 0.0));
    }

    #[test]
    fn rate_one_prunes_all() {
        let s = state_with_weights(vec![0.5; 4], vec![1.0; 4]);
        let masks = global_magnitude_masks(&s, 1.0).unwrap();
        assert!(masks.iter().all(|m| m.zero_fraction() == 1.0));
    }

    #[test]
    fn prunes_smallest_globally() {
        let s = state_with_weights(
            vec![0.01, 0.02, 5.0, 6.0],
            vec![0.03, 7.0, 8.0, 9.0],
        );
        let masks = global_magnitude_masks(&s, 3.0 / 8.0).unwrap();
        // the three smallest magnitudes are 0.01, 0.02 (layer 0), 0.03 (layer 1)
        assert_eq!(masks[0].as_f32().unwrap(), &[0.0, 0.0, 1.0, 1.0]);
        assert_eq!(masks[1].as_f32().unwrap(), &[0.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn exact_rate_under_ties() {
        let s = state_with_weights(vec![1.0; 4], vec![1.0; 4]);
        let masks = global_magnitude_masks(&s, 0.5).unwrap();
        let zeros: usize = masks
            .iter()
            .map(|m| m.as_f32().unwrap().iter().filter(|v| **v == 0.0).count())
            .sum();
        assert_eq!(zeros, 4);
    }

    #[test]
    fn rate_monotonicity() {
        let s = state_with_weights(
            vec![0.1, 0.4, 0.2, 0.9],
            vec![0.5, 0.7, 0.3, 0.8],
        );
        let mut prev_zeros = 0;
        for rate in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let masks = global_magnitude_masks(&s, rate).unwrap();
            let zeros: usize = masks
                .iter()
                .map(|m| m.as_f32().unwrap().iter().filter(|v| **v == 0.0).count())
                .sum();
            assert!(zeros >= prev_zeros);
            prev_zeros = zeros;
        }
        assert_eq!(prev_zeros, 8);
    }
}
