//! The auto-pruning binary search (paper §V-B, Figs 3–4).
//!
//! Objective:  maximize  Pruning_rate
//!             subject to Accuracy_loss(Pruning_rate) ≤ α_p
//!
//! Step 1 (s1) measures the 0%-rate accuracy Acc_p0; subsequent steps
//! binary-search the rate, accepting a probe when the fine-tuned accuracy
//! stays within α_p of Acc_p0 and terminating when the interval shrinks
//! below β_p — giving 1 + log2(1/β_p) steps, exactly the paper's count.
//!
//! Every probe is a pure function of its rate (candidates always prune
//! from the *base* trained weights), so fine-tune probes are submitted
//! through the [`ProbeService`].  Binary search is latency-bound — each
//! step's rate depends on the previous verdict — so with `jobs >= 3`
//! the pool speculatively computes both possible next-step rates in the
//! same batch as the current one and memoizes them; otherwise-idle
//! workers buy the next step for free and the final step's probe is
//! always pre-resolved.  (The trade is bounded: ≤ 2× probe work for a
//! one-batch-shorter critical path; below 3 workers speculation cannot
//! overlap and is skipped.)  The probe trace records only the rates the
//! binary search visits, so it is bit-identical for any worker count.

use std::collections::HashMap;

use crate::dse::{ProbeService, ProbeServiceExt};
use crate::error::Result;
use crate::model::ModelState;
use crate::prune::mask::global_magnitude_masks;
use crate::train::{EvalResult, TrainConfig, Trainer};

#[derive(Debug, Clone)]
pub struct AutopruneConfig {
    /// α_p: tolerated accuracy loss (paper default 2% = 0.02).
    pub tolerate_acc_loss: f64,
    /// β_p: terminate when hi − lo < β_p (paper default 2% = 0.02).
    pub rate_threshold: f64,
    /// Fine-tune epochs per probe.
    pub train_epochs: usize,
    pub seed: u64,
}

impl Default for AutopruneConfig {
    fn default() -> Self {
        AutopruneConfig {
            tolerate_acc_loss: 0.02,
            rate_threshold: 0.02,
            train_epochs: 2,
            seed: 23,
        }
    }
}

/// One probe of the binary search (a point in Fig 3).
#[derive(Debug, Clone)]
pub struct PruneProbe {
    pub step: usize,
    pub rate: f64,
    pub accuracy: f64,
    pub accepted: bool,
    /// Search direction after this probe: +1 rate increased, -1 decreased.
    pub direction: i8,
    /// Non-zero weights per layer of this candidate (for Fig 4 resources).
    pub layer_nnz: Vec<usize>,
}

/// Search result: the accepted state + the full trace (for Figs 3–4).
#[derive(Debug)]
pub struct PruneTrace {
    pub base_accuracy: f64,
    pub best_rate: f64,
    pub best_accuracy: f64,
    pub probes: Vec<PruneProbe>,
}

fn layer_nnz(s: &ModelState) -> Vec<usize> {
    s.masks
        .iter()
        .map(|m| match m.as_f32() {
            Ok(d) => d.iter().filter(|v| **v != 0.0).count(),
            Err(_) => 0,
        })
        .collect()
}

/// Run auto-pruning on `state` in place (leaves the best accepted
/// masks+weights applied).  The trainer supplies fit/evaluate; probe
/// fine-tunes fan out through `pool`.
pub fn autoprune(
    trainer: &Trainer,
    state: &mut ModelState,
    cfg: &AutopruneConfig,
    pool: &dyn ProbeService,
) -> Result<PruneTrace> {
    let fit_cfg = TrainConfig {
        epochs: cfg.train_epochs,
        seed: cfg.seed,
        ..TrainConfig::for_model(&trainer.exec.variant.model)
    };

    // s1: baseline accuracy at 0% rate
    let base = trainer.evaluate(state)?;
    let mut probes = vec![PruneProbe {
        step: 1,
        rate: 0.0,
        accuracy: base.accuracy,
        accepted: true,
        direction: 1,
        layer_nnz: layer_nnz(state),
    }];

    // One probe: prune from the *base* trained weights at `rate`, then
    // fine-tune and evaluate.  Independent of every other probe.
    let base_state: &ModelState = state;
    let probe = |rate: f64| -> Result<(ModelState, EvalResult, Vec<usize>)> {
        let mut cand = base_state.clone();
        cand.masks = global_magnitude_masks(&cand, rate)?;
        cand.apply_masks()?;
        trainer.fit(&mut cand, &fit_cfg)?;
        let eval = trainer.evaluate(&cand)?;
        let nnz = layer_nnz(&cand);
        Ok((cand, eval, nnz))
    };

    let mut lo = 0.0f64; // highest accepted rate
    let mut hi = 1.0f64; // lowest rejected rate
    let mut best_state = base_state.clone();
    let mut best_acc = base.accuracy;
    let mut step = 1usize;
    // memoized probes by exact rate (binary midpoints are exact f64s);
    // holds the speculative lookahead results between steps.  Outcomes
    // stay wrapped in Result so that an error at a speculated rate only
    // propagates if the binary search actually visits that rate — the
    // exact error semantics of the sequential walk, for any jobs value.
    type Probe = (ModelState, EvalResult, Vec<usize>);
    let mut memo: HashMap<u64, Result<Probe>> = HashMap::new();

    while hi - lo > cfg.rate_threshold {
        step += 1;
        let rate = 0.5 * (lo + hi);

        let mut wanted = vec![rate];
        if pool.jobs() >= 3 {
            // speculative one-step lookahead: with enough workers to
            // overlap, also compute the probe each branch outcome would
            // need next (both are in-flight while this step's own probe
            // runs, so the next step — and the final step — hit the memo)
            let next_if_accept = 0.5 * (rate + hi); // lo <- rate
            let next_if_reject = 0.5 * (lo + rate); // hi <- rate
            if hi - rate > cfg.rate_threshold {
                wanted.push(next_if_accept);
            }
            if rate - lo > cfg.rate_threshold {
                wanted.push(next_if_reject);
            }
        }
        let missing: Vec<f64> = wanted
            .into_iter()
            .filter(|r| !memo.contains_key(&r.to_bits()))
            .collect();
        let computed = pool.run_batch(missing.len(), |i| Ok(probe(missing[i])))?;
        for (r, result) in missing.iter().zip(computed) {
            memo.insert(r.to_bits(), result);
        }

        // take ownership of this step's probe (evicting it), so the
        // accepted state moves instead of cloning
        let (cand, eval, nnz) = memo
            .remove(&rate.to_bits())
            .expect("current rate was just probed")?;
        let ok = base.accuracy - eval.accuracy <= cfg.tolerate_acc_loss;
        probes.push(PruneProbe {
            step,
            rate,
            accuracy: eval.accuracy,
            accepted: ok,
            direction: if ok { 1 } else { -1 },
            layer_nnz: nnz,
        });
        if ok {
            lo = rate;
            best_state = cand;
            best_acc = eval.accuracy;
        } else {
            hi = rate;
        }
        // speculated rates outside the surviving interval can never be
        // visited; drop their states to bound memo memory
        memo.retain(|&bits, _| {
            let r = f64::from_bits(bits);
            r > lo && r < hi
        });
    }

    *state = best_state;
    Ok(PruneTrace {
        base_accuracy: base.accuracy,
        best_rate: lo,
        best_accuracy: best_acc,
        probes,
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn expected_step_count() {
        // paper: steps = 1 + log2(1/β); β=2% → 1 + ~5.6 → 7 probes
        // interval halves from 1.0: after n probes width = 2^-n
        // terminates when width < 0.02 → n = 6 probes + s1 = 7
        let beta = 0.02f64;
        let n_probes = (1.0f64 / beta).log2().ceil() as usize;
        assert_eq!(n_probes, 6);
    }
}
