//! MetaML CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map onto the paper's workflows:
//!   `list-tasks`                       Table I task registry
//!   `train --model jet_dnn`            KERAS-MODEL-GEN equivalent
//!   `run-flow --flow <spec.json>`      execute a design flow from config
//!   `synth --model jet_dnn`            HLS4ML + VIVADO-HLS report only
//!   `smoke`                            runtime round-trip check

use metaml::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "smoke" => cmd_smoke(),
        "train" => cmd_train(&args[1..]),
        "list-tasks" => cmd_list_tasks(),
        "run-flow" => cmd_run_flow(&args[1..]),
        "synth" => cmd_synth(&args[1..]),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "metaml {} — cross-stage design-flow automation (FPL'23 reproduction)

USAGE: metaml <COMMAND> [OPTIONS]

COMMANDS:
  smoke                         verify the execution backend + artifacts
  train       --model <name> [--scale S] [--epochs N]   train via AOT step
  list-tasks                    print the pipe-task registry (Table I)
  run-flow    --flow <spec.json> [--model <name>] [--jobs N]
                                execute a design flow; --jobs sets the DSE
                                probe worker count for all O-tasks
  synth       --model <name> [--scale S]                HLS+RTL report
  help                          this message

Artifacts are read from ./artifacts (build with `make artifacts`).
The execution backend is selected by METAML_BACKEND: `reference`
(default, pure-Rust interpreter) or `xla` (PJRT, needs --features xla).
DSE probe workers: --jobs > METAML_JOBS > available parallelism; search
results are bit-identical for every worker count.",
        metaml::version()
    );
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

/// Parse an optional `--flag value` argument, turning malformed values
/// into a clean [`metaml::Error`] instead of a panic.
fn parse_opt<T: std::str::FromStr>(args: &[String], name: &str) -> Result<Option<T>> {
    match opt(args, name) {
        None => Ok(None),
        Some(s) => s.parse::<T>().map(Some).map_err(|_| {
            metaml::Error::other(format!(
                "invalid value {s:?} for {name} (expected {})",
                std::any::type_name::<T>()
            ))
        }),
    }
}

/// `--jobs N` with N >= 1 (the DSE probe worker count).
fn parse_jobs(args: &[String]) -> Result<Option<usize>> {
    match parse_opt::<usize>(args, "--jobs")? {
        Some(0) => Err(metaml::Error::other("--jobs must be at least 1")),
        other => Ok(other),
    }
}

fn artifacts_dir() -> String {
    std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn cmd_smoke() -> Result<()> {
    use metaml::data::{Dataset, DatasetSpec};
    use metaml::model::ModelState;
    use metaml::runtime::{Manifest, ModelExecutable, Runtime};
    use metaml::train::{TrainConfig, Trainer};

    let manifest = Manifest::load(artifacts_dir())?;
    println!("manifest: {} variants", manifest.variants.len());
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());

    let variant = manifest.variant("jet_dnn", 1.0)?;
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag)?;
    let spec = DatasetSpec::for_model(&variant.model, &variant.input_shape, variant.n_classes);
    let data = Dataset::generate(&spec);
    let mut state = ModelState::init(variant, 7);
    let trainer = Trainer::new(&runtime, &exec, &data);
    let before = trainer.evaluate(&state)?;
    println!("before: loss {:.4} acc {:.4}", before.loss, before.accuracy);
    trainer.fit(&mut state, &TrainConfig { epochs: 2, verbose: true, ..Default::default() })?;
    let after = trainer.evaluate(&state)?;
    println!("after : loss {:.4} acc {:.4}", after.loss, after.accuracy);
    let stats = runtime.stats();
    println!(
        "runtime: {} compiles ({:.2}s), {} executions ({:.3}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    if after.accuracy <= before.accuracy {
        return Err(metaml::Error::other("smoke: training did not improve accuracy"));
    }
    println!("smoke OK");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    use metaml::data::{Dataset, DatasetSpec};
    use metaml::model::ModelState;
    use metaml::runtime::{Manifest, ModelExecutable, Runtime};
    use metaml::train::{TrainConfig, Trainer};

    let model = opt(args, "--model").unwrap_or_else(|| "jet_dnn".into());
    let scale: f64 = parse_opt(args, "--scale")?.unwrap_or(1.0);
    let epochs: usize = parse_opt(args, "--epochs")?.unwrap_or(5);

    let manifest = Manifest::load(artifacts_dir())?;
    let runtime = Runtime::cpu()?;
    let variant = manifest.variant(&model, scale)?;
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag)?;
    let spec = DatasetSpec::for_model(&variant.model, &variant.input_shape, variant.n_classes);
    let data = Dataset::generate(&spec);
    let mut state = ModelState::init(variant, 7);
    let trainer = Trainer::new(&runtime, &exec, &data);
    println!("training {} for {epochs} epochs on {}", variant.tag, spec.name);
    trainer.fit(
        &mut state,
        &{ let mut c = TrainConfig::for_model(&variant.model); c.epochs = epochs; c.verbose = true; c },
    )?;
    let eval = trainer.evaluate(&state)?;
    println!("test: loss {:.4} acc {:.4} (n={})", eval.loss, eval.accuracy, eval.n);
    Ok(())
}

fn cmd_list_tasks() -> Result<()> {
    let registry = metaml::flow::TaskRegistry::builtin();
    println!("Implemented pipe tasks (paper Table I):\n");
    print!("{}", registry.table());
    println!("\nBuilt-in flows: {}", metaml::config::builtin_flow_names().join(", "));
    Ok(())
}

fn cmd_run_flow(args: &[String]) -> Result<()> {
    use metaml::config::{builtin_flow, FlowSpec};
    use metaml::flow::{Engine, Session, TaskRegistry};
    use metaml::metamodel::MetaModel;

    let flow_arg = opt(args, "--flow").unwrap_or_else(|| "pruning".into());
    let spec = if flow_arg.ends_with(".json") {
        FlowSpec::load(&flow_arg)?
    } else {
        builtin_flow(&flow_arg)?
    };

    let session = Session::open(&artifacts_dir())?;
    let registry = TaskRegistry::builtin();
    let mut meta = MetaModel::new();
    meta.log.echo = true;
    spec.apply_cfg(&mut meta.cfg);
    if let Some(model) = opt(args, "--model") {
        meta.cfg.set("model", model);
    }
    // DSE probe worker count for every O-task in the flow (global CFG
    // key; instance-scoped `-c <task>.jobs=N` overrides still win)
    if let Some(jobs) = parse_jobs(args)? {
        meta.cfg.set("jobs", jobs);
    }
    // pass-through -c key=value overrides
    for i in 0..args.len() {
        if args[i] == "-c" {
            if let Some(kv) = args.get(i + 1) {
                if let Some((k, v)) = kv.split_once('=') {
                    if let Ok(n) = v.parse::<f64>() {
                        meta.cfg.set(k, n);
                    } else {
                        meta.cfg.set(k, v);
                    }
                }
            }
        }
    }

    println!("running flow '{}'", spec.graph.name);
    let engine = Engine::new(&session, &registry);
    engine.run(&spec.graph, &mut meta)?;

    println!("\nmodel space ({} artifacts):", meta.space.len());
    for m in meta.space.iter() {
        let metrics: Vec<String> = m
            .metrics
            .iter()
            .map(|(k, v)| format!("{k}={v:.4}"))
            .collect();
        println!(
            "  #{} [{}] {} (by {}) {}",
            m.id,
            m.abstraction(),
            m.name,
            m.producer,
            metrics.join(" ")
        );
    }
    Ok(())
}

fn cmd_synth(args: &[String]) -> Result<()> {
    use metaml::flow::{Engine, Session, TaskRegistry};
    use metaml::metamodel::MetaModel;

    let model = opt(args, "--model").unwrap_or_else(|| "jet_dnn".into());
    let scale: f64 = parse_opt(args, "--scale")?.unwrap_or(1.0);
    let device = opt(args, "--device").unwrap_or_else(|| "vu9p".into());

    let session = Session::open(&artifacts_dir())?;
    let registry = TaskRegistry::builtin();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", model);
    meta.cfg.set("scale", scale);
    meta.cfg.set("FPGA_part_number", device);
    let spec = metaml::config::builtin_flow("baseline")?;
    Engine::new(&session, &registry).run(&spec.graph, &mut meta)?;
    let rtl = meta
        .space
        .latest(metaml::metamodel::Abstraction::Rtl)
        .ok_or_else(|| metaml::Error::other("no RTL artifact produced"))?;
    println!("{}", metaml::synth::report::render(rtl.rtl()?));
    Ok(())
}
