//! MetaML CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map onto the paper's workflows:
//!   `list-tasks`                       Table I task registry
//!   `train --model jet_dnn`            KERAS-MODEL-GEN equivalent
//!   `run-flow --flow <spec.json>`      execute a design flow from config
//!   `explore --flow <spec.json>`       run the spec's variant grid + Pareto front
//!   `synth --model jet_dnn`            HLS4ML + VIVADO-HLS report only
//!   `smoke`                            runtime round-trip check
//!
//! Unknown options are rejected with a hint (a typo like `--job 4`
//! must not silently change a run).

use metaml::json::Value;
use metaml::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "smoke" => cmd_smoke(&args[1..]),
        "train" => cmd_train(&args[1..]),
        "list-tasks" => cmd_list_tasks(&args[1..]),
        "run-flow" => cmd_run_flow(&args[1..]),
        "explore" => cmd_explore(&args[1..]),
        "cache" => cmd_cache(&args[1..]),
        "trace" => cmd_trace(&args[1..]),
        "synth" => cmd_synth(&args[1..]),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "metaml {} — cross-stage design-flow automation (FPL'23 reproduction)

USAGE: metaml <COMMAND> [OPTIONS]

COMMANDS:
  smoke                         verify the execution backend + artifacts
  train       --model <name> [--scale S] [--epochs N]   train via AOT step
  list-tasks                    print the pipe-task registry (Table I)
  run-flow    --flow <spec.json> [--model <name>] [--jobs N] [--synthetic]
              [-c k=v]...       execute a design flow; --jobs sets the DSE
                                probe worker count for all O-tasks;
                                --synthetic uses the in-memory jet manifest
  explore     --flow <spec.json> [--model <name>] [--jobs N] [--synthetic]
              [--strategy S] [--budget N] [--seed S] [--surrogate]
              [--warmup N] [--cache-dir DIR]
              [--trace-out FILE] [--metrics-out FILE]
              [-c k=v]...       search the spec's variant space and print
                                the (accuracy, DSP, LUT, latency) Pareto
                                front; --strategy picks exhaustive |
                                random | evolve (overriding the spec's
                                `search` section), --budget bounds the
                                flow evaluations spent, --seed fixes the
                                sampler PRNG; --surrogate enables the
                                online learned predictor (proposals whose
                                predicted objectives are dominated skip
                                the flow run entirely), --warmup sets its
                                real evaluations before predictions gate
                                anything (implies --surrogate);
                                --cache-dir persists probe results on
                                disk so a repeated search recomputes
                                nothing; --synthetic uses the in-memory
                                jet manifest (no artifacts needed); a CSV
                                of the evaluated variants lands in
                                report/; --trace-out writes a Chrome
                                trace-event JSON of the run (flow tasks,
                                search rounds, probe queue/execute,
                                cache tiers), --metrics-out the metrics
                                registry snapshot
  cache       stats|clear --cache-dir DIR   inspect or delete the
                                persistent probe-result store
  trace       summary <trace.json>   per-span-name table (count, total,
                                mean) + cache-tier table for a trace
                                written by --trace-out
  synth       --model <name> [--scale S] [--device D] [--clock NS]
              [--reuse RF]   HLS+RTL report with fit/utilization; --clock
                             sets the target period (ns), --reuse the
                             initial reuse factor (snapped per layer)
  help                          this message

Artifacts are read from ./artifacts (build with `make artifacts`).
The execution backend is selected by METAML_BACKEND: `reference`
(default, pure-Rust interpreter) or `xla` (PJRT, needs --features xla).
DSE probe workers: --jobs > METAML_JOBS > available parallelism; search
results and flow LOGs are bit-identical for every worker count.
Tracing: METAML_TRACE=1 records spans (METAML_TRACE=kernels adds
per-matmul spans); tracing is side-band and never changes results.",
        metaml::version()
    );
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse an optional `--flag value` argument, turning malformed values
/// into a clean [`metaml::Error`] instead of a panic.
fn parse_opt<T: std::str::FromStr>(args: &[String], name: &str) -> Result<Option<T>> {
    match opt(args, name) {
        None => Ok(None),
        Some(s) => s.parse::<T>().map(Some).map_err(|_| {
            metaml::Error::other(format!(
                "invalid value {s:?} for {name} (expected {})",
                std::any::type_name::<T>()
            ))
        }),
    }
}

/// `--jobs N` with N >= 1 (the DSE probe worker count).
fn parse_jobs(args: &[String]) -> Result<Option<usize>> {
    match parse_opt::<usize>(args, "--jobs")? {
        Some(0) => Err(metaml::Error::other("--jobs must be at least 1")),
        other => Ok(other),
    }
}

/// Strict option validation: every token must be a known flag (with its
/// value, when it takes one).  Typos fail loudly with a best-effort
/// "did you mean" hint instead of being silently ignored.
fn check_flags(cmd: &str, args: &[String], allowed: &[(&str, bool)]) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if let Some((name, takes_value)) = allowed.iter().find(|(n, _)| *n == a) {
            if *takes_value {
                // another option is not a value: `--model --synthetic`
                // must fail here, or the naive opt()/flag() scans would
                // double-interpret the token ("-"-prefixed numbers stay
                // legal values)
                match args.get(i + 1) {
                    None => {
                        return Err(metaml::Error::other(format!(
                            "option {name} expects a value"
                        )));
                    }
                    Some(v) if v.starts_with("--") => {
                        return Err(metaml::Error::other(format!(
                            "option {name} expects a value, got option {v:?}"
                        )));
                    }
                    Some(_) => {}
                }
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        let valid = allowed
            .iter()
            .map(|(n, _)| *n)
            .collect::<Vec<_>>()
            .join(", ");
        let msg = if a.starts_with('-') {
            let hint = allowed
                .iter()
                .map(|(n, _)| *n)
                .min_by_key(|n| edit_distance(a, n))
                .filter(|n| edit_distance(a, n) <= 2)
                .map(|n| format!(" (did you mean {n:?}?)"))
                .unwrap_or_default();
            if valid.is_empty() {
                format!("unknown option {a:?}: {cmd} takes no options")
            } else {
                format!("unknown option {a:?} for {cmd}{hint}; valid options: {valid}")
            }
        } else {
            format!("unexpected argument {a:?} for {cmd}")
        };
        return Err(metaml::Error::other(msg));
    }
    Ok(())
}

/// Plain Levenshtein distance (tiny inputs; used only for CLI hints).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// Collect `-c key=value` overrides (numbers become Number values).
/// A `-c` argument without `=` is an error, not a silent no-op.
fn cfg_overrides(args: &[String]) -> Result<Vec<(String, Value)>> {
    let mut out = Vec::new();
    for i in 0..args.len() {
        if args[i] == "-c" {
            let kv = args.get(i + 1).ok_or_else(|| {
                metaml::Error::other("option -c expects a value")
            })?;
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                metaml::Error::other(format!(
                    "malformed -c override {kv:?} (expected key=value)"
                ))
            })?;
            let value = match v.parse::<f64>() {
                Ok(n) => Value::Number(n),
                Err(_) => Value::String(v.to_string()),
            };
            out.push((k.to_string(), value));
        }
    }
    Ok(out)
}

fn artifacts_dir() -> String {
    std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn report_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("METAML_REPORT_OUT").unwrap_or_else(|_| "report".into()),
    )
}

/// Load a flow spec: a JSON path or a builtin name.
fn load_spec(flow_arg: &str) -> Result<metaml::config::FlowSpec> {
    if flow_arg.ends_with(".json") {
        metaml::config::FlowSpec::load(flow_arg)
    } else {
        metaml::config::builtin_flow(flow_arg)
    }
}

/// Session over real artifacts, or the in-memory synthetic jet manifest
/// (scale grid included) when `--synthetic` is given.
fn open_session(synthetic: bool) -> Result<metaml::flow::Session> {
    use metaml::flow::Session;
    if synthetic {
        let manifest = metaml::bench_support::synthetic_jet_manifest_scales(&[1.0, 0.75, 0.5]);
        Ok(Session::with_backend(metaml::runtime::Runtime::cpu()?, manifest))
    } else {
        Session::open(&artifacts_dir())
    }
}

fn cmd_smoke(args: &[String]) -> Result<()> {
    check_flags("smoke", args, &[])?;
    use metaml::data::{Dataset, DatasetSpec};
    use metaml::model::ModelState;
    use metaml::runtime::{Manifest, ModelExecutable, Runtime};
    use metaml::train::{TrainConfig, Trainer};

    let manifest = Manifest::load(artifacts_dir())?;
    println!("manifest: {} variants", manifest.variants.len());
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform());

    let variant = manifest.variant("jet_dnn", 1.0)?;
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag)?;
    let spec = DatasetSpec::for_model(&variant.model, &variant.input_shape, variant.n_classes);
    let data = Dataset::generate(&spec);
    let mut state = ModelState::init(variant, 7);
    let trainer = Trainer::new(&runtime, &exec, &data);
    let before = trainer.evaluate(&state)?;
    println!("before: loss {:.4} acc {:.4}", before.loss, before.accuracy);
    trainer.fit(&mut state, &TrainConfig { epochs: 2, verbose: true, ..Default::default() })?;
    let after = trainer.evaluate(&state)?;
    println!("after : loss {:.4} acc {:.4}", after.loss, after.accuracy);
    let stats = runtime.stats();
    println!(
        "runtime: {} compiles ({:.2}s), {} executions ({:.3}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    if after.accuracy <= before.accuracy {
        return Err(metaml::Error::other("smoke: training did not improve accuracy"));
    }
    println!("smoke OK");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    check_flags(
        "train",
        args,
        &[("--model", true), ("--scale", true), ("--epochs", true)],
    )?;
    use metaml::data::{Dataset, DatasetSpec};
    use metaml::model::ModelState;
    use metaml::runtime::{Manifest, ModelExecutable, Runtime};
    use metaml::train::{TrainConfig, Trainer};

    let model = opt(args, "--model").unwrap_or_else(|| "jet_dnn".into());
    let scale: f64 = parse_opt(args, "--scale")?.unwrap_or(1.0);
    let epochs: usize = parse_opt(args, "--epochs")?.unwrap_or(5);

    let manifest = Manifest::load(artifacts_dir())?;
    let runtime = Runtime::cpu()?;
    let variant = manifest.variant(&model, scale)?;
    let exec = ModelExecutable::load(&runtime, &manifest, &variant.tag)?;
    let spec = DatasetSpec::for_model(&variant.model, &variant.input_shape, variant.n_classes);
    let data = Dataset::generate(&spec);
    let mut state = ModelState::init(variant, 7);
    let trainer = Trainer::new(&runtime, &exec, &data);
    println!("training {} for {epochs} epochs on {}", variant.tag, spec.name);
    trainer.fit(
        &mut state,
        &{ let mut c = TrainConfig::for_model(&variant.model); c.epochs = epochs; c.verbose = true; c },
    )?;
    let eval = trainer.evaluate(&state)?;
    println!("test: loss {:.4} acc {:.4} (n={})", eval.loss, eval.accuracy, eval.n);
    Ok(())
}

fn cmd_list_tasks(args: &[String]) -> Result<()> {
    check_flags("list-tasks", args, &[])?;
    let registry = metaml::flow::TaskRegistry::builtin();
    println!("Implemented pipe tasks (paper Table I):\n");
    print!("{}", registry.table());
    println!("\nBuilt-in flows: {}", metaml::config::builtin_flow_names().join(", "));
    Ok(())
}

fn cmd_run_flow(args: &[String]) -> Result<()> {
    check_flags(
        "run-flow",
        args,
        &[
            ("--flow", true),
            ("--model", true),
            ("--jobs", true),
            ("--synthetic", false),
            ("-c", true),
        ],
    )?;
    use metaml::flow::{Engine, TaskRegistry};
    use metaml::metamodel::MetaModel;

    let flow_arg = opt(args, "--flow").unwrap_or_else(|| "pruning".into());
    let spec = load_spec(&flow_arg)?;

    let session = open_session(flag(args, "--synthetic"))?;
    let registry = TaskRegistry::builtin();
    let mut meta = MetaModel::new();
    meta.log.echo = true;
    spec.apply_cfg(&mut meta.cfg);
    if let Some(model) = opt(args, "--model") {
        meta.cfg.set("model", model);
    }
    // DSE probe worker count for every O-task in the flow (global CFG
    // key; instance-scoped `-c <task>.jobs=N` overrides still win)
    if let Some(jobs) = parse_jobs(args)? {
        meta.cfg.set("jobs", jobs);
    }
    for (k, v) in cfg_overrides(args)? {
        meta.cfg.set(k, v);
    }

    println!("running flow '{}'", spec.graph.name);
    let engine = Engine::new(&session, &registry);
    engine.run_spec(&spec, &mut meta)?;

    println!("\nmodel space ({} artifacts):", meta.space.len());
    for m in meta.space.iter() {
        let metrics: Vec<String> = m
            .metrics
            .iter()
            .map(|(k, v)| format!("{k}={v:.4}"))
            .collect();
        println!(
            "  #{} [{}] {} (by {}) {}",
            m.id,
            m.abstraction(),
            m.name,
            m.producer,
            metrics.join(" ")
        );
    }
    Ok(())
}

fn cmd_explore(args: &[String]) -> Result<()> {
    check_flags(
        "explore",
        args,
        &[
            ("--flow", true),
            ("--model", true),
            ("--jobs", true),
            ("--synthetic", false),
            ("--strategy", true),
            ("--budget", true),
            ("--seed", true),
            ("--surrogate", false),
            ("--warmup", true),
            ("--cache-dir", true),
            ("--trace-out", true),
            ("--metrics-out", true),
            ("-c", true),
        ],
    )?;
    use metaml::dse::{DiskStore, ProbeTiers};
    use metaml::flow::explore::{front_csv, front_table};
    use metaml::flow::TaskRegistry;
    use metaml::obs::{metrics, trace};
    use metaml::search::{run_search_tiered, strategy_names};
    use std::sync::Arc;

    // tracing is opt-in (env or --trace-out) and strictly side-band;
    // the metrics registry is always on, cleared here so the exported
    // snapshot covers exactly this run
    trace::configure_from_env();
    let trace_out = opt(args, "--trace-out");
    let metrics_out = opt(args, "--metrics-out");
    if trace_out.is_some() {
        trace::enable();
    }
    trace::reset();
    metrics::reset();

    let flow_arg = opt(args, "--flow").unwrap_or_else(|| "s_p_q".into());
    let spec = load_spec(&flow_arg)?;
    let session = open_session(flag(args, "--synthetic"))?;
    let registry = TaskRegistry::builtin();
    let jobs = parse_jobs(args)?.unwrap_or_else(metaml::dse::default_jobs);

    let mut extra = Vec::new();
    if let Some(model) = opt(args, "--model") {
        extra.push(("model".to_string(), Value::String(model)));
    }
    extra.extend(cfg_overrides(args)?);

    // spec `search` section (default: exhaustive full grid), with CLI
    // overrides on top
    let mut search = spec.search.clone().unwrap_or_default();
    if let Some(strategy) = opt(args, "--strategy") {
        if !strategy_names().contains(&strategy.as_str()) {
            return Err(metaml::Error::other(format!(
                "unknown --strategy {strategy:?} (expected one of: {})",
                strategy_names().join(", ")
            )));
        }
        search.strategy = strategy;
    }
    if let Some(budget) = parse_opt::<usize>(args, "--budget")? {
        if budget == 0 {
            return Err(metaml::Error::other("--budget must be at least 1"));
        }
        search.budget = Some(budget);
    }
    if let Some(seed) = parse_opt::<u64>(args, "--seed")? {
        search.seed = seed;
    }
    if flag(args, "--surrogate") && search.surrogate.is_none() {
        search.surrogate = Some(Default::default());
    }
    if let Some(warmup) = parse_opt::<usize>(args, "--warmup")? {
        if warmup == 0 {
            return Err(metaml::Error::other("--warmup must be at least 1"));
        }
        search.surrogate.get_or_insert_with(Default::default).warmup = Some(warmup);
    }

    println!(
        "exploring '{}' with strategy '{}' (budget {}, seed {}, jobs {jobs}{})",
        spec.graph.name,
        search.strategy,
        search
            .budget
            .map(|b| b.to_string())
            .unwrap_or_else(|| "grid".into()),
        search.seed,
        if search.surrogate.is_some() { ", surrogate on" } else { "" },
    );

    // probe tiers: in-memory memos, plus the persistent disk tier when
    // --cache-dir is given (a warm store turns repeat searches into
    // pure cache hits — bit-identical results either way)
    let tiers = match opt(args, "--cache-dir") {
        Some(dir) => {
            let store = Arc::new(DiskStore::open(std::path::Path::new(&dir))?);
            let s = store.stats();
            println!(
                "cache: {} ({} training, {} hardware entries loaded)",
                store.path().display(),
                s.train_entries,
                s.hw_entries,
            );
            ProbeTiers::with_disk(store)
        }
        None => ProbeTiers::new(),
    };

    let out = run_search_tiered(&session, &registry, &spec, &search, &extra, jobs, &tiers)?;

    println!(
        "evaluated {} of {} grid variants ({} proposals of budget {})\n",
        out.evaluations(),
        out.grid_size,
        out.spent,
        out.budget
    );
    println!("Pareto front over (accuracy, DSP, LUT, latency):\n");
    print!("{}", front_table(&out.outcome).render());
    println!(
        "\n{} of {} evaluated variants on the front:",
        out.outcome.front.len(),
        out.outcome.results.len()
    );
    for &i in &out.outcome.front {
        let r = &out.outcome.results[i];
        println!(
            "  * {} (acc {:.4}, {} DSP, {} LUT)",
            r.label,
            r.metric("accuracy").unwrap_or(0.0),
            r.metric("dsp").unwrap_or(0.0) as u64,
            r.metric("lut").unwrap_or(0.0) as u64,
        );
    }
    // hit rate is the one shared definition (cached / issued,
    // ProbeCounts::cache_hit_rate) so the summary and the CSV's
    // *_cache_hit_rate columns agree digit for digit
    let rate = |issued: usize, computed: usize| -> String {
        metaml::dse::ProbeCounts::cache_hit_rate(issued, computed)
            .map(|r| format!("{r:.4}"))
            .unwrap_or_else(|| "-".into())
    };
    println!(
        "probes: {} training issued ({} computed, cache hit rate {}), \
         {} hardware issued ({} computed, cache hit rate {})",
        out.probes.train_issued,
        out.probes.train_computed,
        rate(out.probes.train_issued, out.probes.train_computed),
        out.probes.hw_issued,
        out.probes.hw_computed,
        rate(out.probes.hw_issued, out.probes.hw_computed),
    );
    // wall clock and speculation volumes come out of the metrics
    // registry — the driver records them there instead of threading
    // Instant readings through the call chain
    let wall = metrics::gauge("search.wall_secs").unwrap_or(0.0);
    let computed = out.probes.train_computed + out.probes.hw_computed;
    println!(
        "wall: {:.3} s ({:.1} probes/s)",
        wall,
        computed as f64 / wall.max(1e-9),
    );
    let spec_submitted = metrics::counter("probes.speculation.submitted");
    if spec_submitted > 0 {
        println!(
            "speculation: {} submitted, {} committed, {} cancelled",
            spec_submitted,
            metrics::counter("probes.speculation.committed"),
            metrics::counter("probes.speculation.cancelled"),
        );
    }
    if let Some(s) = &out.surrogate {
        let mae = if s.mean_abs_error.is_empty() {
            "-".to_string()
        } else {
            ["acc", "dsp", "lut", "lat_ns"]
                .iter()
                .zip(&s.mean_abs_error)
                .map(|(n, e)| format!("{n} {e:.4}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        println!(
            "surrogate: {} fits, {} predictions, {} deferred ({} validated, \
             {} probes saved), mean abs err [{mae}]",
            s.fits,
            s.predictions,
            s.deferred,
            s.validated,
            s.probes_saved(),
        );
    }

    let csv_path = report_dir().join(format!("explore_{}.csv", spec.graph.name));
    front_csv(&out.outcome, Some(&out.cost())).save(&csv_path)?;
    println!("\nwrote {}", csv_path.display());

    if let Some(path) = &trace_out {
        let doc = trace::chrome_trace(&trace::drain());
        write_json(path, &doc)?;
        println!("wrote {path}");
    }
    if let Some(path) = &metrics_out {
        write_json(path, &metrics::snapshot())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Write a pretty-printed JSON document, creating parent directories.
fn write_json(path: &str, doc: &Value) -> Result<()> {
    let path = std::path::Path::new(path);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, metaml::json::to_string_pretty(doc))?;
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    use metaml::obs::trace;

    let (action, rest) = match args.split_first() {
        Some((a, rest)) if !a.starts_with('-') => (a.as_str(), rest),
        _ => ("", args),
    };
    match action {
        "summary" => {
            let (file, rest) = rest.split_first().ok_or_else(|| {
                metaml::Error::other("trace summary: a trace file is required")
            })?;
            check_flags("trace", rest, &[])?;
            let text = std::fs::read_to_string(file)?;
            let doc = metaml::json::parse(&text)?;
            println!("spans in {file}:\n");
            print!("{}", trace::summary_table(&doc)?.render());
            if let Some(t) = trace::cache_table(&doc)? {
                println!("\ncache tier lookups:\n");
                print!("{}", t.render());
            }
            Ok(())
        }
        other => Err(metaml::Error::other(format!(
            "trace: unknown action {other:?} (expected summary <trace.json>)"
        ))),
    }
}

fn cmd_cache(args: &[String]) -> Result<()> {
    use metaml::dse::DiskStore;

    let (action, rest) = match args.split_first() {
        Some((a, rest)) if !a.starts_with('-') => (a.as_str(), rest),
        _ => ("", args),
    };
    check_flags("cache", rest, &[("--cache-dir", true)])?;
    let dir = opt(rest, "--cache-dir")
        .ok_or_else(|| metaml::Error::other("cache: --cache-dir <DIR> is required"))?;
    let dir = std::path::PathBuf::from(dir);
    match action {
        "stats" => {
            let s = DiskStore::inspect(&dir);
            println!("store: {}", dir.join("probes.jsonl").display());
            println!("training entries: {}", s.train_entries);
            println!("hardware entries: {}", s.hw_entries);
            println!("skipped lines: {}", s.skipped);
            println!("bytes: {}", s.bytes);
            Ok(())
        }
        "clear" => {
            if DiskStore::clear(&dir)? {
                println!("cleared probe store under {}", dir.display());
            } else {
                println!("no probe store under {}", dir.display());
            }
            Ok(())
        }
        other => Err(metaml::Error::other(format!(
            "cache: unknown action {other:?} (expected stats | clear)"
        ))),
    }
}

fn cmd_synth(args: &[String]) -> Result<()> {
    check_flags(
        "synth",
        args,
        &[
            ("--model", true),
            ("--scale", true),
            ("--device", true),
            ("--clock", true),
            ("--reuse", true),
        ],
    )?;
    use metaml::flow::{Engine, TaskRegistry};
    use metaml::metamodel::MetaModel;

    let model = opt(args, "--model").unwrap_or_else(|| "jet_dnn".into());
    let scale: f64 = parse_opt(args, "--scale")?.unwrap_or(1.0);
    let device = opt(args, "--device").unwrap_or_else(|| "vu9p".into());
    // hardware-stage overrides: target clock period (ns) and initial
    // reuse factor (snapped per layer to a legal divisor of the fan-in)
    let clock: Option<f64> = parse_opt(args, "--clock")?;
    if let Some(c) = clock {
        if c <= 0.0 {
            return Err(metaml::Error::other("--clock must be a positive period in ns"));
        }
    }
    let reuse: Option<usize> = parse_opt(args, "--reuse")?;
    if reuse == Some(0) {
        return Err(metaml::Error::other("--reuse must be at least 1"));
    }

    let session = metaml::flow::Session::open(&artifacts_dir())?;
    let registry = TaskRegistry::builtin();
    let mut meta = MetaModel::new();
    meta.cfg.set("model", model);
    meta.cfg.set("scale", scale);
    meta.cfg.set("FPGA_part_number", device);
    if let Some(c) = clock {
        meta.cfg.set("clock_period", c);
    }
    if let Some(r) = reuse {
        meta.cfg.set("reuse_factor", r);
    }
    let spec = metaml::config::builtin_flow("baseline")?;
    Engine::new(&session, &registry).run_spec(&spec, &mut meta)?;
    let rtl = meta
        .space
        .latest(metaml::metamodel::Abstraction::Rtl)
        .ok_or_else(|| metaml::Error::other("no RTL artifact produced"))?;
    let report = rtl.rtl()?;
    println!("{}", metaml::synth::report::render(report));
    println!(
        "fit: {}  (DSP {:.1}%, LUT {:.1}%, FF {:.1}%, BRAM {:.1}%)  II = {}",
        if report.fits() { "YES" } else { "NO" },
        report.dsp_pct(),
        report.lut_pct(),
        report.ff_pct(),
        report.bram_pct(),
        report.ii,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    const RUN_FLOW: &[(&str, bool)] = &[
        ("--flow", true),
        ("--model", true),
        ("--jobs", true),
        ("--synthetic", false),
        ("-c", true),
    ];

    #[test]
    fn known_flags_pass() {
        let args = s(&["--flow", "s_p_q", "--jobs", "4", "-c", "prune.jobs=2", "--synthetic"]);
        assert!(check_flags("run-flow", &args, RUN_FLOW).is_ok());
    }

    #[test]
    fn unknown_flag_rejected_with_hint() {
        let err = check_flags("run-flow", &s(&["--job", "4"]), RUN_FLOW)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--job"), "{err}");
        assert!(err.contains("--jobs"), "{err}");
        assert!(err.contains("valid options"), "{err}");
    }

    #[test]
    fn positional_garbage_rejected() {
        let err = check_flags("run-flow", &s(&["wat"]), RUN_FLOW)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unexpected argument"), "{err}");
    }

    #[test]
    fn missing_value_rejected() {
        let err = check_flags("run-flow", &s(&["--flow"]), RUN_FLOW)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expects a value"), "{err}");
    }

    #[test]
    fn option_as_value_rejected() {
        // `--model --synthetic` must not set model="--synthetic" AND
        // turn the synthetic session on
        let err = check_flags("run-flow", &s(&["--model", "--synthetic"]), RUN_FLOW)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expects a value"), "{err}");
    }

    #[test]
    fn option_on_optionless_command_rejected() {
        let err = check_flags("smoke", &s(&["--fast"]), &[]).unwrap_err().to_string();
        assert!(err.contains("takes no options"), "{err}");
    }

    #[test]
    fn explore_search_flags_validate_with_hint() {
        const EXPLORE: &[(&str, bool)] = &[
            ("--flow", true),
            ("--model", true),
            ("--jobs", true),
            ("--synthetic", false),
            ("--strategy", true),
            ("--budget", true),
            ("--seed", true),
            ("--surrogate", false),
            ("--warmup", true),
            ("--cache-dir", true),
            ("--trace-out", true),
            ("--metrics-out", true),
            ("-c", true),
        ];
        let ok = s(&[
            "--strategy",
            "evolve",
            "--budget",
            "8",
            "--seed",
            "7",
            "--surrogate",
            "--warmup",
            "4",
            "--cache-dir",
            "/tmp/metaml-cache",
            "--trace-out",
            "/tmp/trace.json",
            "--metrics-out",
            "/tmp/metrics.json",
        ]);
        assert!(check_flags("explore", &ok, EXPLORE).is_ok());
        let err = check_flags("explore", &s(&["--buget", "8"]), EXPLORE)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--budget"), "{err}");
        let err = check_flags("explore", &s(&["--surogate"]), EXPLORE)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--surrogate"), "{err}");
    }

    #[test]
    fn synth_hw_flags_validate_with_hint() {
        const SYNTH: &[(&str, bool)] = &[
            ("--model", true),
            ("--scale", true),
            ("--device", true),
            ("--clock", true),
            ("--reuse", true),
        ];
        let ok = s(&["--device", "zynq7020", "--clock", "10", "--reuse", "4"]);
        assert!(check_flags("synth", &ok, SYNTH).is_ok());
        // typo gets the did-you-mean hint like every other subcommand
        let err = check_flags("synth", &s(&["--reus", "4"]), SYNTH)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--reuse"), "{err}");
    }

    #[test]
    fn edit_distance_sane() {
        assert_eq!(edit_distance("--job", "--jobs"), 1);
        assert_eq!(edit_distance("--jobs", "--jobs"), 0);
        assert!(edit_distance("--flow", "--jobs") > 2);
    }

    #[test]
    fn cfg_overrides_parse_numbers_and_strings() {
        let args = s(&["-c", "prune.tolerate_acc_loss=0.05", "-c", "model=jet_dnn"]);
        let over = cfg_overrides(&args).unwrap();
        assert_eq!(over.len(), 2);
        assert_eq!(over[0].1.as_f64(), Some(0.05));
        assert_eq!(over[1].1.as_str(), Some("jet_dnn"));
    }

    #[test]
    fn cfg_override_without_equals_rejected() {
        let err = cfg_overrides(&s(&["-c", "prune.tolerate_acc_loss"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("key=value"), "{err}");
    }
}
