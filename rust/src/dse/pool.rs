//! Deterministic batch executor for candidate probes.
//!
//! The pool is a *batch* executor: callers hand it an indexed set of
//! independent jobs and get the results back in index order, whatever
//! the worker interleaving was.  Parallelism changes wall-clock only —
//! every job is computed by exactly the same single-threaded code path
//! as under `jobs = 1`, so probe results are bit-identical across
//! worker counts and the metamodel LOG stays reproducible.
//!
//! Built on the persistent [`WorkerPool`] (`dse/workers.rs`, no
//! crates.io dependencies): threads spawn once per pool lifetime and
//! batches flow through a submission queue; workers claim indices from
//! a shared atomic cursor and write results into per-index slots, and
//! single-item or single-job batches bypass the queue entirely and run
//! inline on the caller.

use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::dse::workers::WorkerPool;

use crate::dse::cache::{EvalCache, EvalKey, ProbeCache};
use crate::dse::disk::DiskStore;
use crate::dse::hw::{HwCache, HwEval, HwKey, HwProbeRequest, HwProbeResult};
use crate::dse::service::{ProbeTier, ProbeTiers};
use crate::error::{Error, Result};
use crate::model::ModelState;
use crate::obs::{metrics, trace};
use crate::synth::{self, FpgaDevice};
use crate::train::{EvalResult, Trainer};

/// One candidate model to evaluate.
pub struct ProbeRequest {
    /// Caller-side tag for mapping results back (layer index, grid
    /// point, …); echoed on the matching [`ProbeResult`].
    pub id: usize,
    pub state: ModelState,
}

impl ProbeRequest {
    pub fn new(id: usize, state: ModelState) -> Self {
        ProbeRequest { id, state }
    }
}

/// Evaluation of one candidate, in request order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeResult {
    pub id: usize,
    pub eval: EvalResult,
    /// True when the result was served from the memo cache (or from a
    /// duplicate request earlier in the same batch) instead of a fresh
    /// evaluation.
    pub cached: bool,
}

/// Probe-issue accounting, shared alongside the memos.  `issued` counts
/// every request submitted to a batch API (cache hits included): it is
/// independent of cache state, and deterministic for a *fixed* worker
/// configuration — but not across worker counts, because some searches
/// size their speculative batches by `pool.jobs()` (SCALING's grid
/// waves, PRUNING's look-ahead) and the pipelined search scheduler
/// issues probes for mis-speculated flows that never reach the trace,
/// so comparisons of issued counts must pin `jobs` and the scheduling
/// mode.  `computed` counts fresh evaluations, which additionally
/// depends on what concurrent batches had already memoized — a
/// wall-clock-style diagnostic, never a replay-comparable number.
#[derive(Debug, Default)]
pub struct ProbeStats {
    train_issued: AtomicUsize,
    train_computed: AtomicUsize,
    hw_issued: AtomicUsize,
    hw_computed: AtomicUsize,
    sur_fits: AtomicUsize,
    sur_predictions: AtomicUsize,
    spec_submitted: AtomicUsize,
    spec_committed: AtomicUsize,
    spec_cancelled: AtomicUsize,
}

/// A point-in-time copy of [`ProbeStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProbeCounts {
    /// Training probes submitted through [`ProbePool::evaluate_batch`].
    pub train_issued: usize,
    /// Training probes actually evaluated (cache misses).
    pub train_computed: usize,
    /// Hardware probes submitted through [`ProbePool::estimate_batch`].
    pub hw_issued: usize,
    /// Hardware probes actually estimated (cache misses).
    pub hw_computed: usize,
    /// Surrogate model refits ([`crate::search::surrogate`]).
    pub sur_fits: usize,
    /// Surrogate objective-vector predictions served in place of (or
    /// ahead of) flow evaluations.
    pub sur_predictions: usize,
    /// Probe flows enqueued speculatively by the pipelined scheduler
    /// before the strategy committed to them.  Like `computed`, the
    /// `spec_*` trio is a wall-clock diagnostic — speculation volume
    /// depends on worker timing and `--jobs`, never replay-comparable.
    pub spec_submitted: usize,
    /// Speculative flows whose results were committed to the observed
    /// trace (the strategy really proposed them).
    pub spec_committed: usize,
    /// Speculative flows cancelled before any work started.
    pub spec_cancelled: usize,
}

impl ProbeCounts {
    /// The one cache-hit-rate definition shared by the explore summary
    /// and the report CSV: `cached / issued` where
    /// `cached = issued - computed`.  `None` when nothing was issued,
    /// so both renderings show the same blank instead of a fake 0.
    pub fn cache_hit_rate(issued: usize, computed: usize) -> Option<f64> {
        (issued > 0).then(|| issued.saturating_sub(computed) as f64 / issued as f64)
    }
}

impl ProbeStats {
    pub fn snapshot(&self) -> ProbeCounts {
        ProbeCounts {
            train_issued: self.train_issued.load(Ordering::Relaxed),
            train_computed: self.train_computed.load(Ordering::Relaxed),
            hw_issued: self.hw_issued.load(Ordering::Relaxed),
            hw_computed: self.hw_computed.load(Ordering::Relaxed),
            sur_fits: self.sur_fits.load(Ordering::Relaxed),
            sur_predictions: self.sur_predictions.load(Ordering::Relaxed),
            spec_submitted: self.spec_submitted.load(Ordering::Relaxed),
            spec_committed: self.spec_committed.load(Ordering::Relaxed),
            spec_cancelled: self.spec_cancelled.load(Ordering::Relaxed),
        }
    }

    /// The surrogate refit its model (called from
    /// [`crate::search::surrogate::Surrogate`], which shares this
    /// counter block through [`crate::dse::ProbeTiers`]).
    pub fn note_surrogate_fit(&self) {
        self.sur_fits.fetch_add(1, Ordering::Relaxed);
    }

    /// The surrogate served one objective-vector prediction.
    pub fn note_surrogate_prediction(&self) {
        self.sur_predictions.fetch_add(1, Ordering::Relaxed);
    }

    /// The pipelined scheduler enqueued one speculative probe flow.
    pub fn note_speculation_submitted(&self) {
        self.spec_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A speculative flow's result was committed to the observed trace.
    pub fn note_speculation_committed(&self) {
        self.spec_committed.fetch_add(1, Ordering::Relaxed);
    }

    /// A speculative flow was cancelled before any work started.
    pub fn note_speculation_cancelled(&self) {
        self.spec_cancelled.fetch_add(1, Ordering::Relaxed);
    }
}

/// A worker pool + one memo per probe kind, shared by one search
/// (typically created per O-task run from [`crate::flow::TaskCtx::jobs`]).
pub struct ProbePool {
    jobs: usize,
    /// `Arc` so one memo can be shared across pools (the multi-flow
    /// explorer deduplicates identical probes across flow variants);
    /// a pool created via [`ProbePool::new`] owns private memos.
    cache: Arc<EvalCache>,
    /// Hardware-probe memo (synthesis estimations), keyed by
    /// HLS-config fingerprint instead of params fingerprint.
    hw_cache: Arc<HwCache>,
    /// Optional persistent tier consulted below the in-memory memos
    /// (`--cache-dir`); fresh results are written through.
    disk: Option<Arc<DiskStore>>,
    /// Probe-issue accounting (shared with the memos by
    /// [`crate::dse::ProbeTiers`] so a whole search aggregates).
    stats: Arc<ProbeStats>,
    /// Persistent execution threads.  `Arc` so pools built over one
    /// [`ProbeTiers`] bundle at the same width share a single set of
    /// OS threads instead of spawning per O-task run.
    workers: Arc<WorkerPool>,
}

impl ProbePool {
    /// Pool with an explicit worker count (clamped to >= 1) and
    /// private memos for both probe kinds.
    pub fn new(jobs: usize) -> Self {
        Self::with_caches(jobs, Arc::new(EvalCache::new()), Arc::new(HwCache::new()))
    }

    /// Pool sharing an existing eval memo (private hardware memo).
    /// Sharing never changes results (a key incorporates every
    /// evaluation input), only how often a probe is recomputed.
    pub fn with_cache(jobs: usize, cache: Arc<EvalCache>) -> Self {
        Self::with_caches(jobs, cache, Arc::new(HwCache::new()))
    }

    /// Pool sharing existing memos for both probe kinds.
    pub fn with_caches(jobs: usize, cache: Arc<EvalCache>, hw_cache: Arc<HwCache>) -> Self {
        Self::with_shared(jobs, cache, hw_cache, Arc::new(ProbeStats::default()))
    }

    /// Pool sharing memos *and* the probe-issue counters.
    pub fn with_shared(
        jobs: usize,
        cache: Arc<EvalCache>,
        hw_cache: Arc<HwCache>,
        stats: Arc<ProbeStats>,
    ) -> Self {
        let jobs = jobs.max(1);
        ProbePool {
            jobs,
            cache,
            hw_cache,
            disk: None,
            stats,
            workers: Arc::new(WorkerPool::new(jobs)),
        }
    }

    /// Pool over a shared [`ProbeTiers`] bundle — memos, optional disk
    /// tier and counters all shared (how [`ProbeTiers::pool`] builds
    /// the explorer's and the search driver's pools).
    pub fn with_tiers(jobs: usize, tiers: &ProbeTiers) -> Self {
        let jobs = jobs.max(1);
        ProbePool {
            jobs,
            cache: Arc::clone(&tiers.eval),
            hw_cache: Arc::clone(&tiers.hw),
            disk: tiers.disk.clone(),
            stats: Arc::clone(&tiers.stats),
            workers: tiers.worker_pool(jobs),
        }
    }

    /// Pool sized by `METAML_JOBS` / available parallelism
    /// (see [`crate::dse::default_jobs`]).
    pub fn with_default_jobs() -> Self {
        Self::new(crate::dse::default_jobs())
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    pub fn hw_cache(&self) -> &HwCache {
        &self.hw_cache
    }

    /// Current probe-issue counters (see [`ProbeStats`] for what is and
    /// is not replay-comparable).
    pub fn probe_counts(&self) -> ProbeCounts {
        self.stats.snapshot()
    }

    /// The persistent worker pool backing this executor (the async
    /// [`crate::dse::ProbeService`] seam submits through it).
    pub(crate) fn workers(&self) -> &Arc<WorkerPool> {
        &self.workers
    }

    /// Run `f(0..n)` across the pool's workers; results come back in
    /// index order.  The first `Err` in index order is propagated after
    /// the whole batch has been attempted.
    ///
    /// Idle capacity is lent *into* the probes as intra-probe
    /// parallelism (`kernels::with_intra_threads`): a lone probe gets
    /// the whole `--jobs` budget for its row-panel matmul splits, and a
    /// full batch gets `jobs / workers` each.  The split is by shape,
    /// never by thread count, so results stay bit-identical for any
    /// `--jobs` (see `rust/tests/kernel_parity.rs`).
    pub fn run_batch<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.jobs.min(n);
        if workers <= 1 {
            // Fast path (n == 1 or jobs == 1, the common
            // surrogate-validation case): inline on the caller, no
            // queue hop, full `--jobs` budget lent into the probe.
            // Emits the same probe.batch/wait/exec span structure as
            // the pooled path so traces compare across worker counts.
            let intra = self.jobs.max(1);
            let obs = trace::batch(n);
            let out = (0..n)
                .map(|i| {
                    obs.probe_claimed(i);
                    let _span = obs.probe_span(i);
                    crate::runtime::kernels::with_intra_threads(intra, || f(i))
                })
                .collect();
            obs.close();
            return out;
        }

        let intra = (self.jobs / workers).max(1);
        let slots: Vec<Mutex<Option<Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let job = |i: usize| {
            let r = crate::runtime::kernels::with_intra_threads(intra, || f(i));
            *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        };
        self.workers.run(n, &job);

        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .unwrap_or_else(|| {
                        Err(Error::other("probe pool: worker dropped a job slot"))
                    })
            })
            .collect()
    }

    /// Memoized batch execution over a single cache tier.  Thin
    /// wrapper around [`Self::tiered_batch`], kept for callers that
    /// memoize ad-hoc probe kinds against one [`ProbeCache`].
    pub fn memo_batch<K, V, F>(
        &self,
        cache: &ProbeCache<K, V>,
        keys: &[K],
        compute: F,
    ) -> Result<Vec<(V, bool)>>
    where
        K: Clone + Eq + Hash + Send,
        V: Clone + Send,
        F: Fn(usize) -> Result<V> + Sync,
    {
        let tiers: [&dyn ProbeTier<K, V>; 1] = [cache];
        self.tiered_batch("adhoc", &tiers, keys, compute)
    }

    /// Memoized batch execution across a stack of cache tiers — the
    /// shared core of every probe kind.
    ///
    /// Tiers are consulted top-down in request order; a hit at depth
    /// `d` back-fills the tiers above it (so an in-memory memo warms
    /// from the disk tier, while the disk tier — last in the stack —
    /// never re-absorbs what it already served, keeping warm runs
    /// append-free).  Fresh results are written through to *every*
    /// tier.
    ///
    /// Deterministic by construction: tier resolution happens
    /// sequentially in request order, duplicate keys inside the batch
    /// collapse onto the first occurrence, and fresh computations are
    /// pure per-candidate work fanned out via [`Self::run_batch`]
    /// (`compute(i)` computes request `i`).  Returns `(result, cached)`
    /// per request, in request order.
    ///
    /// `kind` labels the probe kind (`"train"`, `"hw"`, …) in the
    /// per-tier observability it emits: `cache.{kind}.{tier}.{hit,miss,
    /// write}` counters plus one `cache.lookup` span per tier per call.
    pub fn tiered_batch<K, V, F>(
        &self,
        kind: &'static str,
        tiers: &[&dyn ProbeTier<K, V>],
        keys: &[K],
        compute: F,
    ) -> Result<Vec<(V, bool)>>
    where
        K: Clone + Eq + Hash,
        V: Clone + Send,
        F: Fn(usize) -> Result<V> + Sync,
    {
        let mut tally = CacheTally::new(tiers.len());
        // Single-request fast path (the common surrogate-validation
        // shape): one tier walk, no resolution map, and the compute —
        // if any — runs inline through `run_batch`'s n == 1 path.
        if let [key] = keys {
            if let Some((depth, v)) = tally.resolve(tiers, key) {
                for (d, upper) in tiers[..depth].iter().enumerate() {
                    upper.put(key, &v);
                    tally.wrote(d);
                }
                tally.publish(kind, tiers);
                return Ok(vec![(v, true)]);
            }
            let fresh = self.run_batch(1, |_| compute(0))?;
            let v = fresh
                .into_iter()
                .next()
                .ok_or_else(|| Error::other("probe pool: worker dropped a job slot"))?;
            for (d, tier) in tiers.iter().enumerate() {
                tier.put(key, &v);
                tally.wrote(d);
            }
            tally.publish(kind, tiers);
            return Ok(vec![(v, false)]);
        }

        // Resolve each request: cached at some tier, to-compute, or
        // duplicate of an earlier to-compute entry (mapped to its
        // position in the compute list).
        enum Resolution<V> {
            Cached(V),
            Compute(usize),
            Duplicate(usize),
        }
        let mut first_compute: std::collections::HashMap<&K, usize> =
            std::collections::HashMap::new();
        let mut compute_idx: Vec<usize> = Vec::new();
        let mut resolved: Vec<Resolution<V>> = Vec::with_capacity(keys.len());
        for (i, key) in keys.iter().enumerate() {
            if let Some((depth, v)) = tally.resolve(tiers, key) {
                for (d, upper) in tiers[..depth].iter().enumerate() {
                    upper.put(key, &v);
                    tally.wrote(d);
                }
                resolved.push(Resolution::Cached(v));
            } else if let Some(&slot) = first_compute.get(key) {
                resolved.push(Resolution::Duplicate(slot));
            } else {
                first_compute.insert(key, compute_idx.len());
                resolved.push(Resolution::Compute(compute_idx.len()));
                compute_idx.push(i);
            }
        }

        let fresh: Vec<V> =
            self.run_batch(compute_idx.len(), |slot| compute(compute_idx[slot]))?;
        for (slot, &i) in compute_idx.iter().enumerate() {
            for (d, tier) in tiers.iter().enumerate() {
                tier.put(&keys[i], &fresh[slot]);
                tally.wrote(d);
            }
        }
        tally.publish(kind, tiers);

        Ok(resolved
            .into_iter()
            .map(|res| match res {
                Resolution::Cached(v) => (v, true),
                Resolution::Compute(slot) => (fresh[slot].clone(), false),
                Resolution::Duplicate(slot) => (fresh[slot].clone(), true),
            })
            .collect())
    }

    /// Evaluate a batch of candidate model states concurrently through
    /// `trainer`, memoizing by [`EvalKey`] (the training probe kind).
    pub fn evaluate_batch(
        &self,
        trainer: &Trainer,
        requests: &[ProbeRequest],
    ) -> Result<Vec<ProbeResult>> {
        let keys: Vec<EvalKey> = requests
            .iter()
            .map(|r| EvalKey::of(&r.state, &trainer.data.spec))
            .collect();
        // issued is counted up front so a failing batch still shows the
        // probes it spent; computed needs the per-request cache flags
        self.stats.train_issued.fetch_add(requests.len(), Ordering::Relaxed);
        let mut tiers: Vec<&dyn ProbeTier<EvalKey, EvalResult>> =
            vec![self.cache.as_ref()];
        if let Some(disk) = &self.disk {
            tiers.push(disk.as_ref());
        }
        let out = self.tiered_batch("train", &tiers, &keys, |i| {
            trainer.evaluate(&requests[i].state)
        })?;
        self.stats.train_computed.fetch_add(
            out.iter().filter(|(_, cached)| !cached).count(),
            Ordering::Relaxed,
        );
        Ok(requests
            .iter()
            .zip(out)
            .map(|(req, (eval, cached))| ProbeResult { id: req.id, eval, cached })
            .collect())
    }

    /// Estimate a batch of candidate HLS configurations on `device` at
    /// `clock_mhz`, memoizing by [`HwKey`] (the hardware probe kind).
    /// Same pool, same ordering guarantees, same determinism contract
    /// as [`Self::evaluate_batch`] — only the probe kind differs.
    pub fn estimate_batch(
        &self,
        device: &FpgaDevice,
        clock_mhz: f64,
        requests: &[HwProbeRequest],
    ) -> Result<Vec<HwProbeResult>> {
        let keys: Vec<HwKey> = requests
            .iter()
            .map(|r| HwKey::of(&r.model, device, clock_mhz))
            .collect();
        self.stats.hw_issued.fetch_add(requests.len(), Ordering::Relaxed);
        let mut tiers: Vec<&dyn ProbeTier<HwKey, HwEval>> =
            vec![self.hw_cache.as_ref()];
        if let Some(disk) = &self.disk {
            tiers.push(disk.as_ref());
        }
        let out = self.tiered_batch("hw", &tiers, &keys, |i| {
            synth::estimate(&requests[i].model, device, clock_mhz)
                .map(|r| HwEval::from_report(&r))
        })?;
        self.stats.hw_computed.fetch_add(
            out.iter().filter(|(_, cached)| !cached).count(),
            Ordering::Relaxed,
        );
        Ok(requests
            .iter()
            .zip(out)
            .map(|(req, (eval, cached))| HwProbeResult { id: req.id, eval, cached })
            .collect())
    }
}

/// Per-call, per-tier cache accounting for [`ProbePool::tiered_batch`]:
/// hit/miss tallies from the top-down resolution walk plus every
/// write-through/back-fill put, published as `cache.{kind}.{tier}.*`
/// counters and — when tracing — one `cache.lookup` span per tier (a
/// constant per-call span structure, whatever the hit pattern).
struct CacheTally {
    /// `[hits, misses, writes]` per tier depth.
    per_tier: Vec<[u64; 3]>,
}

impl CacheTally {
    fn new(tiers: usize) -> Self {
        CacheTally { per_tier: vec![[0; 3]; tiers] }
    }

    /// Walk the tier stack top-down for `key`, tallying a miss for
    /// every tier consulted without an answer and a hit where found.
    fn resolve<K, V>(
        &mut self,
        tiers: &[&dyn ProbeTier<K, V>],
        key: &K,
    ) -> Option<(usize, V)> {
        for (depth, tier) in tiers.iter().enumerate() {
            if let Some(v) = tier.get(key) {
                self.per_tier[depth][0] += 1;
                return Some((depth, v));
            }
            self.per_tier[depth][1] += 1;
        }
        None
    }

    fn wrote(&mut self, depth: usize) {
        self.per_tier[depth][2] += 1;
    }

    fn publish<K, V>(&self, kind: &'static str, tiers: &[&dyn ProbeTier<K, V>]) {
        for (depth, tier) in tiers.iter().enumerate() {
            let [hits, misses, writes] = self.per_tier[depth];
            let name = tier.tier_name();
            metrics::counter_add(&format!("cache.{kind}.{name}.hit"), hits);
            metrics::counter_add(&format!("cache.{kind}.{name}.miss"), misses);
            metrics::counter_add(&format!("cache.{kind}.{name}.write"), writes);
            let mut span = trace::span("cache", "cache.lookup");
            span.arg("tier", name);
            span.arg("kind", kind);
            span.arg("hits", hits);
            span.arg("misses", misses);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_batch_preserves_index_order() {
        let pool = ProbePool::new(4);
        let out = pool.run_batch(33, |i| Ok(i * i)).unwrap();
        assert_eq!(out, (0..33).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_batch_sequential_matches_parallel() {
        let seq = ProbePool::new(1).run_batch(17, |i| Ok(i as u64 * 3 + 1)).unwrap();
        let par = ProbePool::new(8).run_batch(17, |i| Ok(i as u64 * 3 + 1)).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn run_batch_propagates_first_error_in_index_order() {
        let pool = ProbePool::new(4);
        let res: Result<Vec<usize>> = pool.run_batch(10, |i| {
            if i == 3 || i == 7 {
                Err(Error::other(format!("boom {i}")))
            } else {
                Ok(i)
            }
        });
        assert_eq!(res.unwrap_err().to_string(), "boom 3");
    }

    #[test]
    fn run_batch_empty_is_empty() {
        let pool = ProbePool::new(4);
        let out: Vec<usize> = pool.run_batch(0, |_| unreachable!()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn jobs_clamped_to_at_least_one() {
        assert_eq!(ProbePool::new(0).jobs(), 1);
        assert_eq!(ProbePool::new(3).jobs(), 3);
    }

    #[test]
    fn memo_batch_dedupes_and_memoizes_generically() {
        let pool = ProbePool::new(4);
        let cache: ProbeCache<u32, u64> = ProbeCache::new();
        let calls = AtomicUsize::new(0);
        let keys = vec![1u32, 2, 1, 3, 2];
        let out = pool
            .memo_batch(&cache, &keys, |i| {
                calls.fetch_add(1, Ordering::Relaxed);
                Ok(keys[i] as u64 * 10)
            })
            .unwrap();
        assert_eq!(
            out,
            vec![(10, false), (20, false), (10, true), (30, false), (20, true)]
        );
        // duplicates collapsed onto one computation each
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        assert_eq!(cache.len(), 3);
        // a second pass is served entirely from the memo
        let again = pool.memo_batch(&cache, &[1u32], |_| unreachable!()).unwrap();
        assert_eq!(again, vec![(10, true)]);
    }
}
