//! Parallel design-space exploration (DSE) engine.
//!
//! MetaML's O-tasks explore by firing hundreds of independent candidate
//! probes — `Trainer::evaluate`/`fit` calls over perturbed
//! [`crate::model::ModelState`]s.  The probes are embarrassingly
//! parallel (QUANTIZATION tries `2·L` one-bit reductions per round,
//! SCALING walks a speculative grid, AUTOPRUNE fine-tunes binary-search
//! candidates), and the execution substrate underneath is `Send + Sync`
//! end to end (see [`crate::runtime::ExecBackend`]), so this module
//! fans them out across a scoped-thread worker pool:
//!
//! A probe is no longer synonymous with "train-and-eval": the pool is
//! generic over *probe kinds*.  Training probes (candidate
//! `ModelState`s through the trainer) and hardware probes (candidate
//! HLS configurations through the synthesis estimator) share the same
//! batch executor, ordering guarantees and memoization machinery —
//! they differ only in what identifies an evaluation ([`EvalKey`]
//! fingerprints params/masks/dataset, [`HwKey`] fingerprints the HLS
//! config) and what it yields.
//!
//! * [`ProbePool`] — deterministic batch executor
//!   (`std::thread::scope`, no external dependencies) plus one shared
//!   memo per probe kind ([`EvalCache`], [`HwCache`]);
//! * [`ProbeRequest`] / [`ProbeResult`] — the training-probe batch API;
//! * [`HwProbeRequest`] / [`HwProbeResult`] — the hardware-probe batch
//!   API ([`ProbePool::estimate_batch`]);
//! * [`DseCaches`] — the bundle of shared memos the engine threads
//!   through explorer variants;
//! * [`default_jobs`] — worker-count resolution.
//!
//! **Determinism contract:** results are bit-identical for every
//! `jobs` value.  Batches return in request order, selection/tie-break
//! logic runs sequentially over complete batches, and each probe is
//! computed by the same single-threaded code path regardless of worker
//! count.  Parallelism (and the cache) change only how fast the answer
//! arrives.
//!
//! Worker-count precedence, highest first:
//! 1. the `jobs` CFG key (set per task instance, or globally by the
//!    CLI `--jobs` flag);
//! 2. the `METAML_JOBS` environment variable;
//! 3. `std::thread::available_parallelism()`.

pub mod cache;
pub mod hw;
pub mod pool;

pub use cache::{EvalCache, EvalKey, ProbeCache};
pub use hw::{HwCache, HwEval, HwKey, HwProbeRequest, HwProbeResult};
pub use pool::{ProbeCounts, ProbePool, ProbeRequest, ProbeResult, ProbeStats};

use std::sync::Arc;

/// One shared memo per probe kind — what the engine hands to every
/// O-task probe pool during multi-flow exploration so identical probes
/// (training *and* hardware) dedupe across flow variants — plus the
/// probe-issue counters aggregated across every pool built from the
/// bundle (the budgeted-search driver reports them per run).
#[derive(Debug, Clone, Default)]
pub struct DseCaches {
    pub eval: Arc<EvalCache>,
    pub hw: Arc<HwCache>,
    pub stats: Arc<ProbeStats>,
}

impl DseCaches {
    pub fn new() -> Self {
        Self::default()
    }

    /// A pool over these shared memos and counters.
    pub fn pool(&self, jobs: usize) -> ProbePool {
        ProbePool::with_shared(jobs, self.eval.clone(), self.hw.clone(), self.stats.clone())
    }

    /// Probe totals issued/computed through every pool of this bundle.
    pub fn probe_counts(&self) -> ProbeCounts {
        self.stats.snapshot()
    }
}

/// Worker count from `METAML_JOBS`, when set to a positive integer.
pub fn env_jobs() -> Option<usize> {
    std::env::var("METAML_JOBS")
        .ok()?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// Default DSE worker count: `METAML_JOBS` when set, otherwise the
/// machine's available parallelism (1 if unknown).
pub fn default_jobs() -> usize {
    env_jobs().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}
