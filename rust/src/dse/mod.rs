//! Parallel design-space exploration (DSE) engine.
//!
//! MetaML's O-tasks explore by firing hundreds of independent candidate
//! probes — `Trainer::evaluate`/`fit` calls over perturbed
//! [`crate::model::ModelState`]s.  The probes are embarrassingly
//! parallel (QUANTIZATION tries `2·L` one-bit reductions per round,
//! SCALING walks a speculative grid, AUTOPRUNE fine-tunes binary-search
//! candidates), and the execution substrate underneath is `Send + Sync`
//! end to end (see [`crate::runtime::ExecBackend`]), so this module
//! fans them out across a persistent worker pool whose threads spawn
//! once per pool lifetime and drain a submission queue:
//!
//! A probe is no longer synonymous with "train-and-eval": the pool is
//! generic over *probe kinds*.  Training probes (candidate
//! `ModelState`s through the trainer) and hardware probes (candidate
//! HLS configurations through the synthesis estimator) share the same
//! batch executor, ordering guarantees and memoization machinery —
//! they differ only in what identifies an evaluation ([`EvalKey`]
//! fingerprints params/masks/dataset, [`HwKey`] fingerprints the HLS
//! config) and what it yields.
//!
//! * [`ProbeService`] — the object-safe trait every probe consumer
//!   programs against (the seam for remote workers and surrogates),
//!   with both a synchronous batch API and an async submission seam
//!   ([`submit_batch`] → ticket → wait/cancel) that the pipelined
//!   search driver speculates through;
//! * [`WorkerPool`] — the long-lived execution threads (submission
//!   queue, claim-cursor batches, conservative cancellation, no
//!   external dependencies);
//! * [`ProbePool`] — deterministic batch executor over a
//!   [`WorkerPool`] plus a stack of cache tiers per probe kind
//!   ([`EvalCache`], [`HwCache`], and an optional persistent
//!   [`DiskStore`]);
//! * [`ProbeRequest`] / [`ProbeResult`] — the training-probe batch API;
//! * [`HwProbeRequest`] / [`HwProbeResult`] — the hardware-probe batch
//!   API ([`ProbePool::estimate_batch`]);
//! * [`ProbeTiers`] — the bundle of shared tiers the engine threads
//!   through explorer variants;
//! * [`default_jobs`] — worker-count resolution.
//!
//! **Determinism contract:** results are bit-identical for every
//! `jobs` value.  Batches return in request order, selection/tie-break
//! logic runs sequentially over complete batches, and each probe is
//! computed by the same single-threaded code path regardless of worker
//! count.  Parallelism (and the cache) change only how fast the answer
//! arrives.
//!
//! Worker-count precedence, highest first:
//! 1. the `jobs` CFG key (set per task instance, or globally by the
//!    CLI `--jobs` flag);
//! 2. the `METAML_JOBS` environment variable;
//! 3. `std::thread::available_parallelism()`.

pub mod cache;
pub mod disk;
pub mod hw;
pub mod pool;
pub mod service;
pub mod workers;

pub use cache::{EvalCache, EvalKey, ProbeCache};
pub use disk::{DiskStore, StoreStats};
pub use hw::{HwCache, HwEval, HwKey, HwProbeRequest, HwProbeResult};
pub use pool::{ProbeCounts, ProbePool, ProbeRequest, ProbeResult, ProbeStats};
pub use service::{
    submit_batch, ProbeService, ProbeServiceExt, ProbeTier, ProbeTiers, SubmittedBatch,
};
pub use workers::WorkerPool;

/// Worker count from `METAML_JOBS`, when set to a positive integer.
pub fn env_jobs() -> Option<usize> {
    std::env::var("METAML_JOBS")
        .ok()?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// Default DSE worker count: `METAML_JOBS` when set, otherwise the
/// machine's available parallelism (1 if unknown).
pub fn default_jobs() -> usize {
    env_jobs().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}
