//! Persistent worker pool backing the probe executors.
//!
//! `WorkerPool` spawns its threads **once per pool lifetime** and feeds
//! them batches through a submission queue, replacing the old
//! per-batch `std::thread::scope` spawn/join cycle. Batches are
//! work-stealing over a claim cursor: every participating thread
//! (workers *and* the waiter) claims indices with a `fetch_add`, so a
//! batch always completes even on a pool with zero spawned workers —
//! the thread that calls [`WorkerPool::wait`] drains whatever is left
//! itself. That self-draining waiter is also what makes nested batches
//! (a probe that opens its own inner batch on another pool) deadlock
//! free.
//!
//! Cancellation is conservative by design: [`WorkerPool::cancel`]
//! succeeds only when *nothing* of the batch has been claimed yet
//! (compare-and-swap of the claim cursor from 0 to n). A batch that
//! any thread has started is left to finish — its results land in the
//! probe tiers as cache fodder, never half-observed.
//!
//! When tracing is enabled (see [`crate::obs::trace`]), every batch
//! carries a span envelope opened at submission on the submitting
//! thread, and each slot records a `probe.wait` interval (enqueue →
//! claim) plus a `probe.exec` span on whichever thread claims it —
//! making queue-wait vs execute time visible without touching the
//! execution order.

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs::trace;

/// Completion state of one batch, guarded by the batch mutex.
struct Done {
    finished: usize,
    cancelled: bool,
    panic: Option<Box<dyn Any + Send>>,
}

/// One submitted batch: an erased job plus a claim cursor.
///
/// The job reference is lifetime-erased to `'static` at submission;
/// the submitter guarantees the referent outlives the batch (see
/// [`WorkerPool::submit`] safety contract).
struct Batch {
    job: &'static (dyn Fn(usize) + Sync),
    n: usize,
    next: AtomicUsize,
    done: Mutex<Done>,
    cond: Condvar,
    /// Span envelope opened by the submitting thread; inert when
    /// tracing is disabled.
    obs: trace::BatchSpans,
}

impl Batch {
    fn new(job: &'static (dyn Fn(usize) + Sync), n: usize, obs: trace::BatchSpans) -> Self {
        Batch {
            job,
            n,
            next: AtomicUsize::new(0),
            done: Mutex::new(Done { finished: 0, cancelled: false, panic: None }),
            cond: Condvar::new(),
            obs,
        }
    }

    /// Claim and run indices until the cursor passes `n`. Safe to call
    /// from any number of threads concurrently; panics inside the job
    /// are captured (first one wins) and re-thrown by `wait`.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.n {
                break;
            }
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                self.obs.probe_claimed(i);
                let _span = self.obs.probe_span(i);
                (self.job)(i)
            }));
            let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
            done.finished += 1;
            if let Err(p) = outcome {
                if done.panic.is_none() {
                    done.panic = Some(p);
                }
            }
            if done.finished == self.n {
                self.cond.notify_all();
            }
        }
    }

    /// Drain remaining indices on the calling thread, then block until
    /// every claimed index has finished. Re-throws the first captured
    /// panic.
    fn wait(&self) {
        self.drain();
        let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
        while !done.cancelled && done.finished < self.n {
            done = self.cond.wait(done).unwrap_or_else(|e| e.into_inner());
        }
        let panic = done.panic.take();
        drop(done);
        self.obs.close();
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    /// Cancel iff no index has been claimed yet. Returns `true` on
    /// success, in which case the job is guaranteed never to run.
    fn cancel(&self) -> bool {
        if self
            .next
            .compare_exchange(0, self.n.max(1), Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
            done.cancelled = true;
            self.cond.notify_all();
            drop(done);
            self.obs.close_cancelled();
            true
        } else {
            false
        }
    }
}

struct Queue {
    tokens: VecDeque<Arc<Batch>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work: Condvar,
}

/// Long-lived worker pool with a FIFO submission queue.
///
/// `new(jobs)` spawns `jobs - 1` threads: the caller participates as
/// the `jobs`-th worker whenever it waits on a batch, so a `jobs = 1`
/// pool spawns nothing and runs everything inline.
pub struct WorkerPool {
    jobs: usize,
    shared: Arc<Shared>,
    tickets: Mutex<HashMap<u64, Arc<Batch>>>,
    next_ticket: AtomicU64,
    handles: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("jobs", &self.jobs).finish()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let token = {
            let mut queue = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(t) = queue.tokens.pop_front() {
                    break t;
                }
                if queue.shutdown {
                    return;
                }
                queue = shared.work.wait(queue).unwrap_or_else(|e| e.into_inner());
            }
        };
        token.drain();
    }
}

impl WorkerPool {
    /// Build a pool for `jobs` total workers (clamped to at least 1);
    /// spawns `jobs - 1` threads immediately.
    pub fn new(jobs: usize) -> Self {
        let jobs = jobs.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { tokens: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let handles = (1..jobs)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        WorkerPool {
            jobs,
            shared,
            tickets: Mutex::new(HashMap::new()),
            next_ticket: AtomicU64::new(0),
            handles,
        }
    }

    /// Total worker count (spawned threads + the waiting caller).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Enqueue a batch of `n` jobs and return its ticket (tickets start
    /// at 1; 0 is reserved for "already done" sentinels upstream).
    ///
    /// # Safety
    ///
    /// The referent of `job` must remain valid — not moved, dropped, or
    /// mutably aliased — until either `wait(ticket)` returns or
    /// `cancel(ticket)` returns `true`. The pool erases the lifetime
    /// internally; the caller owns the proof.
    pub unsafe fn submit(&self, n: usize, job: &(dyn Fn(usize) + Sync)) -> u64 {
        // Lifetime erasure: validity until wait/cancel is the caller's
        // contract, stated above.
        let job: &'static (dyn Fn(usize) + Sync) = std::mem::transmute(job);
        // Span envelope is created here, on the submitting thread, so
        // its logical parent is whatever span the submitter has open.
        let batch = Arc::new(Batch::new(job, n, trace::batch(n)));
        let ticket = self.next_ticket.fetch_add(1, Ordering::SeqCst) + 1;
        self.tickets
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(ticket, Arc::clone(&batch));
        // One queue token per worker that could usefully help; the
        // waiter drains the rest itself.
        let tokens = n.min(self.jobs.saturating_sub(1)).max(if self.jobs > 1 { 1 } else { 0 });
        if tokens > 0 {
            let mut queue = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            for _ in 0..tokens {
                queue.tokens.push_back(Arc::clone(&batch));
            }
            drop(queue);
            self.shared.work.notify_all();
        }
        ticket
    }

    /// Block until the ticket's batch has fully finished (draining
    /// unclaimed work on this thread first). Unknown or already-waited
    /// tickets are a no-op, so `wait` is idempotent.
    pub fn wait(&self, ticket: u64) {
        let batch = self
            .tickets
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&ticket);
        if let Some(batch) = batch {
            batch.wait();
        }
    }

    /// Try to cancel a pending batch. Returns `true` only when no job
    /// of the batch had started, in which case none ever will.
    pub fn cancel(&self, ticket: u64) -> bool {
        let batch = self
            .tickets
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&ticket)
            .cloned();
        match batch {
            Some(batch) if batch.cancel() => {
                self.tickets
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .remove(&ticket);
                true
            }
            _ => false,
        }
    }

    /// Synchronous run: submit + wait in one call. This is the safe
    /// wrapper the batch executors use; panics from jobs propagate to
    /// the caller exactly as the old scoped-thread executor did.
    pub fn run(&self, n: usize, job: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        // SAFETY: `job` outlives this call, and we wait on the ticket
        // before returning, so the referent is valid for the batch's
        // whole execution.
        let ticket = unsafe { self.submit(n, job) };
        self.wait(ticket);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut queue = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            queue.shutdown = true;
        }
        self.shared.work.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_executes_every_index_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..33).map(|_| AtomicUsize::new(0)).collect();
        let job = |i: usize| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        };
        pool.run(33, &job);
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn single_job_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert!(pool.handles.is_empty());
        let sum = AtomicUsize::new(0);
        let job = |i: usize| {
            sum.fetch_add(i + 1, Ordering::SeqCst);
        };
        pool.run(10, &job);
        assert_eq!(sum.load(Ordering::SeqCst), 55);
    }

    #[test]
    fn reuse_across_batches() {
        let pool = WorkerPool::new(3);
        for round in 0..5 {
            let count = AtomicUsize::new(0);
            let job = |_i: usize| {
                count.fetch_add(1, Ordering::SeqCst);
            };
            pool.run(round + 1, &job);
            assert_eq!(count.load(Ordering::SeqCst), round + 1);
        }
    }

    #[test]
    fn wait_is_idempotent_and_unknown_tickets_are_noops() {
        let pool = WorkerPool::new(2);
        let job = |_i: usize| {};
        // SAFETY: `job` lives to the end of the test; we wait below.
        let ticket = unsafe { pool.submit(3, &job) };
        pool.wait(ticket);
        pool.wait(ticket); // idempotent
        pool.wait(9999); // unknown: no-op
    }

    #[test]
    fn cancel_before_start_prevents_execution() {
        // jobs=1: no spawned workers, so nothing can claim the batch
        // before we cancel it.
        let pool = WorkerPool::new(1);
        let ran = AtomicUsize::new(0);
        let job = |_i: usize| {
            ran.fetch_add(1, Ordering::SeqCst);
        };
        // SAFETY: referent valid until cancel returns true below.
        let ticket = unsafe { pool.submit(4, &job) };
        assert!(pool.cancel(ticket));
        assert!(!pool.cancel(ticket)); // second cancel: ticket gone
        pool.wait(ticket); // no-op after successful cancel
        assert_eq!(ran.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn cancel_fails_once_work_has_started() {
        let pool = WorkerPool::new(1);
        let job = |_i: usize| {};
        // SAFETY: waited below before the referent dies.
        let ticket = unsafe { pool.submit(2, &job) };
        pool.wait(ticket); // fully drained by the waiter
        assert!(!pool.cancel(ticket));
    }

    #[test]
    fn panics_propagate_to_the_waiter() {
        let pool = WorkerPool::new(4);
        let job = |i: usize| {
            if i == 3 {
                panic!("boom 3");
            }
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| pool.run(8, &job)));
        let msg = outcome.expect_err("run should propagate the job panic");
        let text = msg
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| msg.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(text.contains("boom 3"), "unexpected panic payload: {text}");
        // The pool must survive a panicked batch.
        let count = AtomicUsize::new(0);
        let ok = |_i: usize| {
            count.fetch_add(1, Ordering::SeqCst);
        };
        pool.run(5, &ok);
        assert_eq!(count.load(Ordering::SeqCst), 5);
    }
}
