//! The probe-service abstraction: *what* evaluates probes, decoupled
//! from *who* asks.
//!
//! Every probe consumer in the system — the O-task searches
//! ([`crate::quant::quantize_search`], [`crate::scale::scale_search`],
//! [`crate::prune::autoprune`], [`crate::synth::reuse_search`]), the
//! multi-flow explorer, the budgeted search driver and its hardware
//! prefilter — talks to a `&dyn ProbeService` instead of a concrete
//! [`ProbePool`].  The trait exposes exactly the existing batch
//! contracts (results in request order, bit-identical for every worker
//! count, first error in index order), so swapping the implementation
//! can never change a trace — only where and how fast results come
//! from.
//!
//! Implementations compose as **tiers**:
//!
//! ```text
//!   consumer (&dyn ProbeService)
//!      └─ ProbePool ── in-memory memo tier   (EvalCache / HwCache)
//!                   ── disk tier (optional)  (DiskStore under --cache-dir)
//!                   └─ executor tier         (Trainer / synth::estimate)
//! ```
//!
//! The [`ProbeTier`] trait is the seam: a tier is anything that can
//! answer "do you already know this fingerprint key?" and absorb fresh
//! results.  A remote worker pool or a learned surrogate drops in as
//! another tier (or another `ProbeService` entirely) without touching
//! any consumer.
//!
//! [`ProbeTiers`] is the shared bundle the engine threads through a
//! run (the successor of the old `DseCaches`): one in-memory memo per
//! probe kind, an optional disk store, and the [`ProbeStats`] counters
//! aggregated across every pool built from it.

use std::hash::Hash;
use std::sync::{Arc, Mutex, PoisonError};

use crate::dse::cache::{EvalCache, ProbeCache};
use crate::dse::disk::DiskStore;
use crate::dse::hw::{HwCache, HwProbeRequest, HwProbeResult};
use crate::dse::pool::{ProbeCounts, ProbePool, ProbeRequest, ProbeResult, ProbeStats};
use crate::error::{Error, Result};
use crate::synth::FpgaDevice;
use crate::train::Trainer;

/// Batch probe evaluation behind one object-safe interface.
///
/// **Determinism contract** (inherited verbatim from [`ProbePool`]):
/// results come back in request order; each probe is computed by the
/// same single-threaded code path whatever the worker count; caching
/// at any tier can only skip recomputation of bit-identical results.
/// The first error in request order is propagated after the whole
/// batch has been attempted.
pub trait ProbeService: Send + Sync {
    /// Evaluate candidate model states through `trainer` (the training
    /// probe kind), memoized under [`crate::dse::EvalKey`] fingerprints.
    fn evaluate_batch(
        &self,
        trainer: &Trainer,
        requests: &[ProbeRequest],
    ) -> Result<Vec<ProbeResult>>;

    /// Estimate candidate HLS configurations on `device` at `clock_mhz`
    /// (the hardware probe kind), memoized under
    /// [`crate::dse::HwKey`] fingerprints.
    fn estimate_batch(
        &self,
        device: &FpgaDevice,
        clock_mhz: f64,
        requests: &[HwProbeRequest],
    ) -> Result<Vec<HwProbeResult>>;

    /// Worker count — searches size speculative batches by it
    /// (SCALING's grid waves, AUTOPRUNE's look-ahead).
    fn jobs(&self) -> usize;

    /// Probe-issue counters aggregated over this service's lifetime
    /// (see [`ProbeStats`] for what is and is not replay-comparable).
    fn counts(&self) -> ProbeCounts;

    /// Run `f(0..n)` across the service's workers (object-safe core
    /// behind [`ProbeServiceExt::run_batch`]).  The default executes
    /// sequentially; [`ProbePool`] overrides it with its scoped-thread
    /// pool.
    fn run_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }
}

/// Generic batch helper over [`ProbeService::run_raw`] — kept in an
/// extension trait because generic methods would make the service
/// trait non-object-safe.  `use` it wherever a `&dyn ProbeService`
/// needs the typed `run_batch` the concrete [`ProbePool`] offers:
/// same request-order results, same first-error-in-index-order
/// semantics.
pub trait ProbeServiceExt: ProbeService {
    fn run_batch<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let slots: Vec<Mutex<Option<Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        self.run_raw(n, &|i| {
            let r = f(i);
            *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .unwrap_or_else(|| {
                        Err(Error::other("probe service: worker dropped a job slot"))
                    })
            })
            .collect()
    }
}

impl<S: ProbeService + ?Sized> ProbeServiceExt for S {}

impl ProbeService for ProbePool {
    fn evaluate_batch(
        &self,
        trainer: &Trainer,
        requests: &[ProbeRequest],
    ) -> Result<Vec<ProbeResult>> {
        ProbePool::evaluate_batch(self, trainer, requests)
    }

    fn estimate_batch(
        &self,
        device: &FpgaDevice,
        clock_mhz: f64,
        requests: &[HwProbeRequest],
    ) -> Result<Vec<HwProbeResult>> {
        ProbePool::estimate_batch(self, device, clock_mhz, requests)
    }

    fn jobs(&self) -> usize {
        ProbePool::jobs(self)
    }

    fn counts(&self) -> ProbeCounts {
        self.probe_counts()
    }

    fn run_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        // infallible jobs can't produce an Err, so the Result is moot
        let _ = ProbePool::run_batch(self, n, |i| {
            f(i);
            Ok(())
        });
    }
}

/// One cache tier for one probe kind: a key→value store a
/// [`ProbePool`] consults top-down before computing, and back-fills
/// with hits from lower tiers and fresh results.
///
/// `get` must only ever return a value that was `put` for exactly that
/// key — tiers trade recomputation for lookup, never results.  `put`
/// is best-effort (a full or failing tier may drop writes).
pub trait ProbeTier<K, V>: Send + Sync {
    fn get(&self, key: &K) -> Option<V>;
    fn put(&self, key: &K, value: &V);
}

impl<K, V> ProbeTier<K, V> for ProbeCache<K, V>
where
    K: Clone + Eq + Hash + Send,
    V: Clone + Send,
{
    fn get(&self, key: &K) -> Option<V> {
        ProbeCache::get(self, key)
    }

    fn put(&self, key: &K, value: &V) {
        self.insert(key.clone(), value.clone());
    }
}

/// The shared tier bundle the engine threads through a run: one
/// in-memory memo per probe kind, an optional persistent disk tier,
/// and the probe-issue counters aggregated across every pool built
/// from the bundle (the budgeted-search driver reports them per run).
///
/// Sharing never changes results (every key incorporates the complete
/// evaluation input), only how often a probe is recomputed.
#[derive(Debug, Clone, Default)]
pub struct ProbeTiers {
    pub eval: Arc<EvalCache>,
    pub hw: Arc<HwCache>,
    /// Persistent tier consulted after the memos; fresh results are
    /// written through so they survive the process.
    pub disk: Option<Arc<DiskStore>>,
    pub stats: Arc<ProbeStats>,
}

impl ProbeTiers {
    /// In-memory tiers only (the explorer/search default).
    pub fn new() -> Self {
        Self::default()
    }

    /// In-memory tiers backed by a persistent `store` (the CLI's
    /// `--cache-dir`).
    pub fn with_disk(store: Arc<DiskStore>) -> Self {
        ProbeTiers { disk: Some(store), ..Self::default() }
    }

    /// A pool over these shared tiers and counters.
    pub fn pool(&self, jobs: usize) -> ProbePool {
        ProbePool::with_tiers(jobs, self)
    }

    /// The same pool as a shared [`ProbeService`] handle (what
    /// [`crate::flow::TaskCtx::probes`] hands to the O-task searches).
    pub fn service(&self, jobs: usize) -> Arc<dyn ProbeService> {
        Arc::new(self.pool(jobs))
    }

    /// Probe totals issued/computed through every pool of this bundle.
    pub fn probe_counts(&self) -> ProbeCounts {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::pool::ProbePool;

    /// The extension-trait run_batch must match the pool's own batch
    /// executor exactly: request order, first error in index order,
    /// empty batches.
    #[test]
    fn ext_run_batch_matches_pool_contract() {
        let pool = ProbePool::new(4);
        let service: &dyn ProbeService = &pool;
        let out = service.run_batch(33, |i| Ok(i * i)).unwrap();
        assert_eq!(out, (0..33).map(|i| i * i).collect::<Vec<_>>());

        let res: Result<Vec<usize>> = service.run_batch(10, |i| {
            if i == 3 || i == 7 {
                Err(Error::other(format!("boom {i}")))
            } else {
                Ok(i)
            }
        });
        assert_eq!(res.unwrap_err().to_string(), "boom 3");

        let empty: Vec<usize> = service.run_batch(0, |_| unreachable!()).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn default_run_raw_is_sequential_and_ordered() {
        struct Sequential;
        impl ProbeService for Sequential {
            fn evaluate_batch(
                &self,
                _trainer: &Trainer,
                _requests: &[ProbeRequest],
            ) -> Result<Vec<ProbeResult>> {
                unreachable!()
            }
            fn estimate_batch(
                &self,
                _device: &FpgaDevice,
                _clock_mhz: f64,
                _requests: &[HwProbeRequest],
            ) -> Result<Vec<HwProbeResult>> {
                unreachable!()
            }
            fn jobs(&self) -> usize {
                1
            }
            fn counts(&self) -> ProbeCounts {
                ProbeCounts::default()
            }
        }
        let out = Sequential.run_batch(5, |i| Ok(i + 1)).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn tiers_pool_shares_stats_across_pools() {
        let tiers = ProbeTiers::new();
        let a = tiers.pool(1);
        let b = tiers.service(4);
        assert_eq!(a.jobs(), 1);
        assert_eq!(b.jobs(), 4);
        assert_eq!(tiers.probe_counts(), ProbeCounts::default());
    }
}
