//! The probe-service abstraction: *what* evaluates probes, decoupled
//! from *who* asks.
//!
//! Every probe consumer in the system — the O-task searches
//! ([`crate::quant::quantize_search`], [`crate::scale::scale_search`],
//! [`crate::prune::autoprune`], [`crate::synth::reuse_search`]), the
//! multi-flow explorer, the budgeted search driver and its hardware
//! prefilter — talks to a `&dyn ProbeService` instead of a concrete
//! [`ProbePool`].  The trait exposes exactly the existing batch
//! contracts (results in request order, bit-identical for every worker
//! count, first error in index order), so swapping the implementation
//! can never change a trace — only where and how fast results come
//! from.
//!
//! Implementations compose as **tiers**:
//!
//! ```text
//!   consumer (&dyn ProbeService)
//!      └─ ProbePool ── in-memory memo tier   (EvalCache / HwCache)
//!                   ── disk tier (optional)  (DiskStore under --cache-dir)
//!                   └─ executor tier         (Trainer / synth::estimate)
//! ```
//!
//! The [`ProbeTier`] trait is the seam: a tier is anything that can
//! answer "do you already know this fingerprint key?" and absorb fresh
//! results.  A remote worker pool or a learned surrogate drops in as
//! another tier (or another `ProbeService` entirely) without touching
//! any consumer.
//!
//! [`ProbeTiers`] is the shared bundle the engine threads through a
//! run (the successor of the old `DseCaches`): one in-memory memo per
//! probe kind, an optional disk store, and the [`ProbeStats`] counters
//! aggregated across every pool built from it.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex, PoisonError};

use crate::dse::cache::{EvalCache, ProbeCache};
use crate::dse::disk::DiskStore;
use crate::dse::hw::{HwCache, HwProbeRequest, HwProbeResult};
use crate::dse::pool::{ProbeCounts, ProbePool, ProbeRequest, ProbeResult, ProbeStats};
use crate::dse::workers::WorkerPool;
use crate::error::{Error, Result};
use crate::obs::trace;
use crate::synth::FpgaDevice;
use crate::train::Trainer;

/// Batch probe evaluation behind one object-safe interface.
///
/// **Determinism contract** (inherited verbatim from [`ProbePool`]):
/// results come back in request order; each probe is computed by the
/// same single-threaded code path whatever the worker count; caching
/// at any tier can only skip recomputation of bit-identical results.
/// The first error in request order is propagated after the whole
/// batch has been attempted.
pub trait ProbeService: Send + Sync {
    /// Evaluate candidate model states through `trainer` (the training
    /// probe kind), memoized under [`crate::dse::EvalKey`] fingerprints.
    fn evaluate_batch(
        &self,
        trainer: &Trainer,
        requests: &[ProbeRequest],
    ) -> Result<Vec<ProbeResult>>;

    /// Estimate candidate HLS configurations on `device` at `clock_mhz`
    /// (the hardware probe kind), memoized under
    /// [`crate::dse::HwKey`] fingerprints.
    fn estimate_batch(
        &self,
        device: &FpgaDevice,
        clock_mhz: f64,
        requests: &[HwProbeRequest],
    ) -> Result<Vec<HwProbeResult>>;

    /// Worker count — searches size speculative batches by it
    /// (SCALING's grid waves, AUTOPRUNE's look-ahead).
    fn jobs(&self) -> usize;

    /// Probe-issue counters aggregated over this service's lifetime
    /// (see [`ProbeStats`] for what is and is not replay-comparable).
    fn counts(&self) -> ProbeCounts;

    /// Run `f(0..n)` across the service's workers (object-safe core
    /// behind [`ProbeServiceExt::run_batch`]).  The default executes
    /// sequentially; [`ProbePool`] overrides it with its persistent
    /// worker pool.
    fn run_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }

    /// Asynchronous submission seam (object-safe core behind
    /// [`submit_batch`]): enqueue `f(0..n)` for execution and return a
    /// ticket for [`Self::wait_raw`] / [`Self::cancel_raw`].  The
    /// default runs the batch inline and returns ticket `0` (the
    /// "already done" sentinel), so implementations without a queue —
    /// and the jobs = 1 fast path — stay trivially correct.
    ///
    /// # Safety
    ///
    /// The referent of `f` must remain valid — not moved, dropped, or
    /// mutably aliased — until `wait_raw(ticket)` returns or
    /// `cancel_raw(ticket)` returns `true`.  Use [`submit_batch`],
    /// which owns the closure and waits on drop, unless you can prove
    /// that yourself.
    unsafe fn submit_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) -> u64 {
        for i in 0..n {
            f(i);
        }
        0
    }

    /// Block until the ticket's batch has fully executed.  Idempotent;
    /// unknown tickets (including the `0` sentinel) are a no-op.
    fn wait_raw(&self, _ticket: u64) {}

    /// Try to cancel a pending batch.  Returns `true` only when no job
    /// of the batch had started — in which case none ever will — and
    /// `false` otherwise (including for unknown tickets and services
    /// without a queue).
    fn cancel_raw(&self, _ticket: u64) -> bool {
        false
    }
}

/// Generic batch helper over [`ProbeService::run_raw`] — kept in an
/// extension trait because generic methods would make the service
/// trait non-object-safe.  `use` it wherever a `&dyn ProbeService`
/// needs the typed `run_batch` the concrete [`ProbePool`] offers:
/// same request-order results, same first-error-in-index-order
/// semantics.
pub trait ProbeServiceExt: ProbeService {
    fn run_batch<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let slots: Vec<Mutex<Option<Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        self.run_raw(n, &|i| {
            let r = f(i);
            *slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .unwrap_or_else(|| {
                        Err(Error::other("probe service: worker dropped a job slot"))
                    })
            })
            .collect()
    }
}

impl<S: ProbeService + ?Sized> ProbeServiceExt for S {}

impl ProbeService for ProbePool {
    fn evaluate_batch(
        &self,
        trainer: &Trainer,
        requests: &[ProbeRequest],
    ) -> Result<Vec<ProbeResult>> {
        ProbePool::evaluate_batch(self, trainer, requests)
    }

    fn estimate_batch(
        &self,
        device: &FpgaDevice,
        clock_mhz: f64,
        requests: &[HwProbeRequest],
    ) -> Result<Vec<HwProbeResult>> {
        ProbePool::estimate_batch(self, device, clock_mhz, requests)
    }

    fn jobs(&self) -> usize {
        ProbePool::jobs(self)
    }

    fn counts(&self) -> ProbeCounts {
        self.probe_counts()
    }

    fn run_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        // infallible jobs can't produce an Err, so the Result is moot
        let _ = ProbePool::run_batch(self, n, |i| {
            f(i);
            Ok(())
        });
    }

    unsafe fn submit_raw(&self, n: usize, f: &(dyn Fn(usize) + Sync)) -> u64 {
        if ProbePool::jobs(self) <= 1 {
            // jobs = 1 fast path: no queue, no ticket — run inline on
            // the caller thread exactly as the synchronous executor
            // would, emitting the same batch span structure as the
            // queued path.
            let obs = trace::batch(n);
            for i in 0..n {
                obs.probe_claimed(i);
                let _span = obs.probe_span(i);
                f(i);
            }
            obs.close();
            return 0;
        }
        // SAFETY: forwarded verbatim from our caller's contract.
        self.workers().submit(n, f)
    }

    fn wait_raw(&self, ticket: u64) {
        self.workers().wait(ticket);
    }

    fn cancel_raw(&self, ticket: u64) -> bool {
        self.workers().cancel(ticket)
    }
}

/// A batch in flight through [`ProbeService::submit_raw`], returned by
/// [`submit_batch`].
///
/// Owns the erased job closure (stable heap address) and the result
/// slots; **waits on drop** if neither [`Self::wait`] nor a successful
/// [`Self::try_cancel`] happened, which is what makes the async seam
/// safe to use with borrowing closures — the borrows provably outlive
/// the execution.
pub struct SubmittedBatch<'a, T: Send> {
    svc: &'a dyn ProbeService,
    ticket: u64,
    slots: Arc<Vec<Mutex<Option<Result<T>>>>>,
    /// Keeps the erased closure alive for the pool; never read.
    _job: Box<dyn Fn(usize) + Sync + 'a>,
    waited: bool,
}

impl<'a, T: Send> SubmittedBatch<'a, T> {
    /// Block until the batch has fully executed, then return results in
    /// request order.  The first error in request order is propagated
    /// after the whole batch has been attempted — identical to the
    /// synchronous [`ProbeServiceExt::run_batch`] contract.
    pub fn wait(mut self) -> Result<Vec<T>> {
        self.svc.wait_raw(self.ticket);
        self.waited = true;
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let r = slot
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .take()
                .unwrap_or_else(|| {
                    Err(Error::other("probe service: worker dropped a job slot"))
                });
            out.push(r?);
        }
        Ok(out)
    }

    /// Try to cancel before any work starts.  On `true` the batch is
    /// dead (no job ran, none will, drop won't wait); on `false` the
    /// batch is still pending and can be waited or left to finish.
    pub fn try_cancel(&mut self) -> bool {
        if !self.waited && self.svc.cancel_raw(self.ticket) {
            self.waited = true;
            true
        } else {
            false
        }
    }
}

impl<'a, T: Send> Drop for SubmittedBatch<'a, T> {
    fn drop(&mut self) {
        if !self.waited {
            // Unobserved speculative work still runs to completion —
            // its results land in the shared tiers as cache fodder —
            // and the wait keeps the borrowed closure sound.
            self.svc.wait_raw(self.ticket);
        }
    }
}

/// Submit `f(0..n)` asynchronously and get a [`SubmittedBatch`] handle
/// to wait on (or cancel).  This is the safe typed wrapper over
/// [`ProbeService::submit_raw`]: the handle owns the closure and the
/// slots, and waits on drop, so mis-speculated batches can simply be
/// dropped.
pub fn submit_batch<'a, T, F>(svc: &'a dyn ProbeService, n: usize, f: F) -> SubmittedBatch<'a, T>
where
    T: Send + 'a,
    F: Fn(usize) -> Result<T> + Sync + 'a,
{
    let slots: Arc<Vec<Mutex<Option<Result<T>>>>> =
        Arc::new((0..n).map(|_| Mutex::new(None)).collect());
    let job_slots = Arc::clone(&slots);
    let job: Box<dyn Fn(usize) + Sync + 'a> = Box::new(move |i| {
        let r = f(i);
        *job_slots[i].lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
    });
    // SAFETY: the returned SubmittedBatch owns `job` (boxed, so the
    // referent's address is stable across moves of the handle) and
    // guarantees wait_raw/cancel_raw-true before the box drops.
    let ticket = unsafe { svc.submit_raw(n, &*job) };
    SubmittedBatch { svc, ticket, slots, _job: job, waited: false }
}

/// One cache tier for one probe kind: a key→value store a
/// [`ProbePool`] consults top-down before computing, and back-fills
/// with hits from lower tiers and fresh results.
///
/// `get` must only ever return a value that was `put` for exactly that
/// key — tiers trade recomputation for lookup, never results.  `put`
/// is best-effort (a full or failing tier may drop writes).
pub trait ProbeTier<K, V>: Send + Sync {
    fn get(&self, key: &K) -> Option<V>;
    fn put(&self, key: &K, value: &V);

    /// Stable name for per-tier observability (`cache.{kind}.{tier}.*`
    /// counters, `cache.lookup` span attributes).  In-memory memos are
    /// `"memo"`; the persistent [`DiskStore`] overrides to `"disk"`.
    fn tier_name(&self) -> &'static str {
        "memo"
    }
}

impl<K, V> ProbeTier<K, V> for ProbeCache<K, V>
where
    K: Clone + Eq + Hash + Send,
    V: Clone + Send,
{
    fn get(&self, key: &K) -> Option<V> {
        ProbeCache::get(self, key)
    }

    fn put(&self, key: &K, value: &V) {
        self.insert(key.clone(), value.clone());
    }
}

/// The shared tier bundle the engine threads through a run: one
/// in-memory memo per probe kind, an optional persistent disk tier,
/// and the probe-issue counters aggregated across every pool built
/// from the bundle (the budgeted-search driver reports them per run).
///
/// Sharing never changes results (every key incorporates the complete
/// evaluation input), only how often a probe is recomputed.
#[derive(Debug, Clone, Default)]
pub struct ProbeTiers {
    pub eval: Arc<EvalCache>,
    pub hw: Arc<HwCache>,
    /// Persistent tier consulted after the memos; fresh results are
    /// written through so they survive the process.
    pub disk: Option<Arc<DiskStore>>,
    pub stats: Arc<ProbeStats>,
    /// Persistent worker pools keyed by width: every pool/service built
    /// from this bundle at the same `jobs` shares one set of OS threads
    /// (nested searches call [`Self::service`] per O-task run — those
    /// must not spawn threads each time).  Waiters drain their own
    /// batches, so pools of different widths can nest without deadlock.
    workers: Arc<Mutex<HashMap<usize, Arc<WorkerPool>>>>,
}

impl ProbeTiers {
    /// In-memory tiers only (the explorer/search default).
    pub fn new() -> Self {
        Self::default()
    }

    /// In-memory tiers backed by a persistent `store` (the CLI's
    /// `--cache-dir`).
    pub fn with_disk(store: Arc<DiskStore>) -> Self {
        ProbeTiers { disk: Some(store), ..Self::default() }
    }

    /// A pool over these shared tiers and counters.
    pub fn pool(&self, jobs: usize) -> ProbePool {
        ProbePool::with_tiers(jobs, self)
    }

    /// The same pool as a shared [`ProbeService`] handle (what
    /// [`crate::flow::TaskCtx::probes`] hands to the O-task searches).
    pub fn service(&self, jobs: usize) -> Arc<dyn ProbeService> {
        Arc::new(self.pool(jobs))
    }

    /// Probe totals issued/computed through every pool of this bundle.
    pub fn probe_counts(&self) -> ProbeCounts {
        self.stats.snapshot()
    }

    /// The shared persistent [`WorkerPool`] for `jobs` workers,
    /// creating (and thereafter reusing) it on first request.
    pub(crate) fn worker_pool(&self, jobs: usize) -> Arc<WorkerPool> {
        let jobs = jobs.max(1);
        let mut pools = self.workers.lock().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(
            pools
                .entry(jobs)
                .or_insert_with(|| Arc::new(WorkerPool::new(jobs))),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::pool::ProbePool;

    /// The extension-trait run_batch must match the pool's own batch
    /// executor exactly: request order, first error in index order,
    /// empty batches.
    #[test]
    fn ext_run_batch_matches_pool_contract() {
        let pool = ProbePool::new(4);
        let service: &dyn ProbeService = &pool;
        let out = service.run_batch(33, |i| Ok(i * i)).unwrap();
        assert_eq!(out, (0..33).map(|i| i * i).collect::<Vec<_>>());

        let res: Result<Vec<usize>> = service.run_batch(10, |i| {
            if i == 3 || i == 7 {
                Err(Error::other(format!("boom {i}")))
            } else {
                Ok(i)
            }
        });
        assert_eq!(res.unwrap_err().to_string(), "boom 3");

        let empty: Vec<usize> = service.run_batch(0, |_| unreachable!()).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn default_run_raw_is_sequential_and_ordered() {
        struct Sequential;
        impl ProbeService for Sequential {
            fn evaluate_batch(
                &self,
                _trainer: &Trainer,
                _requests: &[ProbeRequest],
            ) -> Result<Vec<ProbeResult>> {
                unreachable!()
            }
            fn estimate_batch(
                &self,
                _device: &FpgaDevice,
                _clock_mhz: f64,
                _requests: &[HwProbeRequest],
            ) -> Result<Vec<HwProbeResult>> {
                unreachable!()
            }
            fn jobs(&self) -> usize {
                1
            }
            fn counts(&self) -> ProbeCounts {
                ProbeCounts::default()
            }
        }
        let out = Sequential.run_batch(5, |i| Ok(i + 1)).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn tiers_pool_shares_stats_across_pools() {
        let tiers = ProbeTiers::new();
        let a = tiers.pool(1);
        let b = tiers.service(4);
        assert_eq!(a.jobs(), 1);
        assert_eq!(b.jobs(), 4);
        assert_eq!(tiers.probe_counts(), ProbeCounts::default());
    }

    #[test]
    fn tiers_share_one_worker_pool_per_width() {
        let tiers = ProbeTiers::new();
        let a = tiers.worker_pool(4);
        let b = tiers.worker_pool(4);
        let c = tiers.worker_pool(2);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(a.jobs(), 4);
        assert_eq!(c.jobs(), 2);
    }

    #[test]
    fn submit_batch_returns_results_in_order() {
        let pool = ProbePool::new(4);
        let svc: &dyn ProbeService = &pool;
        let batch = submit_batch(svc, 33, |i| Ok(i * i));
        assert_eq!(batch.wait().unwrap(), (0..33).map(|i| i * i).collect::<Vec<_>>());

        // jobs = 1: submit runs inline on the caller (ticket sentinel),
        // same results, same order.
        let inline = ProbePool::new(1);
        let svc: &dyn ProbeService = &inline;
        let batch = submit_batch(svc, 5, |i| Ok(i + 1));
        assert_eq!(batch.wait().unwrap(), vec![1, 2, 3, 4, 5]);
        let mut batch = submit_batch(svc, 2, |i| Ok(i));
        assert!(!batch.try_cancel()); // inline work already ran
        assert_eq!(batch.wait().unwrap(), vec![0, 1]);
    }

    #[test]
    fn submit_batch_propagates_first_error_in_index_order() {
        let pool = ProbePool::new(4);
        let svc: &dyn ProbeService = &pool;
        let batch = submit_batch(svc, 10, |i| {
            if i == 3 || i == 7 {
                Err(Error::other(format!("boom {i}")))
            } else {
                Ok(i)
            }
        });
        assert_eq!(batch.wait().unwrap_err().to_string(), "boom 3");
    }

    #[test]
    fn try_cancel_is_deterministic_when_the_only_worker_is_busy() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let pool = ProbePool::new(2); // exactly one spawned worker
        let svc: &dyn ProbeService = &pool;
        let gate = Mutex::new(());
        let ran_b = AtomicUsize::new(0);

        let guard = gate.lock().unwrap_or_else(PoisonError::into_inner);
        // A blocks the only worker on the gate (or, if the worker is
        // slow, sits ahead of B in the FIFO queue — either way B can
        // never start before A completes).
        let a = submit_batch(svc, 1, |_| {
            drop(gate.lock().unwrap_or_else(PoisonError::into_inner));
            Ok(1usize)
        });
        let mut b = submit_batch(svc, 1, |_| {
            ran_b.fetch_add(1, Ordering::SeqCst);
            Ok(2usize)
        });
        // B provably unstarted → cancel must succeed, deterministically.
        assert!(b.try_cancel());
        assert!(!b.try_cancel()); // already dead
        drop(guard);
        assert_eq!(a.wait().unwrap(), vec![1]);
        drop(b); // must not wait or run anything
        assert_eq!(ran_b.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn dropped_batch_still_executes_as_cache_fodder() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let pool = ProbePool::new(4);
        let svc: &dyn ProbeService = &pool;
        let ran = AtomicUsize::new(0);
        let batch = submit_batch(svc, 6, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        drop(batch); // drop-wait: all jobs complete before this returns
        assert_eq!(ran.load(Ordering::SeqCst), 6);
    }
}
