//! Disk-backed probe-result tier: a versioned, corruption-tolerant,
//! append-only store beneath `--cache-dir`.
//!
//! Results are persisted under the same complete-input fingerprint
//! keys the in-memory memos use ([`EvalKey`] for training probes,
//! [`HwKey`] for hardware probes), so a hit can only ever replace a
//! bit-identical recomputation — loading a store never changes a
//! trace, only skips work.  A second identical `metaml explore
//! --cache-dir DIR` run therefore issues zero fresh probe
//! computations.
//!
//! ## On-disk format
//!
//! One file, `probes.jsonl`, one record per line:
//!
//! ```text
//! v1 <kind> <checksum> <payload>
//! ```
//!
//! where `kind` is `train` or `hw`, `checksum` is the 16-hex-digit
//! FNV-1a of the payload bytes, and `payload` is a single-line JSON
//! object `{"key": …, "value": …}`.  Every `f64` and `u64` field is
//! serialized as the 16-hex-digit string of its bit pattern — the
//! in-tree JSON number is an `f64`, which cannot hold either
//! losslessly — so round-trips are bit-exact (including NaN and
//! `-0.0`).  `usize` counters are plain JSON numbers (all far below
//! 2^53).
//!
//! ## Robustness
//!
//! - **Corruption-tolerant load**: truncated, garbage, checksum-failed
//!   or version-mismatched lines are counted and skipped, never a
//!   panic or error; valid entries around them still load.  Skipped
//!   entries are simply recomputed and appended again by the next run.
//! - **Concurrent writers**: the file is opened in `O_APPEND` mode and
//!   each record is one `write_all` of one line, so two processes
//!   sharing a `--cache-dir` interleave whole records, not bytes.
//!   Duplicate keys are harmless (values are bit-identical by
//!   construction; last one wins on load).
//! - **Best-effort writes**: a failing disk drops the write and keeps
//!   the run going — the store is a cache, not a database.

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, PoisonError};

use crate::dse::cache::{EvalKey, Fnv};
use crate::dse::hw::{HwEval, HwKey};
use crate::dse::service::ProbeTier;
use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::train::EvalResult;

/// Store format version; bump on any codec change so old stores are
/// skipped (and lazily rewritten), never misread.
const VERSION: &str = "v1";
const STORE_FILE: &str = "probes.jsonl";

/// Summary counters for `metaml cache stats` and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Distinct training-probe entries loaded.
    pub train_entries: usize,
    /// Distinct hardware-probe entries loaded.
    pub hw_entries: usize,
    /// Lines skipped on load (truncated / garbage / version mismatch /
    /// checksum failure).
    pub skipped: usize,
    /// Store file size in bytes (0 if absent).
    pub bytes: u64,
}

/// The persistent probe-result tier (see module docs for format and
/// guarantees).  Cheap lookups come from an in-memory image of the
/// file loaded once at `open`; `put` appends through an `O_APPEND`
/// handle.
#[derive(Debug)]
pub struct DiskStore {
    path: PathBuf,
    file: Mutex<File>,
    train: Mutex<HashMap<EvalKey, EvalResult>>,
    hw: Mutex<HashMap<HwKey, HwEval>>,
    skipped: usize,
}

impl DiskStore {
    /// Open (creating if needed) the store beneath `dir`, loading every
    /// valid record and counting the rest as skipped.
    pub fn open(dir: &Path) -> Result<DiskStore> {
        fs::create_dir_all(dir).map_err(Error::Io)?;
        let path = dir.join(STORE_FILE);
        let mut train = HashMap::new();
        let mut hw = HashMap::new();
        let mut skipped = 0usize;
        if let Ok(bytes) = fs::read(&path) {
            let text = String::from_utf8_lossy(&bytes);
            for line in text.lines() {
                if line.is_empty() {
                    continue;
                }
                match parse_record(line) {
                    Some(Record::Train(k, v)) => {
                        train.insert(k, v);
                    }
                    Some(Record::Hw(k, v)) => {
                        hw.insert(k, v);
                    }
                    None => skipped += 1,
                }
            }
        }
        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&path)
            .map_err(Error::Io)?;
        Ok(DiskStore {
            path,
            file: Mutex::new(file),
            train: Mutex::new(train),
            hw: Mutex::new(hw),
            skipped,
        })
    }

    /// Read-only stats for `dir` without creating anything (`metaml
    /// cache stats` must not materialize an empty store).
    pub fn inspect(dir: &Path) -> StoreStats {
        let path = dir.join(STORE_FILE);
        let mut stats = StoreStats::default();
        let Ok(bytes) = fs::read(&path) else {
            return stats;
        };
        stats.bytes = bytes.len() as u64;
        let mut train = HashMap::new();
        let mut hw = HashMap::new();
        let text = String::from_utf8_lossy(&bytes);
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            match parse_record(line) {
                Some(Record::Train(k, v)) => {
                    train.insert(k, v);
                }
                Some(Record::Hw(k, v)) => {
                    hw.insert(k, v);
                }
                None => stats.skipped += 1,
            }
        }
        stats.train_entries = train.len();
        stats.hw_entries = hw.len();
        stats
    }

    /// Delete the store file beneath `dir`; returns whether one existed.
    pub fn clear(dir: &Path) -> Result<bool> {
        let path = dir.join(STORE_FILE);
        match fs::remove_file(&path) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(Error::Io(e)),
        }
    }

    /// Stats of this open store (entry counts from the in-memory image,
    /// bytes from the file).
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            train_entries: self.lock_train().len(),
            hw_entries: self.lock_hw().len(),
            skipped: self.skipped,
            bytes: fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0),
        }
    }

    /// Path of the backing `probes.jsonl`.
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn get_train(&self, key: &EvalKey) -> Option<EvalResult> {
        self.lock_train().get(key).copied()
    }

    pub fn put_train(&self, key: &EvalKey, value: &EvalResult) {
        // Only a fresh in-memory insert appends: re-putting a key the
        // store already holds (warm-run back-pressure) writes nothing,
        // so warm runs leave the file byte-identical.
        if self.lock_train().insert(key.clone(), *value).is_none() {
            self.append("train", &train_payload(key, value));
        }
    }

    pub fn get_hw(&self, key: &HwKey) -> Option<HwEval> {
        self.lock_hw().get(key).copied()
    }

    pub fn put_hw(&self, key: &HwKey, value: &HwEval) {
        if self.lock_hw().insert(key.clone(), *value).is_none() {
            self.append("hw", &hw_payload(key, value));
        }
    }

    fn lock_train(&self) -> std::sync::MutexGuard<'_, HashMap<EvalKey, EvalResult>> {
        self.train.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_hw(&self) -> std::sync::MutexGuard<'_, HashMap<HwKey, HwEval>> {
        self.hw.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Append one record line; errors are swallowed (best-effort cache).
    fn append(&self, kind: &str, payload: &Value) {
        let json = json::to_string_compact(payload);
        let mut sum = Fnv::new();
        sum.bytes(json.as_bytes());
        let line = format!("{VERSION} {kind} {} {json}\n", hex64(sum.0));
        let mut file = self.file.lock().unwrap_or_else(PoisonError::into_inner);
        let _ = file.write_all(line.as_bytes());
    }
}

impl ProbeTier<EvalKey, EvalResult> for DiskStore {
    fn get(&self, key: &EvalKey) -> Option<EvalResult> {
        self.get_train(key)
    }

    fn put(&self, key: &EvalKey, value: &EvalResult) {
        self.put_train(key, value);
    }

    fn tier_name(&self) -> &'static str {
        "disk"
    }
}

impl ProbeTier<HwKey, HwEval> for DiskStore {
    fn get(&self, key: &HwKey) -> Option<HwEval> {
        self.get_hw(key)
    }

    fn put(&self, key: &HwKey, value: &HwEval) {
        self.put_hw(key, value);
    }

    fn tier_name(&self) -> &'static str {
        "disk"
    }
}

enum Record {
    Train(EvalKey, EvalResult),
    Hw(HwKey, HwEval),
}

/// Parse one store line; `None` on any defect (wrong version, bad
/// checksum, truncated or malformed payload).
fn parse_record(line: &str) -> Option<Record> {
    let mut parts = line.splitn(4, ' ');
    let version = parts.next()?;
    let kind = parts.next()?;
    let checksum = parts.next()?;
    let payload = parts.next()?;
    if version != VERSION {
        return None;
    }
    let mut sum = Fnv::new();
    sum.bytes(payload.as_bytes());
    if parse_hex64(checksum)? != sum.0 {
        return None;
    }
    let v = json::parse(payload).ok()?;
    let key = v.get("key")?;
    let value = v.get("value")?;
    match kind {
        "train" => {
            let (k, r) = parse_train(key, value)?;
            Some(Record::Train(k, r))
        }
        "hw" => {
            let (k, r) = parse_hw(key, value)?;
            Some(Record::Hw(k, r))
        }
        _ => None,
    }
}

fn train_payload(key: &EvalKey, value: &EvalResult) -> Value {
    let mut k = Value::object();
    k.set("tag", key.tag.as_str());
    k.set(
        "precisions",
        Value::Array(
            key.precisions
                .iter()
                .map(|&(t, i)| Value::from(vec![t as usize, i as usize]))
                .collect(),
        ),
    );
    k.set("fingerprint", hex64(key.fingerprint));
    let mut v = Value::object();
    v.set("loss", hex64(value.loss.to_bits()));
    v.set("accuracy", hex64(value.accuracy.to_bits()));
    v.set("n", value.n);
    let mut rec = Value::object();
    rec.set("key", k);
    rec.set("value", v);
    rec
}

fn parse_train(key: &Value, value: &Value) -> Option<(EvalKey, EvalResult)> {
    let tag = key.get("tag")?.as_str()?.to_string();
    let precisions = key
        .get("precisions")?
        .as_array()?
        .iter()
        .map(|p| {
            let pair = p.as_array()?;
            if pair.len() != 2 {
                return None;
            }
            Some((pair[0].as_usize()? as u32, pair[1].as_usize()? as u32))
        })
        .collect::<Option<Vec<_>>>()?;
    let fingerprint = hex_field(key, "fingerprint")?;
    let k = EvalKey { tag, precisions, fingerprint };
    let r = EvalResult {
        loss: f64::from_bits(hex_field(value, "loss")?),
        accuracy: f64::from_bits(hex_field(value, "accuracy")?),
        n: value.get("n")?.as_usize()?,
    };
    Some((k, r))
}

fn hw_payload(key: &HwKey, value: &HwEval) -> Value {
    let mut k = Value::object();
    k.set("device", key.device.as_str());
    k.set("clock", hex64(key.clock_mhz_bits));
    k.set("reuse", Value::from(key.reuse.clone()));
    k.set("fingerprint", hex64(key.fingerprint));
    let mut v = Value::object();
    v.set("dsp", value.dsp);
    v.set("lut", value.lut);
    v.set("ff", value.ff);
    v.set("bram", value.bram_18k);
    v.set("cycles", value.latency_cycles);
    v.set("latency_ns", hex64(value.latency_ns.to_bits()));
    v.set("ii", value.ii);
    v.set("power_w", hex64(value.power_w.to_bits()));
    v.set("fits", value.fits);
    let mut rec = Value::object();
    rec.set("key", k);
    rec.set("value", v);
    rec
}

fn parse_hw(key: &Value, value: &Value) -> Option<(HwKey, HwEval)> {
    let device = key.get("device")?.as_str()?.to_string();
    let clock_mhz_bits = hex_field(key, "clock")?;
    let reuse = key
        .get("reuse")?
        .as_array()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Option<Vec<_>>>()?;
    let fingerprint = hex_field(key, "fingerprint")?;
    let k = HwKey { device, clock_mhz_bits, reuse, fingerprint };
    let r = HwEval {
        dsp: value.get("dsp")?.as_usize()?,
        lut: value.get("lut")?.as_usize()?,
        ff: value.get("ff")?.as_usize()?,
        bram_18k: value.get("bram")?.as_usize()?,
        latency_cycles: value.get("cycles")?.as_usize()?,
        latency_ns: f64::from_bits(hex_field(value, "latency_ns")?),
        ii: value.get("ii")?.as_usize()?,
        power_w: f64::from_bits(hex_field(value, "power_w")?),
        fits: value.get("fits")?.as_bool()?,
    };
    Some((k, r))
}

/// 16-hex-digit rendering of a bit pattern (`u64` fields and `f64`
/// bits both travel this way — the in-tree JSON number is an `f64`
/// and cannot hold either losslessly).
fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex64(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

fn hex_field(v: &Value, key: &str) -> Option<u64> {
    parse_hex64(v.get(key)?.as_str()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("metaml_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sample_train() -> (EvalKey, EvalResult) {
        (
            EvalKey {
                tag: "jet dnn \"quoted\"".to_string(),
                precisions: vec![(8, 4), (16, 6)],
                fingerprint: 0xdead_beef_cafe_f00d,
            },
            EvalResult { loss: 0.125, accuracy: 0.876_543_210_123, n: 1660 },
        )
    }

    fn sample_hw() -> (HwKey, HwEval) {
        (
            HwKey {
                device: "xcu250".to_string(),
                clock_mhz_bits: 200.0f64.to_bits(),
                reuse: vec![1, 8, 64],
                fingerprint: 0x0123_4567_89ab_cdef,
            },
            HwEval {
                dsp: 123,
                lut: 45_678,
                ff: 9_012,
                bram_18k: 34,
                latency_cycles: 567,
                latency_ns: 2_835.5,
                ii: 8,
                power_w: 1.75,
                fits: true,
            },
        )
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let dir = tmpdir("disk_roundtrip");
        let (ek, er) = sample_train();
        let (hk, he) = sample_hw();
        // NaN and -0.0 must survive the hex codec too.
        let weird = EvalResult { loss: f64::NAN, accuracy: -0.0, n: 0 };
        let wk = EvalKey { tag: "weird".into(), precisions: vec![], fingerprint: 1 };
        {
            let store = DiskStore::open(&dir).unwrap();
            store.put_train(&ek, &er);
            store.put_train(&wk, &weird);
            store.put_hw(&hk, &he);
            // duplicate put must not append a second record
            store.put_train(&ek, &er);
            assert_eq!(store.stats().train_entries, 2);
        }
        let store = DiskStore::open(&dir).unwrap();
        assert_eq!(store.stats().skipped, 0);
        assert_eq!(store.get_train(&ek), Some(er));
        assert_eq!(store.get_hw(&hk), Some(he));
        let w = store.get_train(&wk).unwrap();
        assert_eq!(w.loss.to_bits(), f64::NAN.to_bits());
        assert_eq!(w.accuracy.to_bits(), (-0.0f64).to_bits());
        // exactly three records on disk
        let text = fs::read_to_string(store.path()).unwrap();
        assert_eq!(text.lines().count(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_lines_are_skipped_not_fatal() {
        let dir = tmpdir("disk_corrupt");
        let (ek, er) = sample_train();
        {
            let store = DiskStore::open(&dir).unwrap();
            store.put_train(&ek, &er);
        }
        let path = dir.join(STORE_FILE);
        let good = fs::read_to_string(&path).unwrap();
        let good_line = good.lines().next().unwrap();
        // corruption zoo: garbage, truncation, wrong version, bad
        // checksum, checksummed-but-unparseable payload, unknown kind
        let bad_checksum = {
            let mut parts: Vec<&str> = good_line.splitn(4, ' ').collect();
            parts[2] = "0000000000000000";
            parts.join(" ")
        };
        let mut sum = Fnv::new();
        sum.bytes(b"{\"not\":\"a record\"}");
        let valid_sum_bad_payload =
            format!("v1 train {} {{\"not\":\"a record\"}}", hex64(sum.0));
        let mut kind_sum = Fnv::new();
        kind_sum.bytes(b"{}");
        let unknown_kind = format!("v1 surrogate {} {{}}", hex64(kind_sum.0));
        let doctored = format!(
            "not json at all\n{}\nv0 train 0123456789abcdef {{}}\n{}\n{}\n{}\n{}\n",
            &good_line[..good_line.len() / 2],
            bad_checksum,
            valid_sum_bad_payload,
            unknown_kind,
            good_line,
        );
        fs::write(&path, doctored).unwrap();
        let store = DiskStore::open(&dir).unwrap();
        assert_eq!(store.get_train(&ek), Some(er));
        let stats = store.stats();
        assert_eq!(stats.train_entries, 1);
        assert_eq!(stats.skipped, 6);
        // a fresh put after corruption still works (rewrites happen
        // lazily, via recomputation)
        let (hk, he) = sample_hw();
        store.put_hw(&hk, &he);
        drop(store);
        let reopened = DiskStore::open(&dir).unwrap();
        assert_eq!(reopened.get_hw(&hk), Some(he));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn inspect_does_not_create_and_clear_reports_presence() {
        let dir = tmpdir("disk_inspect");
        assert_eq!(DiskStore::inspect(&dir), StoreStats::default());
        assert!(!dir.join(STORE_FILE).exists());
        assert!(!DiskStore::clear(&dir).unwrap());
        {
            let store = DiskStore::open(&dir).unwrap();
            let (ek, er) = sample_train();
            store.put_train(&ek, &er);
        }
        let stats = DiskStore::inspect(&dir);
        assert_eq!(stats.train_entries, 1);
        assert!(stats.bytes > 0);
        assert!(DiskStore::clear(&dir).unwrap());
        assert_eq!(DiskStore::inspect(&dir), StoreStats::default());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_interleave_whole_records() {
        let dir = tmpdir("disk_concurrent");
        let a = DiskStore::open(&dir).unwrap();
        let b = DiskStore::open(&dir).unwrap();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for i in 0..50u64 {
                    let (mut k, v) = sample_train();
                    k.fingerprint = i;
                    a.put_train(&k, &v);
                }
            });
            scope.spawn(|| {
                for i in 0..50u64 {
                    let (mut k, v) = sample_hw();
                    k.fingerprint = i;
                    b.put_hw(&k, &v);
                }
            });
        });
        // both stores' writes land whole; a fresh open sees all of them
        let merged = DiskStore::open(&dir).unwrap();
        let stats = merged.stats();
        assert_eq!(stats.skipped, 0);
        assert_eq!(stats.train_entries, 50);
        assert_eq!(stats.hw_entries, 50);
        let _ = fs::remove_dir_all(&dir);
    }
}
