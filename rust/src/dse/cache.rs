//! Memoizing caches for design-space-exploration probes.
//!
//! A DSE probe is not always "train-and-eval": the FPGA-stage searches
//! probe the synthesis estimator instead.  Both probe kinds share one
//! memo abstraction — [`ProbeCache`], a generic thread-safe map from a
//! complete-input key to a result — instantiated twice:
//!
//! * [`EvalCache`] (training probes), keyed by [`EvalKey`]: variant
//!   tag + per-layer precisions + a fingerprint of params/masks/dataset;
//! * [`crate::dse::HwCache`] (hardware probes), keyed by
//!   [`crate::dse::HwKey`]: device + clock + per-layer reuse factors +
//!   a fingerprint of the full HLS configuration.
//!
//! The memo is strictly correctness-first: a key incorporates *every*
//! input the evaluation depends on, so a hit can only ever replace a
//! bit-identical re-computation.  That deliberately means the
//! quantization rounds do **not** hit it — once a round folds an
//! accepted cut into the base precisions, every subsequent candidate is
//! a genuinely different network (the sequential search re-evaluated
//! them too).  Hits come from exact repeats: duplicate candidates
//! inside one batch, re-submitted configurations when a pool outlives
//! a search (re-entered flow tasks, ablation benches replaying a
//! config), and repeated base evaluations.
//!
//! Keys are `(variant tag, per-layer precisions, payload fingerprint)`:
//! the precisions are kept exact (they are the axis the quant search
//! moves along), while the rest of the evaluation context — parameter
//! and mask buffers plus the dataset spec the trainer evaluates on —
//! is folded into a 64-bit FNV-1a-style fingerprint.  Evaluation is a
//! pure function of exactly these inputs, so a key match is a result
//! match even when one pool outlives a search or is shared across
//! trainers; collisions would need two probe states agreeing on tag
//! *and* precisions *and* a 64-bit hash — negligible at DSE scale
//! (hundreds of probes).
//!
//! Candidate states share identical params/masks within one search, so
//! the per-probe fingerprint re-hashes constant data; it is kept cheap
//! (one xor-multiply per 64-bit word rather than byte-at-a-time FNV)
//! because a fingerprint pass is still orders of magnitude lighter than
//! the full-test-split evaluation it guards.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

use crate::data::DatasetSpec;
use crate::model::ModelState;
use crate::runtime::HostTensor;
use crate::train::EvalResult;

/// Incremental FNV-1a-style mix: one xor-multiply per 64-bit word
/// (coarser than byte-wise FNV, ample for a cache guarded by exact
/// tag + precisions).  `pub(crate)` so the hardware-probe key
/// ([`crate::dse::HwKey`]) fingerprints with the same function.
pub(crate) struct Fnv(pub(crate) u64);

impl Fnv {
    pub(crate) fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn word(&mut self, w: u64) {
        self.0 = (self.0 ^ w).wrapping_mul(0x100_0000_01b3);
    }

    pub(crate) fn bytes(&mut self, bs: &[u8]) {
        self.word(bs.len() as u64);
        for &b in bs {
            self.word(b as u64);
        }
    }

    fn tensor(&mut self, t: &HostTensor) {
        match t {
            HostTensor::F32 { shape, data } => {
                self.word(0xF32);
                self.word(shape.len() as u64);
                for &d in shape {
                    self.word(d as u64);
                }
                for &v in data {
                    self.word(v.to_bits() as u64);
                }
            }
            HostTensor::I32 { shape, data } => {
                self.word(0x132);
                self.word(shape.len() as u64);
                for &d in shape {
                    self.word(d as u64);
                }
                for &v in data {
                    self.word(v as u32 as u64);
                }
            }
        }
    }
}

/// Cache key identifying one evaluation: variant tag + exact per-layer
/// precisions + a fingerprint of the parameter/mask payload and the
/// dataset it is evaluated on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EvalKey {
    pub tag: String,
    /// `(total_bits, int_bits)` per weight layer, exact.
    pub precisions: Vec<(u32, u32)>,
    /// Fingerprint over params ++ masks bit patterns ++ dataset spec.
    pub fingerprint: u64,
}

impl EvalKey {
    /// Key for a candidate model state evaluated against `spec`'s
    /// dataset (the spec pins which test split the result is for, so a
    /// pool shared across trainers can never alias results).
    pub fn of(state: &ModelState, spec: &DatasetSpec) -> EvalKey {
        let mut h = Fnv::new();
        h.bytes(spec.name.as_bytes());
        h.word(spec.input_shape.len() as u64);
        for &d in &spec.input_shape {
            h.word(d as u64);
        }
        h.word(spec.n_classes as u64);
        h.word(spec.n_train as u64);
        h.word(spec.n_test as u64);
        h.word(spec.noise.to_bits());
        h.word(spec.seed);
        h.word(state.params.len() as u64);
        for t in &state.params {
            h.tensor(t);
        }
        h.word(state.masks.len() as u64);
        for t in &state.masks {
            h.tensor(t);
        }
        EvalKey {
            tag: state.tag.clone(),
            precisions: state
                .precisions
                .iter()
                .map(|p| (p.total_bits, p.int_bits))
                .collect(),
            fingerprint: h.0,
        }
    }
}

/// Thread-safe memo table for one kind of DSE probe, generic over the
/// key (the probe kind's complete-input identity) and the result.
///
/// The probe-kind abstraction: training probes and hardware-synthesis
/// probes differ only in what identifies an evaluation and what it
/// yields; the memoization semantics (exact-key hit, hit/miss
/// accounting, shared-across-pools correctness) are identical and live
/// here once.
#[derive(Debug)]
pub struct ProbeCache<K, V> {
    map: Mutex<HashMap<K, V>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<K, V> Default for ProbeCache<K, V> {
    fn default() -> Self {
        ProbeCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }
}

impl<K: Eq + Hash, V: Clone> ProbeCache<K, V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up a key, counting the hit/miss.
    pub fn get(&self, key: &K) -> Option<V> {
        let map = self.map.lock().unwrap_or_else(PoisonError::into_inner);
        match map.get(key) {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: K, result: V) {
        self.map
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(key, result);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Memo for training probes (the original probe kind).
pub type EvalCache = ProbeCache<EvalKey, EvalResult>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::Precision;

    fn toy_state() -> ModelState {
        ModelState {
            tag: "toy_s1000".into(),
            params: vec![
                HostTensor::from_f32(&[2, 2], vec![0.5, -1.0, 2.0, 0.0]).unwrap(),
                HostTensor::from_f32(&[2], vec![0.0, 0.0]).unwrap(),
            ],
            masks: vec![HostTensor::ones(&[2, 2])],
            precisions: vec![Precision::new(8, 3)],
            weight_param_idx: vec![0],
        }
    }

    fn toy_spec() -> DatasetSpec {
        DatasetSpec {
            name: "toy_sim".into(),
            input_shape: vec![2],
            n_classes: 2,
            n_train: 16,
            n_test: 8,
            noise: 0.5,
            seed: 3,
        }
    }

    #[test]
    fn identical_states_share_a_key() {
        let a = toy_state();
        let b = a.clone();
        let spec = toy_spec();
        assert_eq!(EvalKey::of(&a, &spec), EvalKey::of(&b, &spec));
    }

    #[test]
    fn key_distinguishes_params_masks_precisions_dataset() {
        let base = toy_state();
        let spec = toy_spec();
        let k0 = EvalKey::of(&base, &spec);

        let mut p = base.clone();
        p.params[0].as_f32_mut().unwrap()[0] = 0.5000001;
        assert_ne!(EvalKey::of(&p, &spec), k0, "param bit flip must change the key");

        let mut m = base.clone();
        m.masks[0].as_f32_mut().unwrap()[3] = 0.0;
        assert_ne!(EvalKey::of(&m, &spec), k0, "mask change must change the key");

        let mut q = base.clone();
        q.precisions[0] = Precision::new(7, 3);
        assert_ne!(EvalKey::of(&q, &spec), k0, "precision change must change the key");

        let mut other_data = toy_spec();
        other_data.seed = 4;
        assert_ne!(
            EvalKey::of(&base, &other_data),
            k0,
            "dataset change must change the key"
        );
    }

    #[test]
    fn cache_round_trip_and_counters() {
        let cache = EvalCache::new();
        let key = EvalKey::of(&toy_state(), &toy_spec());
        assert!(cache.get(&key).is_none());
        let result = EvalResult { loss: 0.25, accuracy: 0.75, n: 64 };
        cache.insert(key.clone(), result);
        assert_eq!(cache.get(&key), Some(result));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }
}
