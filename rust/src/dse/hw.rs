//! The hardware (synthesis) probe kind.
//!
//! FPGA-stage searches (REUSE_SEARCH, device/IO grid exploration) probe
//! the synthesis estimator instead of the trainer.  A hardware probe is
//! identified by its complete HLS configuration — not by DNN parameter
//! buffers — so its memo key is an *HLS-config fingerprint*: the target
//! device and clock, the per-compute-layer reuse factors kept exact
//! (they are the axis the reuse search moves along), and a fingerprint
//! folding in everything else the estimator reads (layer shapes,
//! precisions, nnz, IO type).
//!
//! Estimation is a pure function of exactly these inputs, so a key
//! match is a result match and sharing an [`HwCache`] across pools or
//! explorer variants can only skip recomputation of bit-identical
//! results — the same contract as the training-probe [`super::EvalCache`].

use crate::dse::cache::{Fnv, ProbeCache};
use crate::hls::ir::{HlsLayerKind, HlsModel, IoType};
use crate::synth::{FpgaDevice, SynthReport};

/// Memo for hardware probes.
pub type HwCache = ProbeCache<HwKey, HwEval>;

/// Cache key identifying one synthesis estimation: device + clock +
/// exact per-compute-layer reuse factors + a fingerprint of the rest of
/// the HLS configuration.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct HwKey {
    pub device: String,
    /// Bit pattern of the clock frequency (MHz).
    pub clock_mhz_bits: u64,
    /// Reuse factor per compute layer, exact.
    pub reuse: Vec<usize>,
    /// Fingerprint over IO type, layer shapes, precisions and nnz.
    pub fingerprint: u64,
}

impl HwKey {
    /// Key for estimating `model` on `device` at `clock_mhz`.
    pub fn of(model: &HlsModel, device: &FpgaDevice, clock_mhz: f64) -> HwKey {
        let mut h = Fnv::new();
        h.word(match model.io_type {
            IoType::Parallel => 0x10,
            IoType::Stream => 0x51,
        });
        h.word(model.layers.len() as u64);
        for l in &model.layers {
            h.word(match l.kind {
                HlsLayerKind::Dense => 1,
                HlsLayerKind::Conv2D => 2,
                HlsLayerKind::MaxPool2 => 3,
                HlsLayerKind::Flatten => 4,
                HlsLayerKind::ResidualAdd => 5,
            });
            h.bytes(l.name.as_bytes());
            h.word(l.n_in as u64);
            h.word(l.n_out as u64);
            h.word(l.kernel as u64);
            h.word(l.h as u64);
            h.word(l.w as u64);
            h.word(l.precision.total_bits as u64);
            h.word(l.precision.int_bits as u64);
            h.word(u64::from(l.precision.enabled()));
            h.word(l.total_weights as u64);
            h.word(l.nnz as u64);
        }
        HwKey {
            device: device.name.to_string(),
            clock_mhz_bits: clock_mhz.to_bits(),
            reuse: model
                .layers
                .iter()
                .filter(|l| l.is_compute())
                .map(|l| l.reuse_factor)
                .collect(),
            fingerprint: h.0,
        }
    }
}

/// The memoized outcome of one synthesis estimation: the whole-design
/// numbers a hardware search selects on (a compact [`SynthReport`]
/// summary; the full per-layer report is re-derived only for the
/// finally stored artifact).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwEval {
    pub dsp: usize,
    pub lut: usize,
    pub ff: usize,
    pub bram_18k: usize,
    pub latency_cycles: usize,
    pub latency_ns: f64,
    pub ii: usize,
    pub power_w: f64,
    pub fits: bool,
}

impl HwEval {
    pub fn from_report(r: &SynthReport) -> HwEval {
        HwEval {
            dsp: r.dsp,
            lut: r.lut,
            ff: r.ff,
            bram_18k: r.bram_18k,
            latency_cycles: r.latency_cycles,
            latency_ns: r.latency_ns,
            ii: r.ii,
            power_w: r.dynamic_power_w,
            fits: r.fits(),
        }
    }
}

/// One candidate HLS configuration to estimate.
pub struct HwProbeRequest {
    /// Caller-side tag echoed on the matching [`HwProbeResult`].
    pub id: usize,
    pub model: HlsModel,
}

impl HwProbeRequest {
    pub fn new(id: usize, model: HlsModel) -> Self {
        HwProbeRequest { id, model }
    }
}

/// Estimation of one candidate, in request order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwProbeResult {
    pub id: usize,
    pub eval: HwEval,
    /// Served from the memo (or a duplicate earlier in the batch).
    pub cached: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ir::tests::toy_model;
    use crate::hls::transform::{HlsTransform, SetPrecision, SetReuseFactor};
    use crate::model::state::Precision;

    fn vu9p() -> &'static FpgaDevice {
        FpgaDevice::by_name("vu9p").unwrap()
    }

    #[test]
    fn identical_configs_share_a_key() {
        let a = toy_model();
        let b = toy_model();
        assert_eq!(HwKey::of(&a, vu9p(), 200.0), HwKey::of(&b, vu9p(), 200.0));
    }

    #[test]
    fn key_distinguishes_reuse_precision_device_clock_io() {
        let base = toy_model();
        let k0 = HwKey::of(&base, vu9p(), 200.0);

        let mut rf = base.clone();
        SetReuseFactor(4).apply(&mut rf).unwrap();
        assert_ne!(HwKey::of(&rf, vu9p(), 200.0), k0, "reuse change");

        let mut q = base.clone();
        SetPrecision::all(Precision::new(8, 3)).apply(&mut q).unwrap();
        assert_ne!(HwKey::of(&q, vu9p(), 200.0), k0, "precision change");

        let mut io = base.clone();
        io.io_type = IoType::Stream;
        assert_ne!(HwKey::of(&io, vu9p(), 200.0), k0, "io type change");

        let u250 = FpgaDevice::by_name("u250").unwrap();
        assert_ne!(HwKey::of(&base, u250, 200.0), k0, "device change");
        assert_ne!(HwKey::of(&base, vu9p(), 100.0), k0, "clock change");

        let mut nnz = base.clone();
        nnz.layers[0].nnz -= 1;
        assert_ne!(HwKey::of(&nnz, vu9p(), 200.0), k0, "nnz change");
    }

    #[test]
    fn hw_cache_round_trip() {
        let cache = HwCache::new();
        let key = HwKey::of(&toy_model(), vu9p(), 200.0);
        assert!(cache.get(&key).is_none());
        let eval = HwEval {
            dsp: 10,
            lut: 100,
            ff: 50,
            bram_18k: 0,
            latency_cycles: 7,
            latency_ns: 35.0,
            ii: 1,
            power_w: 0.05,
            fits: true,
        };
        cache.insert(key.clone(), eval);
        assert_eq!(cache.get(&key), Some(eval));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }
}
