use super::*;

#[test]
fn parses_scalars() {
    assert_eq!(parse("true").unwrap(), Value::Bool(true));
    assert_eq!(parse("false").unwrap(), Value::Bool(false));
    assert_eq!(parse("null").unwrap(), Value::Null);
    assert_eq!(parse("42").unwrap(), Value::Number(42.0));
    assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
    assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
}

#[test]
fn parses_nested() {
    let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
    let a = v.get("a").unwrap().as_array().unwrap();
    assert_eq!(a.len(), 3);
    assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
    assert_eq!(v.get("d"), Some(&Value::Null));
}

#[test]
fn parses_escapes_and_unicode() {
    let v = parse(r#""a\n\t\"\\ A é""#).unwrap();
    assert_eq!(v.as_str(), Some("a\n\t\"\\ A é"));
}

#[test]
fn rejects_garbage() {
    assert!(parse("{").is_err());
    assert!(parse("[1,]").is_err());
    assert!(parse("tru").is_err());
    assert!(parse("1 2").is_err());
    assert!(parse("\"unterminated").is_err());
}

#[test]
fn roundtrips() {
    let src = r#"{"models": [{"tag": "jet", "shape": [16, 64], "scale": 0.75}], "version": 1}"#;
    let v = parse(src).unwrap();
    let s = to_string_pretty(&v);
    assert_eq!(parse(&s).unwrap(), v);
}

#[test]
fn typed_accessors() {
    let v = parse(r#"{"n": 3, "s": "x", "shape": [2, 4]}"#).unwrap();
    assert_eq!(v.req_usize("n").unwrap(), 3);
    assert_eq!(v.req_str("s").unwrap(), "x");
    assert_eq!(v.req_shape("shape").unwrap(), vec![2, 4]);
    assert!(v.req("missing").is_err());
    assert!(v.req_usize("s").is_err());
}

#[test]
fn builder_api() {
    let mut v = Value::object();
    v.set("x", 1.5).set("y", "z").set("arr", vec![1usize, 2]);
    let s = to_string_pretty(&v);
    assert_eq!(parse(&s).unwrap(), v);
}
