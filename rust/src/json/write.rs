//! JSON serialization (pretty, deterministic key order).

use super::Value;

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

/// Single-line rendering (no indentation, no newlines) — for
/// line-oriented stores like the disk probe cache, where one record
/// must stay one line.  Key order is deterministic (`Value::Object` is
/// a `BTreeMap`), so equal values render to equal strings.
pub fn to_string_compact(v: &Value) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Value::Object(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                push_indent(indent + 1, out);
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                push_indent(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push('}');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn push_indent(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push(' ');
    }
}
