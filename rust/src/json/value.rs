//! JSON value tree + typed accessors.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn object() -> Value {
        Value::Object(BTreeMap::new())
    }

    /// Insert into an object value (panics if not an object — builder use).
    pub fn set(&mut self, key: &str, val: impl Into<Value>) -> &mut Self {
        match self {
            Value::Object(map) => {
                map.insert(key.to_string(), val.into());
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(map) => map.get(key),
            _ => None,
        }
    }

    /// Typed object lookup, erroring with the key name for diagnostics.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("{key:?} is not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Manifest(format!("{key:?} is not a non-negative integer")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Manifest(format!("{key:?} is not a number")))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| Error::Manifest(format!("{key:?} is not an array")))
    }

    /// Parse an array of numbers into a shape vector.
    pub fn req_shape(&self, key: &str) -> Result<Vec<usize>> {
        self.req_array(key)?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Manifest(format!("{key:?} has non-integer dim")))
            })
            .collect()
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}

impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Number(n as f64)
    }
}

impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Number(n as f64)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::String(s)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}
