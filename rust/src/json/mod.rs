//! Minimal JSON support (parser + writer).
//!
//! The offline crate set has no serde facade, so MetaML carries its own
//! small JSON module: enough for the AOT `manifest.json`, flow-spec config
//! files and report emission.  Strict on structure, permissive on numbers
//! (everything is f64, like JavaScript).

mod parse;
mod value;
mod write;

pub use parse::parse;
pub use value::Value;
pub use write::{to_string_compact, to_string_pretty};

#[cfg(test)]
mod tests;
