//! Recursive-descent JSON parser.

use std::collections::BTreeMap;

use super::Value;
use crate::error::{Error, Result};

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::String(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(format!("unexpected byte {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    c => return Err(self.err(format!("bad escape \\{}", c as char))),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("bad number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}
