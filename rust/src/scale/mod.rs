//! Scaling substrate: layer-size search over the pre-lowered scale grid.

pub mod search;

pub use search::{scale_search, ScaleConfig, ScaleProbe, ScaleTrace};
