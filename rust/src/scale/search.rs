//! The SCALING O-task's automatic layer-size search (paper §V-B).
//!
//! Layer widths change tensor shapes, so each candidate scale is a
//! separate pre-lowered AOT variant (the manifest's scale grid).  The
//! search walks the grid downward from the current scale, retraining each
//! candidate, and stops when the accuracy loss vs the unscaled baseline
//! exceeds α_s (paper default 0.05% — essentially "free" shrinkage only).
//!
//! Under `jobs > 1` the grid is evaluated *speculatively* in
//! worker-count-sized waves through the [`ProbeService`]: each wave trains
//! `jobs` candidates concurrently, then the stop rule scans results in
//! grid order before the next wave launches.  Speculative work is
//! bounded by otherwise-idle capacity (at most `jobs - 1` discarded
//! trials, and wall-clock never exceeds the lazy walk), and the probe
//! trace is bit-identical to the sequential walk (which `jobs = 1`
//! still performs lazily, trial by trial).

use crate::dse::{ProbeService, ProbeServiceExt};
use crate::error::Result;
use crate::flow::session::Session;
use crate::model::ModelState;
use crate::train::{TrainConfig, Trainer};

#[derive(Debug, Clone)]
pub struct ScaleConfig {
    /// α_s: tolerated accuracy loss (paper sets 0.05% = 0.0005).
    pub tolerate_acc_loss: f64,
    /// Scale applied when auto-search is off (Table I default_scale_factor).
    pub default_scale_factor: f64,
    /// Auto-search the grid vs apply default_scale_factor once.
    pub auto: bool,
    /// Bound on candidate trials (Table I max_trials_num).
    pub max_trials: usize,
    pub train_epochs: usize,
    pub seed: u64,
    /// When scaling runs *after* pruning (Fig 5b), candidates inherit the
    /// pruned structure: each scaled model is re-pruned at this rate and
    /// briefly fine-tuned before evaluation.  0.0 = no inheritance.
    pub inherit_pruning_rate: f64,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig {
            tolerate_acc_loss: 0.0005,
            default_scale_factor: 0.5,
            auto: true,
            max_trials: 8,
            train_epochs: 4,
            seed: 29,
            inherit_pruning_rate: 0.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ScaleProbe {
    pub trial: usize,
    pub scale: f64,
    pub accuracy: f64,
    pub accepted: bool,
    pub params: usize,
}

#[derive(Debug)]
pub struct ScaleTrace {
    pub base_accuracy: f64,
    pub best_scale: f64,
    pub best_accuracy: f64,
    pub probes: Vec<ScaleProbe>,
}

/// Run the scaling search. Returns the trace plus the new (retrained)
/// state at the chosen scale; the caller re-binds executables for the
/// returned scale's variant tag.
pub fn scale_search(
    session: &Session,
    model: &str,
    current_scale: f64,
    base_accuracy: f64,
    cfg: &ScaleConfig,
    pool: &dyn ProbeService,
) -> Result<(ScaleTrace, ModelState, f64)> {
    let data = session.dataset(model)?;
    let grid = session.manifest.scales_for(model);
    let mut candidates: Vec<f64> = if cfg.auto {
        grid.iter().copied().filter(|&s| s < current_scale).collect()
    } else {
        // single trial at the closest grid point to the default factor
        let want = current_scale * cfg.default_scale_factor;
        let nearest = grid
            .iter()
            .copied()
            .min_by(|a, b| {
                (a - want).abs().partial_cmp(&(b - want).abs()).unwrap()
            })
            .filter(|&s| s < current_scale);
        nearest.into_iter().collect()
    };
    candidates.truncate(cfg.max_trials);

    let fit_cfg = |epochs| TrainConfig {
        epochs,
        seed: cfg.seed,
        ..TrainConfig::for_model(model)
    };

    // One candidate trial: bind the variant, train from scratch,
    // optionally inherit pruning, evaluate.  Pure per-scale work — the
    // speculative path runs this concurrently for the whole grid.
    let probe = |scale: f64| -> Result<(ModelState, f64, usize)> {
        let variant = session.manifest.variant(model, scale)?;
        let exec = session.executable(&variant.tag)?;
        let trainer = Trainer::new(&session.runtime, &exec, &data);
        let mut cand = ModelState::init(variant, cfg.seed);
        trainer.fit(&mut cand, &fit_cfg(cfg.train_epochs))?;
        if cfg.inherit_pruning_rate > 0.0 {
            cand.masks =
                crate::prune::global_magnitude_masks(&cand, cfg.inherit_pruning_rate)?;
            cand.apply_masks()?;
            trainer.fit(&mut cand, &fit_cfg(2))?;
        }
        let eval = trainer.evaluate(&cand)?;
        Ok((cand, eval.accuracy, variant.total_weights()))
    };

    // Speculative evaluation in worker-sized waves.  Per-trial outcomes
    // are wrapped so that errors past the stopping point are discarded
    // exactly as the lazy walk would never have hit them.
    let wave = pool.jobs().min(candidates.len()).max(1);
    let mut probes = Vec::new();
    let mut best: Option<(f64, ModelState, f64)> = None;
    'walk: for (wave_idx, chunk) in candidates.chunks(wave).enumerate() {
        let mut speculated: Vec<Option<Result<(ModelState, f64, usize)>>> =
            if wave > 1 {
                pool.run_batch(chunk.len(), |i| Ok(probe(chunk[i])))?
                    .into_iter()
                    .map(Some)
                    .collect()
            } else {
                (0..chunk.len()).map(|_| None).collect()
            };
        for (j, &scale) in chunk.iter().enumerate() {
            let (cand, accuracy, params) = match speculated[j].take() {
                Some(result) => result?,
                None => probe(scale)?,
            };
            let ok = base_accuracy - accuracy <= cfg.tolerate_acc_loss;
            probes.push(ScaleProbe {
                trial: wave_idx * wave + j + 1,
                scale,
                accuracy,
                accepted: ok,
                params,
            });
            if ok {
                best = Some((scale, cand, accuracy));
            } else {
                break 'walk; // grid walk stops at the first violation (paper)
            }
        }
    }

    let (best_scale, state, best_acc) = match best {
        Some(b) => b,
        None => {
            // no smaller scale acceptable: stay at the current scale
            let (state, accuracy, _) = probe(current_scale)?;
            (current_scale, state, accuracy)
        }
    };

    Ok((
        ScaleTrace {
            base_accuracy,
            best_scale,
            best_accuracy: best_acc,
            probes,
        },
        state,
        best_scale,
    ))
}
