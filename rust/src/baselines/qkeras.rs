//! QKeras / AutoQKeras baselines (Coelho et al., Nat. Mach. Intell. 2021).
//!
//! Q6 = uniform 6-bit quantized_bits QAT; QE / QB = AutoQKeras'
//! energy-optimized and bits-optimized heterogeneous configurations.
//! Each is reproduced as a fixed per-layer precision schedule trained
//! through our QAT pipeline (the qcfg operand of the AOT train step) and
//! synthesized by our estimator — measured rows, not transcriptions.

use crate::error::Result;
use crate::flow::Session;
use crate::hls::{HlsModel, IoType};
use crate::model::state::Precision;
use crate::model::ModelState;
use crate::synth::{self, FpgaDevice};
use crate::train::{TrainConfig, Trainer};

/// A published (Auto)QKeras design point for the jet tagger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QKerasVariant {
    /// Uniform 6-bit QAT (output head kept wide, QKeras default practice).
    Q6,
    /// AutoQKeras energy-minimized heterogeneous config.
    QE,
    /// AutoQKeras bit-minimized heterogeneous config.
    QB,
}

impl QKerasVariant {
    pub fn name(&self) -> &'static str {
        match self {
            QKerasVariant::Q6 => "QKeras Q6",
            QKerasVariant::QE => "AutoQKeras QE",
            QKerasVariant::QB => "AutoQKeras QB",
        }
    }

    /// Per-layer ap_fixed schedule for the 4-layer jet tagger.
    pub fn precisions(&self) -> Vec<Precision> {
        match self {
            QKerasVariant::Q6 => vec![
                Precision::new(6, 1),
                Precision::new(6, 1),
                Precision::new(6, 1),
                Precision::new(16, 6), // wide head
            ],
            QKerasVariant::QE => vec![
                Precision::new(4, 1),
                Precision::new(4, 1),
                Precision::new(6, 2),
                Precision::new(12, 4),
            ],
            QKerasVariant::QB => vec![
                Precision::new(4, 1),
                Precision::new(6, 2),
                Precision::new(4, 1),
                Precision::new(12, 4),
            ],
        }
    }
}

#[derive(Debug, Clone)]
pub struct QKerasDesign {
    pub name: String,
    pub accuracy: f64,
    pub report: synth::SynthReport,
}

/// Train the variant with QAT and synthesize it on `device`.
pub fn qkeras_design(
    session: &Session,
    variant_kind: QKerasVariant,
    device: &FpgaDevice,
) -> Result<QKerasDesign> {
    let variant = session.manifest.variant("jet_dnn", 1.0)?;
    let exec = session.executable(&variant.tag)?;
    let data = session.dataset("jet_dnn")?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);

    let mut state = ModelState::init(variant, 0x9143);
    let precisions = variant_kind.precisions();
    for (i, p) in state.precisions.iter_mut().enumerate() {
        *p = precisions[i.min(precisions.len() - 1)];
    }
    let mut tc = TrainConfig::for_model("jet_dnn");
    tc.epochs = 8; // QAT needs a little longer
    trainer.fit(&mut state, &tc)?;
    let eval = trainer.evaluate(&state)?;

    let hls = HlsModel::from_dnn(
        variant,
        &state,
        Precision::new(18, 8),
        IoType::Parallel,
        device.name,
        1000.0 / device.default_clock_mhz,
    )?;
    let report = synth::estimate(&hls, device, device.default_clock_mhz)?;
    Ok(QKerasDesign {
        name: variant_kind.name().to_string(),
        accuracy: eval.accuracy,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::cost;

    #[test]
    fn schedules_have_expected_bit_budgets() {
        let q6: u32 = QKerasVariant::Q6.precisions().iter().map(|p| p.total_bits).sum();
        let qe: u32 = QKerasVariant::QE.precisions().iter().map(|p| p.total_bits).sum();
        let qb: u32 = QKerasVariant::QB.precisions().iter().map(|p| p.total_bits).sum();
        // AutoQKeras configs use fewer bits than uniform Q6
        assert!(qe < q6);
        assert!(qb < q6);
    }

    #[test]
    fn only_wide_heads_use_dsps() {
        for v in [QKerasVariant::Q6, QKerasVariant::QE, QKerasVariant::QB] {
            let ps = v.precisions();
            // hidden layers below the DSP threshold
            assert!(ps[..3].iter().filter(|p| cost::uses_dsp(**p)).count() <= 1);
            // the head is DSP-mapped (the nonzero-DSP rows of Table II)
            assert!(cost::uses_dsp(ps[3]));
        }
    }
}
