//! LogicNets baseline (Umuroglu et al., FPL 2020) — JSC-M / JSC-L.
//!
//! LogicNets co-designs sparse, low-precision networks whose neurons map
//! directly to LUT truth tables: a neuron with fan-in η inputs of β bits
//! is an (η·β)-input boolean function, decomposable into 6-LUTs.  The
//! designs use **zero DSPs** and pay everything in LUTs.
//!
//! We implement (a) the LUT cost model from their paper (truth-table
//! decomposition with logic sharing) and (b) the training configuration —
//! an extremely sparse, 3–4-bit jet tagger trained through our own
//! pipeline — so the Table II row is measured, not transcribed.

use crate::error::Result;
use crate::flow::Session;
use crate::model::state::Precision;
use crate::model::ModelState;
use crate::prune::global_magnitude_masks;
use crate::train::{TrainConfig, Trainer};

/// One LogicNets network configuration.
#[derive(Debug, Clone)]
pub struct LogicNetsConfig {
    pub name: &'static str,
    /// Hidden layer widths of the published topology.
    pub neurons: &'static [usize],
    /// Fan-in per neuron (η).
    pub eta: usize,
    /// Activation bit-width (β).
    pub beta: u32,
    /// Pipeline cycles (one per layer; softmax removed in JSC-L).
    pub cycles: usize,
    /// Clock the paper reports (384 MHz for JSC-L).
    pub clock_mhz: f64,
    /// Which exported jet scale stands in for this topology's capacity.
    pub jet_scale: f64,
    /// Fine-tune epochs (larger nets train longer).
    pub epochs: usize,
}

/// Published configurations (LogicNets paper, jet-tagging variants).
pub const JSC_M: LogicNetsConfig = LogicNetsConfig {
    name: "LogicNets JSC-M",
    neurons: &[64, 32, 32, 5],
    eta: 4,
    beta: 3,
    cycles: 5,
    clock_mhz: 384.0,
    jet_scale: 0.375,
    epochs: 5,
};

pub const JSC_L: LogicNetsConfig = LogicNetsConfig {
    name: "LogicNets JSC-L",
    neurons: &[32, 64, 192, 192, 16],
    eta: 4,
    beta: 3,
    cycles: 5,
    clock_mhz: 384.0,
    jet_scale: 0.75,
    epochs: 8,
};

/// 6-LUT count for one W-input, 1-bit-output boolean function after
/// Shannon decomposition, with the paper's observed logic sharing.
fn lut6_per_bit(w_in: usize) -> f64 {
    if w_in <= 6 {
        return 1.0;
    }
    // full decomposition: 2^(W-6) leaf LUTs + (2^(W-6)-1)/5 mux levels
    let leaves = 2f64.powi(w_in as i32 - 6);
    let muxes = (leaves - 1.0) / 5.0;
    // synthesis sharing across the truth table (fit to published totals)
    0.55 * (leaves + muxes)
}

/// Measured LogicNets-style design point.
#[derive(Debug, Clone)]
pub struct LogicNetsDesign {
    pub name: String,
    pub accuracy: f64,
    pub lut: usize,
    pub dsp: usize,
    pub latency_cycles: usize,
    pub latency_ns: f64,
    pub power_w: f64,
}

/// LUT cost of a whole configuration.
pub fn lut_cost(cfg: &LogicNetsConfig) -> usize {
    let w_in = cfg.eta * cfg.beta as usize;
    let per_neuron = cfg.beta as f64 * lut6_per_bit(w_in);
    let neurons: usize = cfg.neurons.iter().sum();
    (neurons as f64 * per_neuron).round() as usize
}

/// Train the sparse/low-precision jet tagger the config implies and
/// measure its accuracy, then apply the LUT cost model.
pub fn logicnets_design(session: &Session, cfg: &LogicNetsConfig) -> Result<LogicNetsDesign> {
    // closest exported jet variant to the config's capacity (JSC-L is
    // wider than JSC-M, hence the larger stand-in scale)
    let variant = session.manifest.variant("jet_dnn", cfg.jet_scale)?;
    let exec = session.executable(&variant.tag)?;
    let data = session.dataset("jet_dnn")?;
    let trainer = Trainer::new(&session.runtime, &exec, &data);

    let mut state = ModelState::init(variant, 0x10c1c);
    // β-bit activations/weights
    for p in state.precisions.iter_mut() {
        *p = Precision::new(cfg.beta + 1, 1); // sign bit + β magnitude bits
    }
    // η-sparse connectivity: density η / fan-in per layer; approximate
    // with a global rate matching the average density
    let avg_fan: f64 = 16.0; // jet hidden fan-ins dominate
    let density = (cfg.eta as f64 / avg_fan).min(1.0);
    let mut tc = TrainConfig::for_model("jet_dnn");
    tc.epochs = cfg.epochs;
    trainer.fit(&mut state, &tc)?;
    state.masks = global_magnitude_masks(&state, 1.0 - density)?;
    state.apply_masks()?;
    let mut ft = tc.clone();
    ft.epochs = 4;
    trainer.fit(&mut state, &ft)?;
    let eval = trainer.evaluate(&state)?;

    let lut = lut_cost(cfg);
    Ok(LogicNetsDesign {
        name: cfg.name.to_string(),
        accuracy: eval.accuracy,
        lut,
        dsp: 0,
        latency_cycles: cfg.cycles,
        latency_ns: cfg.cycles as f64 * 1000.0 / cfg.clock_mhz,
        power_w: crate::synth::cost::power_w(0.0, lut as f64, cfg.clock_mhz),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_costs_in_published_ballpark() {
        // JSC-M published: 14,428 LUTs; JSC-L: 37,931 LUTs.
        let m = lut_cost(&JSC_M);
        let l = lut_cost(&JSC_L);
        assert!((10_000..25_000).contains(&m), "JSC-M {m}");
        assert!((25_000..70_000).contains(&l), "JSC-L {l}");
        assert!(l > m);
    }

    #[test]
    fn small_functions_fit_one_lut() {
        assert_eq!(lut6_per_bit(4), 1.0);
        assert_eq!(lut6_per_bit(6), 1.0);
        assert!(lut6_per_bit(12) > 30.0);
    }

    #[test]
    fn latency_matches_published_jscl() {
        // 5 cycles @ 384 MHz = 13 ns
        let ns = JSC_L.cycles as f64 * 1000.0 / JSC_L.clock_mhz;
        assert!((ns - 13.0).abs() < 0.1, "{ns}");
    }
}
