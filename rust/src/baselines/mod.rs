//! Table II comparator baselines, implemented from their papers' cost
//! models and training configurations (see DESIGN.md §1).

pub mod logicnets;
pub mod qkeras;

pub use logicnets::{logicnets_design, LogicNetsConfig};
pub use qkeras::{qkeras_design, QKerasVariant};
