//! The reusable pipe-task library (paper §IV, Table I).
//!
//! O-tasks (optimization): [PruningTask], [ScalingTask],
//! [QuantizationTask] (DNN stage) and [ReuseSearchTask] (FPGA stage).
//! λ-tasks (transformation): [ModelGenTask] (KERAS-MODEL-GEN), [Hls4mlTask],
//! [VivadoHlsTask].
//!
//! Tasks are stateless; all state lives in the meta-model, all heavy
//! compute in the session's AOT executables.

mod hls4ml;
mod model_gen;
mod pruning;
mod quantization;
mod reuse;
mod scaling;
mod vivado_hls;

pub use hls4ml::Hls4mlTask;
pub use model_gen::ModelGenTask;
pub use pruning::PruningTask;
pub use quantization::QuantizationTask;
pub use reuse::ReuseSearchTask;
pub use scaling::ScalingTask;
pub use vivado_hls::VivadoHlsTask;

pub(crate) mod util {
    use crate::error::{Error, Result};
    use crate::flow::TaskCtx;
    use crate::metamodel::{Abstraction, ModelArtifact};
    use crate::model::state::Precision;

    /// Latest DNN artifact in the model space (most tasks' input).
    pub fn latest_dnn(ctx: &TaskCtx) -> Result<ModelArtifact> {
        ctx.meta
            .space
            .latest(Abstraction::Dnn)
            .cloned()
            .ok_or_else(|| Error::other("no DNN model in the model space"))
    }

    /// Parse "ap_fixed<18,8>" / "float" into a Precision.
    pub fn parse_precision(s: &str) -> Result<Precision> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("float") || s.eq_ignore_ascii_case("none") {
            return Ok(Precision::DISABLED);
        }
        let inner = s
            .strip_prefix("ap_fixed<")
            .and_then(|r| r.strip_suffix('>'))
            .ok_or_else(|| Error::Config(format!("bad precision spec {s:?}")))?;
        let mut parts = inner.split(',');
        let total: u32 = parts
            .next()
            .and_then(|p| p.trim().parse().ok())
            .ok_or_else(|| Error::Config(format!("bad precision spec {s:?}")))?;
        let int: u32 = parts
            .next()
            .and_then(|p| p.trim().parse().ok())
            .ok_or_else(|| Error::Config(format!("bad precision spec {s:?}")))?;
        if parts.next().is_some() || int > total || total == 0 {
            return Err(Error::Config(format!("bad precision spec {s:?}")));
        }
        Ok(Precision::new(total, int))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parses_ap_fixed() {
            let p = parse_precision("ap_fixed<18,8>").unwrap();
            assert_eq!((p.total_bits, p.int_bits), (18, 8));
            assert_eq!(parse_precision("float").unwrap(), Precision::DISABLED);
            assert!(parse_precision("ap_fixed<8>").is_err());
            assert!(parse_precision("ap_fixed<4,8>").is_err());
            assert!(parse_precision("garbage").is_err());
        }
    }
}
