//! SCALING O-task: automatic layer-size reduction (Table I; §V-B).

use crate::error::Result;
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::metamodel::ModelPayload;
use crate::scale::{scale_search, ScaleConfig};

pub struct ScalingTask;

impl PipeTask for ScalingTask {
    fn name(&self) -> &str {
        "SCALING"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "default_scale_factor", description: "scale applied when auto off", default: Some("0.5") },
            ParamSpec { name: "tolerate_acc_loss", description: "α_s: accepted accuracy drop", default: Some("0.0005") },
            ParamSpec { name: "scale_auto", description: "walk the scale grid automatically", default: Some("true") },
            ParamSpec { name: "max_trials_num", description: "bound on candidate trials", default: Some("8") },
            ParamSpec { name: "train_test_dataset", description: "dataset (synthetic substitute)", default: Some("per-model") },
            ParamSpec { name: "train_epochs", description: "training epochs per trial", default: Some("4") },
            ParamSpec { name: "jobs", description: "DSE probe workers (default METAML_JOBS/auto)", default: Some("auto") },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = super::util::latest_dnn(ctx)?;
        let in_state = input.dnn()?;
        let variant = ctx.session.manifest.get(&in_state.tag)?.clone();
        let base_acc = match input.metric("accuracy") {
            Some(a) => a,
            None => {
                let exec = ctx.session.executable(&variant.tag)?;
                let data = ctx.session.dataset(&variant.model)?;
                let trainer =
                    crate::train::Trainer::new(&ctx.session.runtime, &exec, &data);
                trainer.evaluate(in_state)?.accuracy
            }
        };

        let cfg = ScaleConfig {
            tolerate_acc_loss: ctx.cfg_f64("tolerate_acc_loss", 0.0005),
            default_scale_factor: ctx.cfg_f64("default_scale_factor", 0.5),
            auto: ctx.cfg_bool("scale_auto", true),
            max_trials: ctx.cfg_usize("max_trials_num", 8),
            train_epochs: ctx.cfg_usize("train_epochs", 4),
            seed: ctx.cfg_usize("seed", 29) as u64,
            // when an upstream PRUNING task already pruned the model, the
            // scaled candidates must carry that structure (Fig 5b)
            inherit_pruning_rate: input.metric("pruning_rate").unwrap_or(0.0),
        };

        let pool = ctx.probes();
        let (trace, state, new_scale) = scale_search(
            ctx.session,
            &variant.model,
            variant.scale,
            base_acc,
            &cfg,
            pool.as_ref(),
        )?;
        for p in &trace.probes {
            ctx.log_metric("probe_scale", p.scale);
            ctx.log_metric("probe_accuracy", p.accuracy);
            ctx.log_metric("probe_params", p.params as f64);
        }
        ctx.log_metric("scale", new_scale);
        ctx.log_metric("accuracy", trace.best_accuracy);
        ctx.log_message(format!(
            "scaling: {} -> {} (acc {:.4} -> {:.4}, {} trials)",
            variant.scale,
            new_scale,
            trace.base_accuracy,
            trace.best_accuracy,
            trace.probes.len()
        ));

        let new_variant = ctx.session.manifest.variant(&variant.model, new_scale)?;
        let params = new_variant.total_weights() as f64;
        let id = ctx.meta.space.store(
            format!("{}_scaled", new_variant.tag),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Dnn(state),
        );
        ctx.meta.space.set_metric(id, "accuracy", trace.best_accuracy)?;
        ctx.meta.space.set_metric(id, "scale", new_scale)?;
        ctx.meta.space.set_metric(id, "params", params)?;
        if cfg.inherit_pruning_rate > 0.0 {
            ctx.meta
                .space
                .set_metric(id, "pruning_rate", cfg.inherit_pruning_rate)?;
        }
        Ok(TaskOutcome::produced([id]))
    }
}
