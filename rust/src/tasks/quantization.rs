//! QUANTIZATION O-task: HLS-level mixed-precision search (Table I; §V-B).
//!
//! In the paper this task rewrites `ap_fixed` types in the generated HLS
//! C++ via Artisan source-to-source transforms and validates accuracy by
//! co-simulation.  Here: the search runs against the AOT eval executable
//! (bit-exact ap_fixed emulation in the fused Pallas kernel), and the
//! chosen per-layer precisions are instrumented into the HLS model via
//! the SetPrecision pass, re-emitting the C++ supporting files.
//!
//! When no HLS model exists yet (order-ablation flows that quantize at
//! the DNN level), the task degrades gracefully and only updates the DNN
//! state's precisions.

use crate::error::Result;
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::hls::{codegen, HlsTransform, SetPrecision};
use crate::metamodel::{Abstraction, ModelPayload};
use crate::quant::{quantize_search, QuantConfig};
use crate::train::Trainer;

pub struct QuantizationTask;

impl PipeTask for QuantizationTask {
    fn name(&self) -> &str {
        "QUANTIZATION"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "tolerate_acc_loss", description: "α_q: accepted accuracy drop", default: Some("0.01") },
            ParamSpec { name: "tolerate_acc_loss_step", description: "α_q widening per back-edge re-execution (cross-stage feedback)", default: Some("0.0") },
            ParamSpec { name: "start_precision", description: "starting ap_fixed type", default: Some("ap_fixed<18,8>") },
            ParamSpec { name: "min_bits", description: "floor on per-layer total bits", default: Some("2") },
            ParamSpec { name: "train_test_dataset", description: "dataset (synthetic substitute)", default: Some("per-model") },
            ParamSpec { name: "jobs", description: "DSE probe workers (default METAML_JOBS/auto)", default: Some("auto") },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = super::util::latest_dnn(ctx)?;
        let mut state = input.dnn()?.clone();
        let variant = ctx.session.manifest.get(&state.tag)?.clone();

        // each back-edge re-execution (e.g. VIVADO-HLS → QUANTIZATION
        // while the design misses its resource budget) widens α_q by
        // `tolerate_acc_loss_step`, so the re-run searches deeper
        // instead of reproducing the previous result; the iteration
        // index comes from the LOG, keeping the task stateless
        let iteration = ctx.runs_started().saturating_sub(1);
        let alpha = ctx.cfg_f64("tolerate_acc_loss", 0.01)
            + ctx.cfg_f64("tolerate_acc_loss_step", 0.0) * iteration as f64;
        let cfg = QuantConfig {
            tolerate_acc_loss: alpha,
            start: super::util::parse_precision(
                &ctx.cfg_str("start_precision", "ap_fixed<18,8>"),
            )?,
            min_bits: ctx.cfg_usize("min_bits", 2) as u32,
        };
        ctx.log_metric("tolerate_acc_loss", alpha);

        let exec = ctx.session.executable(&variant.tag)?;
        let data = ctx.session.dataset(&variant.model)?;
        let trainer = Trainer::new(&ctx.session.runtime, &exec, &data);

        let pool = ctx.probes();
        let trace = quantize_search(&trainer, &mut state, &cfg, pool.as_ref())?;
        for p in &trace.probes {
            ctx.log_metric("probe_layer", p.layer as f64);
            ctx.log_metric("probe_bits", p.tried.total_bits as f64);
            ctx.log_metric("probe_accuracy", p.accuracy);
        }
        // hit counts depend on tier sharing/timing, so they are a side
        // note, not a replay-comparable LOG event
        let counts = pool.counts();
        ctx.log_note(
            "train_probes_cached",
            counts.train_issued.saturating_sub(counts.train_computed) as f64,
        );
        ctx.log_metric("accuracy", trace.final_accuracy);
        ctx.log_metric("bits_total", trace.bits_after as f64);
        ctx.log_message(format!(
            "quantization: {} -> {} total bits (acc {:.4} -> {:.4}); per-layer {}",
            trace.bits_before,
            trace.bits_after,
            trace.base_accuracy,
            trace.final_accuracy,
            state
                .precisions
                .iter()
                .map(|p| p.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ));

        // store the quantized DNN
        let dnn_id = ctx.meta.space.store(
            format!("{}_quantized", variant.tag),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Dnn(state.clone()),
        );
        ctx.meta.space.set_metric(dnn_id, "accuracy", trace.final_accuracy)?;
        ctx.meta.space.set_metric(dnn_id, "bits_total", trace.bits_after as f64)?;
        ctx.meta
            .space
            .set_metric(dnn_id, "scale", input.metric("scale").unwrap_or(1.0))?;
        if let Some(r) = input.metric("pruning_rate") {
            ctx.meta.space.set_metric(dnn_id, "pruning_rate", r)?;
        }
        let mut produced = vec![dnn_id];

        // instrument the precisions into the HLS model, if one exists
        if let Some(hls_art) = ctx.meta.space.latest(Abstraction::HlsCpp).cloned() {
            let mut hls = hls_art.hls()?.clone();
            let idxs = hls.compute_layer_indices();
            for (layer_i, &ir_i) in idxs.iter().enumerate() {
                if layer_i < state.precisions.len() {
                    let name = hls.layers[ir_i].name.clone();
                    SetPrecision::layer(name, state.precisions[layer_i])
                        .apply(&mut hls)?;
                }
            }
            let files = codegen::emit(&hls);
            let hls_id = ctx.meta.space.store(
                format!("{}_quantized_hls", variant.tag),
                ctx.instance.clone(),
                Some(hls_art.id),
                ModelPayload::Hls(hls),
            );
            for (name, content) in files {
                ctx.meta.space.add_supporting(hls_id, name, content)?;
            }
            ctx.meta
                .space
                .set_metric(hls_id, "accuracy", trace.final_accuracy)?;
            ctx.meta
                .space
                .set_metric(hls_id, "bits_total", trace.bits_after as f64)?;
            // carry search-provenance metrics so the final RTL row has them
            for key in ["pruning_rate", "scale"] {
                if let Some(v) = ctx.meta.space.get(dnn_id)?.metric(key) {
                    ctx.meta.space.set_metric(hls_id, key, v)?;
                }
            }
            produced.push(hls_id);
        }
        Ok(TaskOutcome::produced(produced))
    }
}
