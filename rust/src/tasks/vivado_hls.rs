//! VIVADO-HLS λ-task: synthesize the HLS model into an RTL report (Table I).

use crate::error::{Error, Result};
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::metamodel::{Abstraction, ModelPayload};
use crate::synth::{self, FpgaDevice};

pub struct VivadoHlsTask;

impl PipeTask for VivadoHlsTask {
    fn name(&self) -> &str {
        "VIVADO-HLS"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Lambda
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![ParamSpec {
            name: "project_dir",
            description: "HLS project directory (report naming only)",
            default: Some("metaml_prj"),
        }]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = ctx
            .meta
            .space
            .latest(Abstraction::HlsCpp)
            .cloned()
            .ok_or_else(|| Error::other("no HLS model in the model space"))?;
        let hls = input.hls()?.clone();

        let (device, clock_mhz) = FpgaDevice::target_of(&hls)?;
        let report = synth::estimate(&hls, device, clock_mhz)?;

        ctx.log_metric("dsp", report.dsp as f64);
        ctx.log_metric("lut", report.lut as f64);
        ctx.log_metric("ff", report.ff as f64);
        ctx.log_metric("bram", report.bram_18k as f64);
        ctx.log_metric("latency_cycles", report.latency_cycles as f64);
        ctx.log_metric("latency_ns", report.latency_ns);
        ctx.log_metric("power_w", report.dynamic_power_w);
        ctx.log_metric("ii", report.ii as f64);
        // guardable fit/utilization metrics: edge predicates (forward
        // or back) can condition on device fit and headroom
        ctx.log_metric("fits", if report.fits() { 1.0 } else { 0.0 });
        ctx.log_metric("dsp_pct", report.dsp_pct());
        ctx.log_metric("lut_pct", report.lut_pct());
        ctx.log_metric("ff_pct", report.ff_pct());
        ctx.log_metric("bram_pct", report.bram_pct());
        ctx.log_message(format!(
            "synthesized {}: {} DSP ({:.1}%), {} LUT ({:.1}%), {} cycles = {:.0} ns, {}",
            report.design,
            report.dsp,
            report.dsp_pct(),
            report.lut,
            report.lut_pct(),
            report.latency_cycles,
            report.latency_ns,
            if report.fits() { "fits" } else { "DOES NOT FIT" },
        ));

        let text = synth::report::render(&report);
        let metrics: Vec<(&str, f64)> = vec![
            ("dsp", report.dsp as f64),
            ("dsp_pct", report.dsp_pct()),
            ("lut", report.lut as f64),
            ("lut_pct", report.lut_pct()),
            ("ff", report.ff as f64),
            ("ff_pct", report.ff_pct()),
            ("bram", report.bram_18k as f64),
            ("bram_pct", report.bram_pct()),
            ("latency_cycles", report.latency_cycles as f64),
            ("latency_ns", report.latency_ns),
            ("power_w", report.dynamic_power_w),
            ("ii", report.ii as f64),
            ("fits", if report.fits() { 1.0 } else { 0.0 }),
        ];
        let id = ctx.meta.space.store(
            format!("{}_rtl", hls.name),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Rtl(report),
        );
        ctx.meta.space.add_supporting(id, "csynth.rpt", text)?;
        for (k, v) in metrics {
            ctx.meta.space.set_metric(id, k, v)?;
        }
        // carry model-quality metrics forward so the RTL artifact is the
        // single row source for Table II
        for key in ["accuracy", "pruning_rate", "scale", "bits_total"] {
            if let Some(v) = input.metric(key) {
                ctx.meta.space.set_metric(id, key, v)?;
            }
        }
        Ok(TaskOutcome::produced([id]))
    }
}
