//! PRUNING O-task: auto-pruning by binary search (Table I; §V-B, Fig 3).

use crate::error::Result;
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::metamodel::ModelPayload;
use crate::prune::{autoprune, AutopruneConfig};
use crate::train::Trainer;

pub struct PruningTask;

impl PipeTask for PruningTask {
    fn name(&self) -> &str {
        "PRUNING"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "tolerate_acc_loss", description: "α_p: accepted accuracy drop", default: Some("0.02") },
            ParamSpec { name: "pruning_rate_thresh", description: "β_p: binary-search stop width", default: Some("0.02") },
            ParamSpec { name: "train_test_dataset", description: "dataset (synthetic substitute)", default: Some("per-model") },
            ParamSpec { name: "train_epochs", description: "fine-tune epochs per probe", default: Some("2") },
            ParamSpec { name: "jobs", description: "DSE probe workers (default METAML_JOBS/auto)", default: Some("auto") },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = super::util::latest_dnn(ctx)?;
        let mut state = input.dnn()?.clone();
        let variant = ctx.session.manifest.get(&state.tag)?.clone();

        let cfg = AutopruneConfig {
            tolerate_acc_loss: ctx.cfg_f64("tolerate_acc_loss", 0.02),
            rate_threshold: ctx.cfg_f64("pruning_rate_thresh", 0.02),
            train_epochs: ctx.cfg_usize("train_epochs", 2),
            seed: ctx.cfg_usize("seed", 23) as u64,
        };

        let exec = ctx.session.executable(&variant.tag)?;
        let data = ctx.session.dataset(&variant.model)?;
        let trainer = Trainer::new(&ctx.session.runtime, &exec, &data);

        let pool = ctx.probes();
        let trace = autoprune(&trainer, &mut state, &cfg, pool.as_ref())?;
        for p in &trace.probes {
            ctx.log_metric("probe_rate", p.rate);
            ctx.log_metric("probe_accuracy", p.accuracy);
            ctx.log_metric("probe_accepted", if p.accepted { 1.0 } else { 0.0 });
        }
        ctx.log_metric("pruning_rate", trace.best_rate);
        ctx.log_metric("accuracy", trace.best_accuracy);
        ctx.log_message(format!(
            "auto-pruning: rate {:.1}% (base acc {:.4} -> {:.4}, {} probes)",
            100.0 * trace.best_rate,
            trace.base_accuracy,
            trace.best_accuracy,
            trace.probes.len()
        ));

        let nnz = state.nonzero_weights() as f64;
        let id = ctx.meta.space.store(
            format!("{}_pruned", variant.tag),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Dnn(state),
        );
        ctx.meta.space.set_metric(id, "accuracy", trace.best_accuracy)?;
        ctx.meta.space.set_metric(id, "pruning_rate", trace.best_rate)?;
        ctx.meta.space.set_metric(id, "nonzero_weights", nnz)?;
        ctx.meta
            .space
            .set_metric(id, "scale", input.metric("scale").unwrap_or(1.0))?;
        Ok(TaskOutcome::produced([id]))
    }
}
