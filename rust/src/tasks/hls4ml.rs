//! HLS4ML λ-task: translate the DNN model into an HLS C++ model (Table I).

use crate::error::Result;
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::hls::{codegen, HlsModel, HlsTransform, IoType, SetReuseFactor};
use crate::metamodel::ModelPayload;

pub struct Hls4mlTask;

impl PipeTask for Hls4mlTask {
    fn name(&self) -> &str {
        "HLS4ML"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Lambda
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "default_precision", description: "datapath type for unquantized layers", default: Some("ap_fixed<18,8>") },
            ParamSpec { name: "IOType", description: "io_parallel | io_stream", default: Some("io_parallel") },
            ParamSpec { name: "FPGA_part_number", description: "target device (name or part)", default: Some("vu9p") },
            ParamSpec { name: "clock_period", description: "target clock period (ns)", default: Some("5.0") },
            ParamSpec { name: "reuse_factor", description: "initial reuse factor (snapped per layer to a divisor of the fan-in)", default: Some("1") },
            ParamSpec { name: "test_dataset", description: "dataset for co-simulation", default: Some("per-model") },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = super::util::latest_dnn(ctx)?;
        let state = input.dnn()?;
        let variant = ctx.session.manifest.get(&state.tag)?.clone();

        let precision = super::util::parse_precision(
            &ctx.cfg_str("default_precision", "ap_fixed<18,8>"),
        )?;
        let io_type = match ctx.cfg_str("IOType", "io_parallel").as_str() {
            "io_stream" => IoType::Stream,
            _ => IoType::Parallel,
        };
        let part = ctx.cfg_str("FPGA_part_number", "vu9p");
        let clock_ns = ctx.cfg_f64("clock_period", 5.0);
        let reuse = ctx.cfg_usize("reuse_factor", 1);

        let mut hls =
            HlsModel::from_dnn(&variant, state, precision, io_type, &part, clock_ns)?;
        if reuse > 1 {
            // hardware grid dimension: an explore spec ranging over
            // `hls.reuse_factor` lands here (snapped to legality)
            SetReuseFactor(reuse).apply(&mut hls)?;
        }
        let mults = hls.total_multipliers();
        ctx.log_metric("multipliers", mults as f64);
        ctx.log_metric("reuse_factor", hls.max_reuse_factor() as f64);
        ctx.log_message(format!(
            "translated {} to HLS: {} layers, {} multipliers, {} @ {} ns, RF {}",
            variant.tag,
            hls.layers.len(),
            mults,
            io_type,
            clock_ns,
            hls.max_reuse_factor()
        ));

        let files = codegen::emit(&hls);
        let id = ctx.meta.space.store(
            format!("{}_hls", variant.tag),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Hls(hls),
        );
        for (name, content) in files {
            ctx.meta.space.add_supporting(id, name, content)?;
        }
        // carry the DNN metrics forward for reporting
        for key in ["accuracy", "pruning_rate", "scale", "bits_total"] {
            if let Some(v) = input.metric(key) {
                ctx.meta.space.set_metric(id, key, v)?;
            }
        }
        ctx.meta.space.set_metric(id, "multipliers", mults as f64)?;
        Ok(TaskOutcome::produced([id]))
    }
}
