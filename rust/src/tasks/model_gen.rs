//! KERAS-MODEL-GEN λ-task: produce (and optionally train) the initial DNN.
//!
//! Table I: multiplicity 0-to-1; parameters train_en, train_test_dataset,
//! train_epochs.  Our training runs through the AOT train executable, and
//! the dataset is the model family's synthetic substitute (DESIGN.md §1).

use crate::error::Result;
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::metamodel::ModelPayload;
use crate::model::ModelState;
use crate::train::{TrainConfig, Trainer};

pub struct ModelGenTask;

impl PipeTask for ModelGenTask {
    fn name(&self) -> &str {
        "KERAS-MODEL-GEN"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Lambda
    }

    fn multiplicity(&self) -> (usize, usize) {
        (0, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "model", description: "model family to generate", default: Some("jet_dnn") },
            ParamSpec { name: "scale", description: "initial layer-size scale", default: Some("1.0") },
            ParamSpec { name: "train_en", description: "train after generation", default: Some("true") },
            ParamSpec { name: "train_test_dataset", description: "dataset name (synthetic substitute)", default: Some("per-model") },
            ParamSpec { name: "train_epochs", description: "training epochs", default: Some("per-model") },
            ParamSpec { name: "seed", description: "init + shuffle seed", default: Some("7") },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let model = ctx.cfg_str("model", "jet_dnn");
        let scale = ctx.cfg_f64("scale", 1.0);
        let train_en = ctx.cfg_bool("train_en", true);
        let seed = ctx.cfg_usize("seed", 7) as u64;

        let variant = ctx.session.manifest.variant(&model, scale)?.clone();
        let mut cfg = TrainConfig::for_model(&model);
        cfg.epochs = ctx.cfg_usize("train_epochs", cfg.epochs);
        cfg.seed = seed;

        let mut state = ModelState::init(&variant, seed);
        let exec = ctx.session.executable(&variant.tag)?;
        let data = ctx.session.dataset(&model)?;
        let trainer = Trainer::new(&ctx.session.runtime, &exec, &data);

        if train_en {
            ctx.log_message(format!(
                "training {} for {} epochs on {}",
                variant.tag, cfg.epochs, data.spec.name
            ));
            trainer.fit(&mut state, &cfg)?;
        }
        let eval = trainer.evaluate(&state)?;
        ctx.log_metric("accuracy", eval.accuracy);
        ctx.log_metric("loss", eval.loss);

        let id = ctx.meta.space.store(
            format!("{}_base", variant.tag),
            ctx.instance.clone(),
            None,
            ModelPayload::Dnn(state),
        );
        ctx.meta.space.set_metric(id, "accuracy", eval.accuracy)?;
        ctx.meta.space.set_metric(id, "loss", eval.loss)?;
        ctx.meta.space.set_metric(id, "scale", scale)?;
        ctx.meta
            .space
            .set_metric(id, "params", variant.total_weights() as f64)?;
        ctx.meta.log.push(crate::metamodel::LogEvent::ModelStored {
            task: ctx.instance.clone(),
            model_id: id,
            abstraction: "DNN".into(),
        });
        Ok(TaskOutcome::produced([id]))
    }
}
