//! REUSE_SEARCH O-task: FPGA-stage per-layer reuse-factor search.
//!
//! The first hardware-stage optimization task: where QUANTIZATION /
//! PRUNING / SCALING search the DNN stage by probing the trainer, this
//! task searches the FPGA stage by probing the synthesis estimator —
//! raising per-layer reuse factors (hls4ml time-multiplexing) to
//! minimize DSP/LUT under a latency budget, or to make an
//! over-provisioned design fit its device at maximum throughput.
//! Probes go through the same [`crate::dse::ProbePool`] as the DNN
//! searches, memoized by HLS-config fingerprint.

use crate::error::{Error, Result};
use crate::flow::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
use crate::hls::codegen;
use crate::metamodel::{Abstraction, ModelPayload};
use crate::synth::{reuse_search, FpgaDevice, ReuseConfig};

pub struct ReuseSearchTask;

impl PipeTask for ReuseSearchTask {
    fn name(&self) -> &str {
        "REUSE_SEARCH"
    }

    fn role(&self) -> TaskRole {
        TaskRole::Optimization
    }

    fn multiplicity(&self) -> (usize, usize) {
        (1, 1)
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec {
                name: "latency_budget_ns",
                description: "latency ceiling; unset = fit the device at max throughput",
                default: Some("none"),
            },
            ParamSpec {
                name: "jobs",
                description: "DSE probe workers (default METAML_JOBS/auto)",
                default: Some("auto"),
            },
        ]
    }

    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome> {
        let input = ctx
            .meta
            .space
            .latest(Abstraction::HlsCpp)
            .cloned()
            .ok_or_else(|| Error::other("no HLS model in the model space"))?;
        let hls = input.hls()?.clone();

        let (device, clock_mhz) = FpgaDevice::target_of(&hls)?;
        let cfg = ReuseConfig {
            latency_budget_ns: ctx.meta.cfg.get_f64(&ctx.instance, "latency_budget_ns"),
        };

        let pool = ctx.probes();
        let (model, trace) = reuse_search(&hls, device, clock_mhz, &cfg, pool.as_ref())?;
        for p in &trace.probes {
            ctx.log_metric("probe_layer", p.layer as f64);
            ctx.log_metric("probe_rf", p.rf as f64);
            ctx.log_metric("probe_dsp", p.dsp as f64);
            ctx.log_metric("probe_lut", p.lut as f64);
            ctx.log_metric("probe_latency_ns", p.latency_ns);
            ctx.log_metric("probe_accepted", if p.accepted { 1.0 } else { 0.0 });
        }
        // hit counts depend on tier sharing/timing: side note, never
        // the replay-comparable event stream
        let counts = pool.counts();
        ctx.log_note(
            "hw_probes_cached",
            counts.hw_issued.saturating_sub(counts.hw_computed) as f64,
        );
        let e = &trace.final_eval;
        ctx.log_metric("dsp", e.dsp as f64);
        ctx.log_metric("lut", e.lut as f64);
        ctx.log_metric("bram", e.bram_18k as f64);
        ctx.log_metric("latency_ns", e.latency_ns);
        ctx.log_metric("ii", e.ii as f64);
        ctx.log_metric("fits", if e.fits { 1.0 } else { 0.0 });
        ctx.log_message(format!(
            "reuse search ({}): RF {:?}, {} -> {} DSP, {} -> {} LUT, {:.0} -> {:.0} ns ({} probes)",
            match cfg.latency_budget_ns {
                Some(b) => format!("budget {b:.0} ns"),
                None => "fit".to_string(),
            },
            trace.reuse,
            trace.base.dsp,
            e.dsp,
            trace.base.lut,
            e.lut,
            trace.base.latency_ns,
            e.latency_ns,
            trace.probes.len(),
        ));

        let files = codegen::emit(&model);
        let id = ctx.meta.space.store(
            format!("{}_reused", hls.name),
            ctx.instance.clone(),
            Some(input.id),
            ModelPayload::Hls(model),
        );
        for (name, content) in files {
            ctx.meta.space.add_supporting(id, name, content)?;
        }
        ctx.meta.space.set_metric(id, "dsp", e.dsp as f64)?;
        ctx.meta.space.set_metric(id, "lut", e.lut as f64)?;
        ctx.meta.space.set_metric(id, "latency_ns", e.latency_ns)?;
        ctx.meta.space.set_metric(id, "ii", e.ii as f64)?;
        ctx.meta
            .space
            .set_metric(id, "fits", if e.fits { 1.0 } else { 0.0 })?;
        // carry model-quality metrics forward for the final RTL row
        for key in ["accuracy", "pruning_rate", "scale", "bits_total"] {
            if let Some(v) = input.metric(key) {
                ctx.meta.space.set_metric(id, key, v)?;
            }
        }
        Ok(TaskOutcome::produced([id]))
    }
}
