//! The pure-Rust reference-interpreter backend.
//!
//! Executes the train/eval step semantics directly from a manifest
//! variant's layer descriptions, with no native dependencies — the
//! default [`crate::runtime::ExecBackend`].  It mirrors, op for op, the
//! Python reference stack (`python/compile/kernels/*.py`,
//! `layers.py`, `train.py`):
//!
//! * `fq`: ap_fixed<W,I> round-to-nearest-even + saturate, identity when
//!   W == 0 (`fake_quant_ref`);
//! * forward: `act(fq(x,q) @ (fq(w,q) * mask) + b)` per weight layer,
//!   conv as channel-major im2col, 2x2 VALID max-pool, residual
//!   `relu(x + skip)`;
//! * backward (the `qmm` custom-VJP STE semantics):
//!   `dx = (g @ (fq(w)*m)^T) * ste(x)`,
//!   `dw = (fq(x)^T @ g) * m * ste(w)` — pruned weights stay dead,
//!   saturated weights get no gradient;
//! * loss: stable log-softmax cross-entropy mean + argmax accuracy;
//! * update: plain SGD `p' = p - lr * g`.
//!
//! Parity with the JAX stack is pinned by `rust/tests/backend_parity.rs`
//! against goldens generated from the actual Pallas-interpret kernels.

use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{ExecBackend, ModelExec, RuntimeStats, StatsCell};
use crate::runtime::manifest::{LayerDesc, Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Round half to even (`jnp.round` semantics; `f32::round` rounds half
/// away from zero, which would diverge from the reference kernels).
fn round_ties_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - x.signum()
    } else {
        r
    }
}

/// ap_fixed<W,I> fake quantization: round to nearest (ties to even) at
/// `2^(W-I)` resolution, saturate to the representable range.  `W <= 0`
/// disables quantization (identity).
pub fn fake_quant(v: f32, total_bits: f32, int_bits: f32) -> f32 {
    if total_bits <= 0.0 {
        return v;
    }
    let scale = (total_bits - int_bits).exp2();
    let hi = (int_bits - 1.0).exp2() - 1.0 / scale;
    let lo = -(int_bits - 1.0).exp2();
    (round_ties_even(v * scale) / scale).clamp(lo, hi)
}

/// Straight-through gradient mask: 1 inside the representable range (or
/// when quantization is disabled), 0 where the forward pass saturated.
fn ste(v: f32, total_bits: f32, int_bits: f32) -> f32 {
    if total_bits <= 0.0 {
        return 1.0;
    }
    let hi = (int_bits - 1.0).exp2();
    if v.abs() <= hi {
        1.0
    } else {
        0.0
    }
}

/// `a[m,k] @ b[k,n]` (row-major, f32 accumulation).
///
/// No zero-skipping: `0 * NaN = NaN` must propagate exactly as in the
/// XLA matmul, so a diverged model reports NaN loss instead of a
/// plausible finite value.
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for t in 0..k {
            let av = a[i * k + t];
            let brow = &b[t * n..(t + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `a[m,n] @ b[k,n]^T` → `[m,k]`.
fn mm_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            out[i * k + j] = acc;
        }
    }
    out
}

/// `a[m,k]^T @ b[m,n]` → `[k,n]` (same NaN-propagation contract as [`mm`]).
fn mm_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for t in 0..m {
        let arow = &a[t * k..(t + 1) * k];
        let brow = &b[t * n..(t + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `fq(w) * mask`, elementwise.
fn quantized_masked(w: &[f32], mask: &[f32], wb: f32, ib: f32) -> Vec<f32> {
    w.iter()
        .zip(mask)
        .map(|(&wv, &mv)| fake_quant(wv, wb, ib) * mv)
        .collect()
}

/// Channel-major im2col: `[B,H,W,C]` → `[B*H*W, C*k*k]`, SAME padding,
/// stride 1, feature index `c*k*k + kh*k + kw` (matching
/// `conv_general_dilated_patches` + the HWIO→(C,k,k,Cout) weight
/// transpose in `layers.qconv2d`).
fn im2col(x: &[f32], shape: [usize; 4], k: usize) -> Vec<f32> {
    let [b, h, w, c] = shape;
    let pad = (k - 1) / 2;
    let fk = c * k * k;
    let mut cols = vec![0.0f32; b * h * w * fk];
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let row = ((bi * h + i) * w + j) * fk;
                for kh in 0..k {
                    let y = i + kh;
                    if y < pad || y - pad >= h {
                        continue;
                    }
                    let y = y - pad;
                    for kw in 0..k {
                        let xx = j + kw;
                        if xx < pad || xx - pad >= w {
                            continue;
                        }
                        let xx = xx - pad;
                        let src = ((bi * h + y) * w + xx) * c;
                        for ci in 0..c {
                            cols[row + ci * k * k + kh * k + kw] = x[src + ci];
                        }
                    }
                }
            }
        }
    }
    cols
}

/// Scatter-add transpose of [`im2col`]: `[B*H*W, C*k*k]` → `[B,H,W,C]`.
fn col2im(dcols: &[f32], shape: [usize; 4], k: usize) -> Vec<f32> {
    let [b, h, w, c] = shape;
    let pad = (k - 1) / 2;
    let fk = c * k * k;
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let row = ((bi * h + i) * w + j) * fk;
                for kh in 0..k {
                    let y = i + kh;
                    if y < pad || y - pad >= h {
                        continue;
                    }
                    let y = y - pad;
                    for kw in 0..k {
                        let xx = j + kw;
                        if xx < pad || xx - pad >= w {
                            continue;
                        }
                        let xx = xx - pad;
                        let dst = ((bi * h + y) * w + xx) * c;
                        for ci in 0..c {
                            dx[dst + ci] += dcols[row + ci * k * k + kh * k + kw];
                        }
                    }
                }
            }
        }
    }
    dx
}

/// HWIO `[k,k,Cin,Cout]` → matmul operand `[Cin*k*k, Cout]`.
fn hwio_to_2d(w4: &[f32], k: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut w2 = vec![0.0f32; cin * k * k * cout];
    for kh in 0..k {
        for kw in 0..k {
            for c in 0..cin {
                let src = (((kh * k) + kw) * cin + c) * cout;
                let dst = (c * k * k + kh * k + kw) * cout;
                w2[dst..dst + cout].copy_from_slice(&w4[src..src + cout]);
            }
        }
    }
    w2
}

/// Inverse of [`hwio_to_2d`].
fn hwio_from_2d(w2: &[f32], k: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut w4 = vec![0.0f32; k * k * cin * cout];
    for kh in 0..k {
        for kw in 0..k {
            for c in 0..cin {
                let dst = (((kh * k) + kw) * cin + c) * cout;
                let src = (c * k * k + kh * k + kw) * cout;
                w4[dst..dst + cout].copy_from_slice(&w2[src..src + cout]);
            }
        }
    }
    w4
}

/// Current activation value flowing through the layer stack.
struct Act {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Per-layer state saved by the forward pass for the backward pass.
enum Tape {
    /// `x`: pre-quantization layer input; `out`: post-activation output.
    Dense { x: Vec<f32>, out: Vec<f32>, li: usize },
    /// `cols`: pre-quantization im2col patches; `in_shape`: input NHWC.
    Conv { cols: Vec<f32>, in_shape: [usize; 4], out: Vec<f32>, li: usize },
    /// `arg`: per-output-cell index of the (first) max in its 2x2 window.
    Pool { in_shape: [usize; 4], arg: Vec<u8> },
    Flatten,
    /// `skip`: the activation captured at the block entry.
    ResBegin { skip: Vec<f32> },
    /// `begin`: tape index of the matching [`Tape::ResBegin`].
    ResAdd { begin: usize, out: Vec<f32> },
}

struct Forward {
    logits: Act,
    tape: Vec<Tape>,
}

/// Parsed flat argument list (the `python/compile/train.py` convention).
struct StepArgs<'a> {
    params: Vec<&'a [f32]>,
    masks: Vec<&'a [f32]>,
    /// Flattened `[L, 2]` rows of `[total_bits, int_bits]`.
    qcfg: &'a [f32],
    x: &'a HostTensor,
    y: &'a [i32],
    lr: Option<f32>,
}

/// A manifest variant bound to the reference interpreter.
///
/// Holds only the immutable variant description plus the shared atomic
/// stats cell, so one model is freely stepped from concurrent DSE probe
/// workers (`ModelExec` requires `Send + Sync`).
pub struct RefModel {
    variant: ModelVariant,
    stats: Arc<StatsCell>,
}

impl RefModel {
    fn layer_q(&self, qcfg: &[f32], l: &LayerDesc) -> Result<(f32, f32)> {
        let row = l.mask_idx as usize;
        if l.mask_idx < 0 || (row + 1) * 2 > qcfg.len() {
            return Err(Error::backend(format!(
                "layer {} has qcfg row {} but qcfg holds {} rows",
                l.name,
                l.mask_idx,
                qcfg.len() / 2
            )));
        }
        Ok((qcfg[2 * row], qcfg[2 * row + 1]))
    }

    fn split_args<'a>(&self, args: &'a [HostTensor], with_lr: bool) -> Result<StepArgs<'a>> {
        let n_p = self.variant.n_params();
        let n_m = self.variant.n_masks();
        let expect = n_p + n_m + 3 + usize::from(with_lr);
        if args.len() != expect {
            return Err(Error::backend(format!(
                "expected {expect} args, got {}",
                args.len()
            )));
        }
        let mut params = Vec::with_capacity(n_p);
        for (i, (name, shape)) in self.variant.param_shapes.iter().enumerate() {
            let p = args[i].as_f32()?;
            let want: usize = shape.iter().product();
            if p.len() != want {
                return Err(Error::backend(format!(
                    "param {name}: expected {want} elements, got {}",
                    p.len()
                )));
            }
            params.push(p);
        }
        let mut masks = Vec::with_capacity(n_m);
        for (i, (pidx, shape)) in self.variant.mask_shapes.iter().enumerate() {
            let m = args[n_p + i].as_f32()?;
            let want: usize = shape.iter().product();
            if m.len() != want {
                return Err(Error::backend(format!(
                    "mask {i} (param {pidx}): expected {want} elements, got {}",
                    m.len()
                )));
            }
            masks.push(m);
        }
        let qcfg = args[n_p + n_m].as_f32()?;
        if qcfg.len() != 2 * self.variant.qcfg_rows {
            return Err(Error::backend(format!(
                "qcfg: expected {} rows, got {} elements",
                self.variant.qcfg_rows,
                qcfg.len()
            )));
        }
        let x = &args[n_p + n_m + 1];
        let y = args[n_p + n_m + 2].as_i32()?;
        let batch = *x.shape().first().unwrap_or(&0);
        if y.len() != batch {
            return Err(Error::backend(format!(
                "labels: expected {batch} entries, got {}",
                y.len()
            )));
        }
        let lr = if with_lr { Some(args[n_p + n_m + 3].scalar_f32()?) } else { None };
        Ok(StepArgs { params, masks, qcfg, x, y, lr })
    }

    /// Forward pass.  With `record` set, saves per-layer state for
    /// [`Self::backward`]; without it (the eval path) only the
    /// [`Tape::ResBegin`] skip values needed by the forward computation
    /// itself are kept, so evaluation never clones activations.
    fn forward(&self, a: &StepArgs, record: bool) -> Result<Forward> {
        let mut act = Act { shape: a.x.shape().to_vec(), data: a.x.as_f32()?.to_vec() };
        let mut tape: Vec<Tape> = Vec::with_capacity(self.variant.layers.len());
        let mut res_stack: Vec<usize> = Vec::new();

        for (li, l) in self.variant.layers.iter().enumerate() {
            match l.kind.as_str() {
                "dense" => {
                    if act.shape.len() != 2 || act.shape[1] != l.in_dim {
                        return Err(Error::backend(format!(
                            "dense {}: input shape {:?}, want [B, {}]",
                            l.name, act.shape, l.in_dim
                        )));
                    }
                    let (wb, ib) = self.layer_q(a.qcfg, l)?;
                    let b = act.shape[0];
                    let w = a.params[l.param_w as usize];
                    let bias = a.params[l.param_b as usize];
                    let mask = a.masks[l.mask_idx as usize];
                    let wq = quantized_masked(w, mask, wb, ib);
                    let xq: Vec<f32> =
                        act.data.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut z = mm(&xq, &wq, b, l.in_dim, l.out_dim);
                    apply_bias_activation(&mut z, bias, l.out_dim, &l.activation)?;
                    if record {
                        tape.push(Tape::Dense {
                            x: std::mem::take(&mut act.data),
                            out: z.clone(),
                            li,
                        });
                    }
                    act = Act { shape: vec![b, l.out_dim], data: z };
                }
                "conv2d" => {
                    if act.shape.len() != 4 || act.shape[3] != l.in_dim {
                        return Err(Error::backend(format!(
                            "conv2d {}: input shape {:?}, want [B,H,W,{}]",
                            l.name, act.shape, l.in_dim
                        )));
                    }
                    let (wb, ib) = self.layer_q(a.qcfg, l)?;
                    let in_shape =
                        [act.shape[0], act.shape[1], act.shape[2], act.shape[3]];
                    let [b, h, w, cin] = in_shape;
                    let k = l.kernel;
                    let cout = l.out_dim;
                    let cols = im2col(&act.data, in_shape, k);
                    let w2 =
                        hwio_to_2d(a.params[l.param_w as usize], k, cin, cout);
                    let m2 = hwio_to_2d(a.masks[l.mask_idx as usize], k, cin, cout);
                    let wq2 = quantized_masked(&w2, &m2, wb, ib);
                    let colsq: Vec<f32> =
                        cols.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let rows = b * h * w;
                    let mut z = mm(&colsq, &wq2, rows, cin * k * k, cout);
                    apply_bias_activation(
                        &mut z,
                        a.params[l.param_b as usize],
                        cout,
                        &l.activation,
                    )?;
                    if record {
                        tape.push(Tape::Conv { cols, in_shape, out: z.clone(), li });
                    }
                    act = Act { shape: vec![b, h, w, cout], data: z };
                }
                "maxpool2" => {
                    if act.shape.len() != 4 {
                        return Err(Error::backend(format!(
                            "maxpool2: input shape {:?}, want NHWC",
                            act.shape
                        )));
                    }
                    let in_shape =
                        [act.shape[0], act.shape[1], act.shape[2], act.shape[3]];
                    let [b, h, w, c] = in_shape;
                    let (oh, ow) = (h / 2, w / 2);
                    let mut out = vec![0.0f32; b * oh * ow * c];
                    let mut arg = if record { vec![0u8; b * oh * ow * c] } else { Vec::new() };
                    for bi in 0..b {
                        for i in 0..oh {
                            for j in 0..ow {
                                for ci in 0..c {
                                    let mut best = f32::NEG_INFINITY;
                                    let mut bidx = 0u8;
                                    for di in 0..2 {
                                        for dj in 0..2 {
                                            let v = act.data[((bi * h + 2 * i + di)
                                                * w
                                                + 2 * j
                                                + dj)
                                                * c
                                                + ci];
                                            if v.is_nan() {
                                                // NaN must win the window
                                                // (lax.max propagates NaN)
                                                best = f32::NAN;
                                            } else if v > best {
                                                best = v;
                                                bidx = (di * 2 + dj) as u8;
                                            }
                                        }
                                    }
                                    let o = ((bi * oh + i) * ow + j) * c + ci;
                                    out[o] = best;
                                    if record {
                                        arg[o] = bidx;
                                    }
                                }
                            }
                        }
                    }
                    if record {
                        tape.push(Tape::Pool { in_shape, arg });
                    }
                    act = Act { shape: vec![b, oh, ow, c], data: out };
                }
                "flatten" => {
                    let b = act.shape[0];
                    let rest: usize = act.shape[1..].iter().product();
                    if record {
                        tape.push(Tape::Flatten);
                    }
                    act.shape = vec![b, rest];
                }
                "residual_begin" => {
                    res_stack.push(tape.len());
                    tape.push(Tape::ResBegin { skip: act.data.clone() });
                }
                "residual_add" => {
                    let begin = res_stack.pop().ok_or_else(|| {
                        Error::backend("residual_add without residual_begin")
                    })?;
                    let skip = match &tape[begin] {
                        Tape::ResBegin { skip } => skip,
                        _ => unreachable!("res_stack points at ResBegin entries"),
                    };
                    if skip.len() != act.data.len() {
                        return Err(Error::backend(
                            "residual_add: branch/skip shape mismatch",
                        ));
                    }
                    // NaN-propagating relu(v + s), as in jax.nn.relu
                    let z: Vec<f32> = act
                        .data
                        .iter()
                        .zip(skip)
                        .map(|(&v, &s)| {
                            let sum = v + s;
                            if sum < 0.0 {
                                0.0
                            } else {
                                sum
                            }
                        })
                        .collect();
                    if record {
                        tape.push(Tape::ResAdd { begin, out: z.clone() });
                    }
                    act.data = z;
                }
                other => {
                    return Err(Error::backend(format!(
                        "reference interpreter: unknown layer kind {other:?}"
                    )))
                }
            }
        }
        Ok(Forward { logits: act, tape })
    }

    /// Stable softmax cross-entropy + accuracy; returns `d loss / d logits`.
    fn loss_acc(&self, logits: &Act, y: &[i32]) -> Result<(f32, f32, Vec<f32>)> {
        let n_classes = self.variant.n_classes;
        if logits.shape.len() != 2 || logits.shape[1] != n_classes {
            return Err(Error::backend(format!(
                "logits shape {:?}, want [B, {n_classes}]",
                logits.shape
            )));
        }
        let b = logits.shape[0];
        let mut loss = 0.0f32;
        let mut correct = 0usize;
        let mut dlogits = vec![0.0f32; b * n_classes];
        for i in 0..b {
            let row = &logits.data[i * n_classes..(i + 1) * n_classes];
            let label = y[i];
            if label < 0 || label as usize >= n_classes {
                return Err(Error::backend(format!(
                    "label {label} out of range [0, {n_classes})"
                )));
            }
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            let lse = sum.ln();
            loss -= row[label as usize] - mx - lse;
            // argmax with first-max tie-break and NaN treated as maximal
            // (jnp.argmax semantics)
            let mut am = 0usize;
            for (c, &v) in row.iter().enumerate().skip(1) {
                let cur = row[am];
                let better = if v.is_nan() { !cur.is_nan() } else { v > cur };
                if better {
                    am = c;
                }
            }
            if am == label as usize {
                correct += 1;
            }
            for c in 0..n_classes {
                let soft = (row[c] - mx - lse).exp();
                let onehot = if c == label as usize { 1.0 } else { 0.0 };
                dlogits[i * n_classes + c] = (soft - onehot) / b as f32;
            }
        }
        Ok((loss / b as f32, correct as f32 / b as f32, dlogits))
    }

    /// Reverse pass over the tape; returns per-param gradients in flat
    /// param order.
    fn backward(&self, a: &StepArgs, fwd: &Forward, dlogits: Vec<f32>) -> Result<Vec<Vec<f32>>> {
        let mut grads: Vec<Vec<f32>> =
            a.params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let mut g = dlogits;
        // gradient contributions waiting at a ResBegin tape index
        let mut pending: Vec<Option<Vec<f32>>> = (0..fwd.tape.len()).map(|_| None).collect();

        for (t, entry) in fwd.tape.iter().enumerate().rev() {
            match entry {
                Tape::Dense { x, out, li } => {
                    let l = &self.variant.layers[*li];
                    let (wb, ib) = self.layer_q(a.qcfg, l)?;
                    if l.activation == "relu" {
                        relu_mask(&mut g, out);
                    }
                    let b = x.len() / l.in_dim;
                    let w = a.params[l.param_w as usize];
                    let mask = a.masks[l.mask_idx as usize];
                    grads[l.param_b as usize] = bias_grad(&g, b, l.out_dim);
                    let wq = quantized_masked(w, mask, wb, ib);
                    let mut dx = mm_bt(&g, &wq, b, l.out_dim, l.in_dim);
                    for (d, &xv) in dx.iter_mut().zip(x) {
                        *d *= ste(xv, wb, ib);
                    }
                    let xq: Vec<f32> =
                        x.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut dw = mm_at(&xq, &g, b, l.in_dim, l.out_dim);
                    for ((d, &mv), &wv) in dw.iter_mut().zip(mask).zip(w) {
                        *d *= mv * ste(wv, wb, ib);
                    }
                    grads[l.param_w as usize] = dw;
                    g = dx;
                }
                Tape::Conv { cols, in_shape, out, li } => {
                    let l = &self.variant.layers[*li];
                    let (wb, ib) = self.layer_q(a.qcfg, l)?;
                    if l.activation == "relu" {
                        relu_mask(&mut g, out);
                    }
                    let [_, _, _, cin] = *in_shape;
                    let (k, cout) = (l.kernel, l.out_dim);
                    let fk = cin * k * k;
                    let rows = cols.len() / fk;
                    grads[l.param_b as usize] = bias_grad(&g, rows, cout);
                    let w2 =
                        hwio_to_2d(a.params[l.param_w as usize], k, cin, cout);
                    let m2 = hwio_to_2d(a.masks[l.mask_idx as usize], k, cin, cout);
                    let wq2 = quantized_masked(&w2, &m2, wb, ib);
                    let mut dcols = mm_bt(&g, &wq2, rows, cout, fk);
                    for (d, &cv) in dcols.iter_mut().zip(cols) {
                        *d *= ste(cv, wb, ib);
                    }
                    let colsq: Vec<f32> =
                        cols.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut dw2 = mm_at(&colsq, &g, rows, fk, cout);
                    for ((d, &mv), &wv) in dw2.iter_mut().zip(&m2).zip(&w2) {
                        *d *= mv * ste(wv, wb, ib);
                    }
                    grads[l.param_w as usize] = hwio_from_2d(&dw2, k, cin, cout);
                    g = col2im(&dcols, *in_shape, k);
                }
                Tape::Pool { in_shape, arg } => {
                    let [b, h, w, c] = *in_shape;
                    let (oh, ow) = (h / 2, w / 2);
                    let mut dx = vec![0.0f32; b * h * w * c];
                    for bi in 0..b {
                        for i in 0..oh {
                            for j in 0..ow {
                                for ci in 0..c {
                                    let o = ((bi * oh + i) * ow + j) * c + ci;
                                    let (di, dj) =
                                        ((arg[o] / 2) as usize, (arg[o] % 2) as usize);
                                    dx[((bi * h + 2 * i + di) * w + 2 * j + dj) * c
                                        + ci] += g[o];
                                }
                            }
                        }
                    }
                    g = dx;
                }
                Tape::Flatten => {
                    // pure reshape: the gradient buffer is already flat
                }
                Tape::ResAdd { begin, out } => {
                    relu_mask(&mut g, out);
                    if let Some(acc) = pending[*begin].as_mut() {
                        for (dst, &src) in acc.iter_mut().zip(&g) {
                            *dst += src;
                        }
                    } else {
                        pending[*begin] = Some(g.clone());
                    }
                }
                Tape::ResBegin { .. } => {
                    if let Some(skip_g) = pending[t].take() {
                        for (dst, &src) in g.iter_mut().zip(&skip_g) {
                            *dst += src;
                        }
                    }
                }
            }
        }
        Ok(grads)
    }
}

/// `z += bias` (broadcast over rows) then apply the layer activation.
fn apply_bias_activation(z: &mut [f32], bias: &[f32], width: usize, activation: &str) -> Result<()> {
    for row in z.chunks_mut(width) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
    match activation {
        "relu" => {
            // `if v < 0` rather than f32::max: Rust's max(NaN, 0.0)
            // returns 0.0, but jnp.maximum propagates NaN
            for v in z.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            Ok(())
        }
        "linear" => Ok(()),
        other => Err(Error::backend(format!("unknown activation {other:?}"))),
    }
}

/// `g *= (out > 0)` — the relu VJP against the saved post-activation.
fn relu_mask(g: &mut [f32], out: &[f32]) {
    for (gv, &ov) in g.iter_mut().zip(out) {
        if ov <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Column sums of `g[rows, width]` (the bias gradient).
fn bias_grad(g: &[f32], rows: usize, width: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; width];
    for i in 0..rows {
        for (d, &gv) in db.iter_mut().zip(&g[i * width..(i + 1) * width]) {
            *d += gv;
        }
    }
    db
}

impl ModelExec for RefModel {
    fn variant(&self) -> &ModelVariant {
        &self.variant
    }

    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let t0 = Instant::now();
        let a = self.split_args(args, true)?;
        let lr = a.lr.expect("split_args(with_lr)");
        let fwd = self.forward(&a, true)?;
        let (loss, acc, dlogits) = self.loss_acc(&fwd.logits, a.y)?;
        let grads = self.backward(&a, &fwd, dlogits)?;
        let mut new_params = Vec::with_capacity(a.params.len());
        for (i, (p, gr)) in a.params.iter().zip(&grads).enumerate() {
            let data: Vec<f32> =
                p.iter().zip(gr).map(|(&pv, &gv)| pv - lr * gv).collect();
            let shape = &self.variant.param_shapes[i].1;
            new_params.push(HostTensor::F32 { shape: shape.clone(), data });
        }
        self.stats.add_execute(t0.elapsed());
        Ok((new_params, loss, acc))
    }

    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        let a = self.split_args(args, false)?;
        let fwd = self.forward(&a, false)?;
        let (loss, acc, _) = self.loss_acc(&fwd.logits, a.y)?;
        self.stats.add_execute(t0.elapsed());
        Ok((loss, acc))
    }
}

/// Reject malformed manifests up front so the interpreter can index
/// params/masks/qcfg by layer descriptor — and slice weight buffers by
/// layer dims — without panicking.
fn validate_layer_indices(variant: &ModelVariant) -> Result<()> {
    let n_p = variant.n_params() as i64;
    let n_m = variant.n_masks() as i64;
    for l in &variant.layers {
        if !matches!(l.kind.as_str(), "dense" | "conv2d") {
            continue;
        }
        if l.param_w < 0 || l.param_w >= n_p || l.param_b < 0 || l.param_b >= n_p {
            return Err(Error::backend(format!(
                "layer {}: param indices ({}, {}) out of range [0, {n_p})",
                l.name, l.param_w, l.param_b
            )));
        }
        if l.mask_idx < 0 || l.mask_idx >= n_m || l.mask_idx as usize >= variant.qcfg_rows {
            return Err(Error::backend(format!(
                "layer {}: mask/qcfg row {} out of range ({} masks, {} qcfg rows)",
                l.name, l.mask_idx, n_m, variant.qcfg_rows
            )));
        }
        if l.kind == "conv2d" && l.kernel == 0 {
            return Err(Error::backend(format!(
                "conv2d layer {}: kernel size must be positive",
                l.name
            )));
        }
        // dims recorded on the layer must agree with the declared
        // param/mask shapes the interpreter slices by
        let w_shape = &variant.param_shapes[l.param_w as usize].1;
        let b_shape = &variant.param_shapes[l.param_b as usize].1;
        let m_shape = &variant.mask_shapes[l.mask_idx as usize].1;
        let want_w: Vec<usize> = if l.kind == "dense" {
            vec![l.in_dim, l.out_dim]
        } else {
            vec![l.kernel, l.kernel, l.in_dim, l.out_dim]
        };
        if w_shape.as_slice() != want_w.as_slice() {
            return Err(Error::backend(format!(
                "layer {}: weight shape {w_shape:?} does not match layer dims {want_w:?}",
                l.name
            )));
        }
        if b_shape.len() != 1 || b_shape[0] != l.out_dim {
            return Err(Error::backend(format!(
                "layer {}: bias shape {b_shape:?} does not match out_dim {}",
                l.name, l.out_dim
            )));
        }
        if m_shape != w_shape {
            return Err(Error::backend(format!(
                "layer {}: mask shape {m_shape:?} does not match weight shape {w_shape:?}",
                l.name
            )));
        }
    }
    Ok(())
}

/// The reference-interpreter backend: no artifacts, no native libraries.
pub struct RefBackend {
    stats: Arc<StatsCell>,
}

impl RefBackend {
    pub fn new() -> Self {
        RefBackend { stats: Arc::new(StatsCell::new()) }
    }
}

impl Default for RefBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for RefBackend {
    fn platform(&self) -> String {
        "reference-interpreter".to_string()
    }

    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Arc<dyn ModelExec>> {
        let t0 = Instant::now();
        let variant = manifest.get(tag)?.clone();
        if variant.layers.is_empty() {
            return Err(Error::backend(format!(
                "variant {tag:?} carries no layer descriptions; the reference \
                 interpreter executes from manifest layers"
            )));
        }
        validate_layer_indices(&variant)?;
        self.stats.add_compile(t0.elapsed());
        Ok(Arc::new(RefModel { variant, stats: self.stats.clone() }))
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_ties_even_matches_jnp_round() {
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.5), 4.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        assert_eq!(round_ties_even(-3.5), -4.0);
        assert_eq!(round_ties_even(2.4), 2.0);
        assert_eq!(round_ties_even(2.6), 3.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(0.0), 0.0);
    }

    #[test]
    fn fake_quant_disabled_is_identity() {
        for v in [-7.3f32, -0.1, 0.0, 0.49, 123.4] {
            assert_eq!(fake_quant(v, 0.0, 0.0), v);
        }
    }

    #[test]
    fn fake_quant_rounds_and_saturates() {
        // ap_fixed<6,3>: scale 8, range [-4, 3.875]
        assert_eq!(fake_quant(7.9, 6.0, 3.0), 3.875);
        assert_eq!(fake_quant(-9.0, 6.0, 3.0), -4.0);
        assert_eq!(fake_quant(0.13, 6.0, 3.0), 0.125);
        assert_eq!(fake_quant(1.0, 6.0, 3.0), 1.0);
    }

    #[test]
    fn ste_boundary() {
        // enabled <7,3>: representable magnitude bound 2^(3-1) = 4
        assert_eq!(ste(3.9, 7.0, 3.0), 1.0);
        assert_eq!(ste(4.0, 7.0, 3.0), 1.0);
        assert_eq!(ste(4.1, 7.0, 3.0), 0.0);
        assert_eq!(ste(-4.1, 7.0, 3.0), 0.0);
        assert_eq!(ste(100.0, 0.0, 0.0), 1.0);
    }

    #[test]
    fn matmul_variants_agree() {
        // a: 2x3, b: 3x2
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = mm(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
        // b^T is 2x3; mm_bt(a2x3 @ (bt)^T) must equal mm with b
        let bt = [7.0f32, 9.0, 11.0, 8.0, 10.0, 12.0];
        assert_eq!(mm_bt(&a, &bt, 2, 3, 2), c);
        // a^T path: (a^T)^T @ b
        let at = [1.0f32, 4.0, 2.0, 5.0, 3.0, 6.0];
        assert_eq!(mm_at(&at, &b, 3, 2, 2), c);
    }

    #[test]
    fn im2col_col2im_roundtrip_shapes() {
        // 1x2x2x1 input, k=3: each pixel sees its 3x3 SAME neighborhood
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let cols = im2col(&x, [1, 2, 2, 1], 3);
        assert_eq!(cols.len(), 4 * 9);
        // center of patch (kh=1, kw=1) is the pixel itself
        for (p, &v) in x.iter().enumerate() {
            assert_eq!(cols[p * 9 + 4], v);
        }
        // col2im of all-ones gradient counts each pixel's patch memberships
        let dx = col2im(&vec![1.0f32; 4 * 9], [1, 2, 2, 1], 3);
        assert_eq!(dx, vec![4.0; 4]);
    }

    #[test]
    fn hwio_transpose_roundtrip() {
        let (k, cin, cout) = (3, 2, 4);
        let w4: Vec<f32> = (0..k * k * cin * cout).map(|i| i as f32).collect();
        let w2 = hwio_to_2d(&w4, k, cin, cout);
        assert_eq!(hwio_from_2d(&w2, k, cin, cout), w4);
    }
}
