//! The pure-Rust reference-interpreter backend.
//!
//! Executes the train/eval step semantics directly from a manifest
//! variant's layer descriptions, with no native dependencies — the
//! default [`crate::runtime::ExecBackend`].  It mirrors, op for op, the
//! Python reference stack (`python/compile/kernels/*.py`,
//! `layers.py`, `train.py`):
//!
//! * `fq`: ap_fixed<W,I> round-to-nearest-even + saturate, identity when
//!   W == 0 (`fake_quant_ref`);
//! * forward: `act(fq(x,q) @ (fq(w,q) * mask) + b)` per weight layer,
//!   conv as channel-major im2col, 2x2 VALID max-pool, residual
//!   `relu(x + skip)`;
//! * backward (the `qmm` custom-VJP STE semantics):
//!   `dx = (g @ (fq(w)*m)^T) * ste(x)`,
//!   `dw = (fq(x)^T @ g) * m * ste(w)` — pruned weights stay dead,
//!   saturated weights get no gradient;
//! * loss: stable log-softmax cross-entropy mean + argmax accuracy;
//! * update: plain SGD `p' = p - lr * g`.
//!
//! The hot math lives in [`crate::runtime::kernels`] as blocked,
//! sparse-aware, row-panel-parallel kernels, all bit-identical to the
//! original naive triple loops (kept as [`kernels::naive`]).  The step
//! driver here adds the per-step hoisting around them:
//!
//! * [`LayerWeights`] — `fq(w) * mask` with hoisted quantization
//!   constants and the compressed sparse index list, built once per
//!   train step (and once per eval *run* via
//!   [`ModelExec::eval_batches`]) instead of re-derived per matmul;
//! * [`Workspace`] — a per-execution buffer pool checked out of the
//!   model, so steps stop allocating `Vec`s; the input batch is
//!   borrowed, never copied;
//! * [`KernelMode`] — `Fast` (default), `DenseOnly` (sparse path off,
//!   for measuring sparse speedup) or `Naive` (the original per-call
//!   requantizing, per-call-allocating implementation — the test
//!   oracle and the "before" baseline of `benches/perf_runtime.rs`).
//!   Selected by `METAML_INTERP=fast|dense|naive` at backend
//!   construction, or explicitly via [`RefBackend::with_mode`].
//!
//! Every mode produces bit-identical results (pinned by
//! `rust/tests/kernel_parity.rs`), so parity with the JAX stack —
//! pinned by `rust/tests/backend_parity.rs` against goldens generated
//! from the actual Pallas-interpret kernels — and the DSE determinism
//! traces are unchanged by the kernel layer.

use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{ExecBackend, ModelExec, RuntimeStats, StatsCell};
use crate::runtime::kernels::{
    self, matmul_at, matmul_bt_masked, matmul_masked, naive, MaskedWeight, Quant, Workspace,
    SPARSE_DENSITY_THRESHOLD,
};
use crate::runtime::manifest::{LayerDesc, Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

pub use crate::runtime::kernels::fake_quant;

/// Straight-through gradient mask: 1 inside the representable range (or
/// when quantization is disabled), 0 where the forward pass saturated.
/// (Per-element constant recomputation — the naive path; the fast path
/// hoists the bound into [`Quant`].)
fn ste(v: f32, total_bits: f32, int_bits: f32) -> f32 {
    if total_bits <= 0.0 {
        return 1.0;
    }
    let hi = (int_bits - 1.0).exp2();
    if v.abs() <= hi {
        1.0
    } else {
        0.0
    }
}

/// Which kernel implementation a [`RefBackend`] drives.
///
/// All three are bit-identical in output; they differ only in cost.
/// `Fast` is the default; `DenseOnly` and `Naive` exist so the bench
/// can measure the sparse and blocked/workspace wins in-process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Blocked matmuls, hoisted quantization, workspace reuse, sparse
    /// skip below [`SPARSE_DENSITY_THRESHOLD`], intra-probe parallelism.
    Fast,
    /// `Fast` with the compressed sparse path disabled (every masked
    /// matmul runs dense-blocked).
    DenseOnly,
    /// The original implementation: naive triple-loop matmuls,
    /// per-call `fq(w) * mask` requantization, per-call allocations.
    Naive,
}

impl KernelMode {
    /// Parse `METAML_INTERP` (`fast` default; `dense` / `naive`).
    pub fn from_env() -> KernelMode {
        match std::env::var("METAML_INTERP")
            .unwrap_or_default()
            .to_ascii_lowercase()
            .as_str()
        {
            "naive" => KernelMode::Naive,
            "dense" | "dense-only" | "dense_only" => KernelMode::DenseOnly,
            _ => KernelMode::Fast,
        }
    }
}

// ---------------------------------------------------------------------------
// argument parsing
// ---------------------------------------------------------------------------

/// The model operand prefix shared by every step of a run:
/// `params ++ masks ++ [qcfg]`, borrowed from the caller's tensors.
struct BaseArgs<'a> {
    params: Vec<&'a [f32]>,
    masks: Vec<&'a [f32]>,
    /// Flattened `[L, 2]` rows of `[total_bits, int_bits]`.
    qcfg: &'a [f32],
}

// ---------------------------------------------------------------------------
// fast-path activation plumbing
// ---------------------------------------------------------------------------

/// Activation shape without `Vec` churn (rank is always 2 or 4 here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ActShape {
    dims: [usize; 4],
    rank: usize,
}

impl ActShape {
    fn from_slice(s: &[usize]) -> Result<ActShape> {
        if s.len() > 4 {
            return Err(Error::backend(format!(
                "activation rank {} exceeds the interpreter's max rank 4",
                s.len()
            )));
        }
        let mut dims = [0usize; 4];
        dims[..s.len()].copy_from_slice(s);
        Ok(ActShape { dims, rank: s.len() })
    }

    fn d2(b: usize, d: usize) -> ActShape {
        ActShape { dims: [b, d, 0, 0], rank: 2 }
    }

    fn d4(b: usize, h: usize, w: usize, c: usize) -> ActShape {
        ActShape { dims: [b, h, w, c], rank: 4 }
    }

    fn as_slice(&self) -> &[usize] {
        &self.dims[..self.rank]
    }
}

/// Activation storage: the input batch is borrowed from the caller's
/// tensor (the old code cloned it every step); everything downstream
/// lives in workspace buffers.
enum Buf<'a> {
    Borrowed(&'a [f32]),
    Owned(Vec<f32>),
}

impl Buf<'_> {
    fn as_slice(&self) -> &[f32] {
        match self {
            Buf::Borrowed(s) => s,
            Buf::Owned(v) => v,
        }
    }

    fn recycle(self, ws: &mut Workspace) {
        if let Buf::Owned(v) = self {
            ws.recycle(v);
        }
    }
}

/// One weight layer's step-hoisted operands: quantization constants and
/// `fq(w) * mask` (with its sparse index list), built once per train
/// step / eval run.  For conv layers the 2d-transposed weight and mask
/// are kept for the backward `m * ste(w)` products; dense layers use
/// the caller's slices directly.
struct LayerWeights {
    q: Quant,
    mw: MaskedWeight,
    w2: Vec<f32>,
    m2: Vec<f32>,
}

/// Per-layer forward state saved for the fast backward pass.  Relu
/// masks are stored as compact keep-bytes instead of cloning the whole
/// post-activation tensor (all the backward needs is `out <= 0`).
enum FastTape<'a> {
    Dense { x: Buf<'a>, xq: Option<Vec<f32>>, relu: Option<Vec<u8>>, li: usize },
    Conv {
        cols: Vec<f32>,
        colsq: Option<Vec<f32>>,
        in_shape: [usize; 4],
        relu: Option<Vec<u8>>,
        li: usize,
    },
    Pool { in_shape: [usize; 4], arg: Vec<u8> },
    Flatten,
    /// `skip`: the activation captured at the block entry (forward-only).
    ResBegin { skip: Buf<'a> },
    /// `begin`: tape index of the matching [`FastTape::ResBegin`].
    ResAdd { begin: usize, relu: Vec<u8> },
}

fn recycle_tape(ws: &mut Workspace, tape: Vec<FastTape>) {
    for entry in tape {
        match entry {
            FastTape::Dense { x, xq, relu, .. } => {
                x.recycle(ws);
                if let Some(v) = xq {
                    ws.recycle(v);
                }
                if let Some(m) = relu {
                    ws.recycle_u8(m);
                }
            }
            FastTape::Conv { cols, colsq, relu, .. } => {
                ws.recycle(cols);
                if let Some(v) = colsq {
                    ws.recycle(v);
                }
                if let Some(m) = relu {
                    ws.recycle_u8(m);
                }
            }
            FastTape::Pool { arg, .. } => ws.recycle_u8(arg),
            FastTape::Flatten => {}
            FastTape::ResBegin { skip } => skip.recycle(ws),
            FastTape::ResAdd { relu, .. } => ws.recycle_u8(relu),
        }
    }
}

fn recycle_weights(ws: &mut Workspace, lws: Vec<Option<LayerWeights>>) {
    for lw in lws.into_iter().flatten() {
        ws.recycle_weight(lw.mw);
        ws.recycle(lw.w2);
        ws.recycle(lw.m2);
    }
}

/// `keep[i] = !(z[i] <= 0.0)` — the relu-VJP predicate (NaN keeps).
fn keep_mask_into(keep: &mut [u8], z: &[f32]) {
    for (k, &v) in keep.iter_mut().zip(z) {
        *k = u8::from(!(v <= 0.0));
    }
}

/// Apply a keep-mask: `g[i] = 0.0` where the forward output was `<= 0`.
fn apply_keep(g: &mut [f32], keep: &[u8]) {
    for (gv, &k) in g.iter_mut().zip(keep) {
        if k == 0 {
            *gv = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// shared layer loops (used verbatim by the fast and naive paths, so the
// two can never diverge on these ops)
// ---------------------------------------------------------------------------

/// 2x2 VALID max-pool.  Writes argmax bytes only when `arg` is
/// non-empty (the training path).  NaN wins its window (`lax.max`
/// propagates NaN).
fn maxpool_forward(x: &[f32], in_shape: [usize; 4], out: &mut [f32], arg: &mut [u8]) {
    let [b, h, w, c] = in_shape;
    let (oh, ow) = (h / 2, w / 2);
    let record = !arg.is_empty();
    for bi in 0..b {
        for i in 0..oh {
            for j in 0..ow {
                for ci in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u8;
                    for di in 0..2 {
                        for dj in 0..2 {
                            let v = x[((bi * h + 2 * i + di) * w + 2 * j + dj) * c + ci];
                            if v.is_nan() {
                                best = f32::NAN;
                            } else if v > best {
                                best = v;
                                bidx = (di * 2 + dj) as u8;
                            }
                        }
                    }
                    let o = ((bi * oh + i) * ow + j) * c + ci;
                    out[o] = best;
                    if record {
                        arg[o] = bidx;
                    }
                }
            }
        }
    }
}

/// Scatter each output-cell gradient back to its argmax input cell.
/// `dx` must be zeroed by the caller.
fn maxpool_backward(g: &[f32], arg: &[u8], in_shape: [usize; 4], dx: &mut [f32]) {
    let [b, h, w, c] = in_shape;
    let (oh, ow) = (h / 2, w / 2);
    for bi in 0..b {
        for i in 0..oh {
            for j in 0..ow {
                for ci in 0..c {
                    let o = ((bi * oh + i) * ow + j) * c + ci;
                    let (di, dj) = ((arg[o] / 2) as usize, (arg[o] % 2) as usize);
                    dx[((bi * h + 2 * i + di) * w + 2 * j + dj) * c + ci] += g[o];
                }
            }
        }
    }
}

/// NaN-propagating `relu(branch + skip)`, as in `jax.nn.relu`.
fn resadd_forward(branch: &[f32], skip: &[f32], z: &mut [f32]) {
    for ((zv, &v), &s) in z.iter_mut().zip(branch).zip(skip) {
        let sum = v + s;
        *zv = if sum < 0.0 { 0.0 } else { sum };
    }
}

/// `z += bias` (broadcast over rows) then apply the layer activation.
fn apply_bias_activation(
    z: &mut [f32],
    bias: &[f32],
    width: usize,
    activation: &str,
) -> Result<()> {
    for row in z.chunks_mut(width) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
    match activation {
        "relu" => {
            // `if v < 0` rather than f32::max: Rust's max(NaN, 0.0)
            // returns 0.0, but jnp.maximum propagates NaN
            for v in z.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            Ok(())
        }
        "linear" => Ok(()),
        other => Err(Error::backend(format!("unknown activation {other:?}"))),
    }
}

/// `g *= (out > 0)` — the relu VJP against the saved post-activation
/// (the naive path; the fast path stores keep-bytes instead).
fn relu_mask(g: &mut [f32], out: &[f32]) {
    for (gv, &ov) in g.iter_mut().zip(out) {
        if ov <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Column sums of `g[rows, width]` into `db` (zeroed first — the bias
/// gradient).
fn bias_grad_into(db: &mut [f32], g: &[f32], rows: usize, width: usize) {
    db.fill(0.0);
    for i in 0..rows {
        for (d, &gv) in db.iter_mut().zip(&g[i * width..(i + 1) * width]) {
            *d += gv;
        }
    }
}

// ---------------------------------------------------------------------------
// naive-path helpers (guarded layout transforms returning fresh Vecs)
// ---------------------------------------------------------------------------

fn im2col_vec(x: &[f32], shape: [usize; 4], k: usize) -> Result<Vec<f32>> {
    let [b, h, w, c] = shape;
    let mut cols = vec![0.0f32; b * h * w * c * k * k];
    kernels::im2col(&mut cols, x, shape, k)?;
    Ok(cols)
}

fn col2im_vec(dcols: &[f32], shape: [usize; 4], k: usize) -> Result<Vec<f32>> {
    let [b, h, w, c] = shape;
    let mut dx = vec![0.0f32; b * h * w * c];
    kernels::col2im(&mut dx, dcols, shape, k)?;
    Ok(dx)
}

fn hwio_to_2d_vec(w4: &[f32], k: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut w2 = vec![0.0f32; cin * k * k * cout];
    kernels::hwio_to_2d(&mut w2, w4, k, cin, cout);
    w2
}

fn hwio_from_2d_vec(w2: &[f32], k: usize, cin: usize, cout: usize) -> Vec<f32> {
    let mut w4 = vec![0.0f32; k * k * cin * cout];
    kernels::hwio_from_2d(&mut w4, w2, k, cin, cout);
    w4
}

// ---------------------------------------------------------------------------
// naive-path forward/backward state (the original implementation)
// ---------------------------------------------------------------------------

/// Current activation value flowing through the naive layer stack.
struct Act {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Per-layer state saved by the naive forward pass for the backward pass.
enum Tape {
    /// `x`: pre-quantization layer input; `out`: post-activation output.
    Dense { x: Vec<f32>, out: Vec<f32>, li: usize },
    /// `cols`: pre-quantization im2col patches; `in_shape`: input NHWC.
    Conv { cols: Vec<f32>, in_shape: [usize; 4], out: Vec<f32>, li: usize },
    /// `arg`: per-output-cell index of the (first) max in its 2x2 window.
    Pool { in_shape: [usize; 4], arg: Vec<u8> },
    Flatten,
    /// `skip`: the activation captured at the block entry.
    ResBegin { skip: Vec<f32> },
    /// `begin`: tape index of the matching [`Tape::ResBegin`].
    ResAdd { begin: usize, out: Vec<f32> },
}

struct Forward {
    logits: Act,
    tape: Vec<Tape>,
}

/// A manifest variant bound to the reference interpreter.
///
/// Holds the immutable variant description, the shared atomic stats
/// cell, and a pool of reusable [`Workspace`]s — one is checked out per
/// step, so one model is freely stepped from concurrent DSE probe
/// workers (`ModelExec` requires `Send + Sync`) without contention or
/// per-step allocation.
pub struct RefModel {
    variant: ModelVariant,
    stats: Arc<StatsCell>,
    mode: KernelMode,
    workspaces: Mutex<Vec<Workspace>>,
}

impl RefModel {
    fn take_ws(&self) -> Workspace {
        self.workspaces
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop()
            .unwrap_or_default()
    }

    fn put_ws(&self, ws: Workspace) {
        self.workspaces
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(ws);
    }

    fn layer_q(&self, qcfg: &[f32], l: &LayerDesc) -> Result<(f32, f32)> {
        let row = l.mask_idx as usize;
        if l.mask_idx < 0 || (row + 1) * 2 > qcfg.len() {
            return Err(Error::backend(format!(
                "layer {} has qcfg row {} but qcfg holds {} rows",
                l.name,
                l.mask_idx,
                qcfg.len() / 2
            )));
        }
        Ok((qcfg[2 * row], qcfg[2 * row + 1]))
    }

    /// Parse the model operand prefix (`params ++ masks ++ [qcfg]`).
    fn split_base<'a>(&self, args: &'a [HostTensor]) -> Result<BaseArgs<'a>> {
        let n_p = self.variant.n_params();
        let n_m = self.variant.n_masks();
        if args.len() != n_p + n_m + 1 {
            return Err(Error::backend(format!(
                "expected {} model operands, got {}",
                n_p + n_m + 1,
                args.len()
            )));
        }
        let mut params = Vec::with_capacity(n_p);
        for (i, (name, shape)) in self.variant.param_shapes.iter().enumerate() {
            let p = args[i].as_f32()?;
            let want: usize = shape.iter().product();
            if p.len() != want {
                return Err(Error::backend(format!(
                    "param {name}: expected {want} elements, got {}",
                    p.len()
                )));
            }
            params.push(p);
        }
        let mut masks = Vec::with_capacity(n_m);
        for (i, (pidx, shape)) in self.variant.mask_shapes.iter().enumerate() {
            let m = args[n_p + i].as_f32()?;
            let want: usize = shape.iter().product();
            if m.len() != want {
                return Err(Error::backend(format!(
                    "mask {i} (param {pidx}): expected {want} elements, got {}",
                    m.len()
                )));
            }
            masks.push(m);
        }
        let qcfg = args[n_p + n_m].as_f32()?;
        if qcfg.len() != 2 * self.variant.qcfg_rows {
            return Err(Error::backend(format!(
                "qcfg: expected {} rows, got {} elements",
                self.variant.qcfg_rows,
                qcfg.len()
            )));
        }
        Ok(BaseArgs { params, masks, qcfg })
    }

    /// Parse a full flat step argument list (the
    /// `python/compile/train.py` convention).
    fn split_step<'a>(
        &self,
        args: &'a [HostTensor],
        with_lr: bool,
    ) -> Result<(BaseArgs<'a>, &'a HostTensor, &'a [i32], Option<f32>)> {
        let n_p = self.variant.n_params();
        let n_m = self.variant.n_masks();
        let expect = n_p + n_m + 3 + usize::from(with_lr);
        if args.len() != expect {
            return Err(Error::backend(format!(
                "expected {expect} args, got {}",
                args.len()
            )));
        }
        let base = self.split_base(&args[..n_p + n_m + 1])?;
        let x = &args[n_p + n_m + 1];
        let y = args[n_p + n_m + 2].as_i32()?;
        let batch = *x.shape().first().unwrap_or(&0);
        if y.len() != batch {
            return Err(Error::backend(format!(
                "labels: expected {batch} entries, got {}",
                y.len()
            )));
        }
        let lr = if with_lr { Some(args[n_p + n_m + 3].scalar_f32()?) } else { None };
        Ok((base, x, y, lr))
    }

    /// Stable softmax cross-entropy + accuracy; optionally fills
    /// `d loss / d logits` into `grad` (resized to `[B, n_classes]`).
    /// One implementation serves eval (no grad) and training — the
    /// loss/accuracy arithmetic cannot diverge between them.
    fn loss_acc_core(
        &self,
        shape: &[usize],
        logits: &[f32],
        y: &[i32],
        mut grad: Option<&mut Vec<f32>>,
    ) -> Result<(f32, f32)> {
        let n_classes = self.variant.n_classes;
        if shape.len() != 2 || shape[1] != n_classes {
            return Err(Error::backend(format!(
                "logits shape {shape:?}, want [B, {n_classes}]"
            )));
        }
        let b = shape[0];
        if let Some(d) = grad.as_deref_mut() {
            d.clear();
            d.resize(b * n_classes, 0.0);
        }
        let mut loss = 0.0f32;
        let mut correct = 0usize;
        for i in 0..b {
            let row = &logits[i * n_classes..(i + 1) * n_classes];
            let label = y[i];
            if label < 0 || label as usize >= n_classes {
                return Err(Error::backend(format!(
                    "label {label} out of range [0, {n_classes})"
                )));
            }
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            let lse = sum.ln();
            loss -= row[label as usize] - mx - lse;
            // argmax with first-max tie-break and NaN treated as maximal
            // (jnp.argmax semantics)
            let mut am = 0usize;
            for (c, &v) in row.iter().enumerate().skip(1) {
                let cur = row[am];
                let better = if v.is_nan() { !cur.is_nan() } else { v > cur };
                if better {
                    am = c;
                }
            }
            if am == label as usize {
                correct += 1;
            }
            if let Some(d) = grad.as_deref_mut() {
                for c in 0..n_classes {
                    let soft = (row[c] - mx - lse).exp();
                    let onehot = if c == label as usize { 1.0 } else { 0.0 };
                    d[i * n_classes + c] = (soft - onehot) / b as f32;
                }
            }
        }
        Ok((loss / b as f32, correct as f32 / b as f32))
    }
}

// ---------------------------------------------------------------------------
// fast path
// ---------------------------------------------------------------------------

impl RefModel {
    /// Hoist every weight layer's step-constant operands: quantization
    /// constants, `fq(w) * mask`, and (below the density threshold) the
    /// compressed sparse index list.  Indexed by layer position; `None`
    /// for layers without weights.
    fn prepare_weights(
        &self,
        base: &BaseArgs,
        ws: &mut Workspace,
    ) -> Result<Vec<Option<LayerWeights>>> {
        let threshold = match self.mode {
            KernelMode::Fast => SPARSE_DENSITY_THRESHOLD,
            // density < 0.0 never holds: the sparse list is never built
            _ => 0.0,
        };
        let mut lws = Vec::with_capacity(self.variant.layers.len());
        for l in &self.variant.layers {
            lws.push(match l.kind.as_str() {
                "dense" => {
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    let q = Quant::new(wb, ib);
                    let w = base.params[l.param_w as usize];
                    let mask = base.masks[l.mask_idx as usize];
                    let mw = MaskedWeight::build(ws, w, mask, &q, l.in_dim, l.out_dim, threshold);
                    Some(LayerWeights { q, mw, w2: Vec::new(), m2: Vec::new() })
                }
                "conv2d" => {
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    let q = Quant::new(wb, ib);
                    let (k, cin, cout) = (l.kernel, l.in_dim, l.out_dim);
                    let mut w2 = ws.buf_uninit(cin * k * k * cout);
                    let mut m2 = ws.buf_uninit(cin * k * k * cout);
                    kernels::hwio_to_2d(&mut w2, base.params[l.param_w as usize], k, cin, cout);
                    kernels::hwio_to_2d(&mut m2, base.masks[l.mask_idx as usize], k, cin, cout);
                    let mw = MaskedWeight::build(ws, &w2, &m2, &q, cin * k * k, cout, threshold);
                    Some(LayerWeights { q, mw, w2, m2 })
                }
                _ => None,
            });
        }
        Ok(lws)
    }

    /// Fast forward pass.  With `record` set, saves per-layer state for
    /// [`Self::backward_fast`]; without it (the eval path) only the
    /// [`FastTape::ResBegin`] skip values needed by the forward itself
    /// are kept.  The input batch is borrowed, never copied.
    fn forward_fast<'a>(
        &self,
        base: &BaseArgs<'a>,
        x: &'a HostTensor,
        lws: &[Option<LayerWeights>],
        ws: &mut Workspace,
        record: bool,
    ) -> Result<(ActShape, Buf<'a>, Vec<FastTape<'a>>)> {
        let (xshape, xdata) = x.as_f32_shaped()?;
        let mut shape = ActShape::from_slice(xshape)?;
        let mut data: Buf<'a> = Buf::Borrowed(xdata);
        let mut tape: Vec<FastTape<'a>> = Vec::with_capacity(self.variant.layers.len());
        let mut res_stack: Vec<usize> = Vec::new();

        for (li, l) in self.variant.layers.iter().enumerate() {
            match l.kind.as_str() {
                "dense" => {
                    if shape.rank != 2 || shape.dims[1] != l.in_dim {
                        return Err(Error::backend(format!(
                            "dense {}: input shape {:?}, want [B, {}]",
                            l.name,
                            shape.as_slice(),
                            l.in_dim
                        )));
                    }
                    let lw = lws[li].as_ref().expect("weights prepared for dense layer");
                    let b = shape.dims[0];
                    let bias = base.params[l.param_b as usize];
                    let xq = if lw.q.enabled() {
                        let mut buf = ws.buf_uninit(data.as_slice().len());
                        lw.q.fq_into(&mut buf, data.as_slice());
                        Some(buf)
                    } else {
                        None
                    };
                    let mut z = ws.buf_uninit(b * l.out_dim);
                    {
                        let src = match &xq {
                            Some(v) => v.as_slice(),
                            None => data.as_slice(),
                        };
                        matmul_masked(&mut z, src, &lw.mw, b, l.in_dim, l.out_dim, &mut ws.pack);
                    }
                    apply_bias_activation(&mut z, bias, l.out_dim, &l.activation)?;
                    let relu = if record && l.activation == "relu" {
                        let mut m = ws.buf_u8(z.len());
                        keep_mask_into(&mut m, &z);
                        Some(m)
                    } else {
                        None
                    };
                    let prev = std::mem::replace(&mut data, Buf::Owned(z));
                    if record {
                        tape.push(FastTape::Dense { x: prev, xq, relu, li });
                    } else {
                        prev.recycle(ws);
                        if let Some(v) = xq {
                            ws.recycle(v);
                        }
                    }
                    shape = ActShape::d2(b, l.out_dim);
                }
                "conv2d" => {
                    if shape.rank != 4 || shape.dims[3] != l.in_dim {
                        return Err(Error::backend(format!(
                            "conv2d {}: input shape {:?}, want [B,H,W,{}]",
                            l.name,
                            shape.as_slice(),
                            l.in_dim
                        )));
                    }
                    let lw = lws[li].as_ref().expect("weights prepared for conv layer");
                    let in_shape = shape.dims;
                    let [b, h, w, cin] = in_shape;
                    let (k, cout) = (l.kernel, l.out_dim);
                    let fk = cin * k * k;
                    let rows = b * h * w;
                    let mut cols = ws.buf_uninit(rows * fk);
                    kernels::im2col(&mut cols, data.as_slice(), in_shape, k)?;
                    let colsq = if lw.q.enabled() {
                        let mut buf = ws.buf_uninit(cols.len());
                        lw.q.fq_into(&mut buf, &cols);
                        Some(buf)
                    } else {
                        None
                    };
                    let mut z = ws.buf_uninit(rows * cout);
                    {
                        let src = match &colsq {
                            Some(v) => v.as_slice(),
                            None => cols.as_slice(),
                        };
                        matmul_masked(&mut z, src, &lw.mw, rows, fk, cout, &mut ws.pack);
                    }
                    apply_bias_activation(
                        &mut z,
                        base.params[l.param_b as usize],
                        cout,
                        &l.activation,
                    )?;
                    let relu = if record && l.activation == "relu" {
                        let mut m = ws.buf_u8(z.len());
                        keep_mask_into(&mut m, &z);
                        Some(m)
                    } else {
                        None
                    };
                    let prev = std::mem::replace(&mut data, Buf::Owned(z));
                    // the conv backward reads the patches, not the input
                    prev.recycle(ws);
                    if record {
                        tape.push(FastTape::Conv { cols, colsq, in_shape, relu, li });
                    } else {
                        ws.recycle(cols);
                        if let Some(v) = colsq {
                            ws.recycle(v);
                        }
                    }
                    shape = ActShape::d4(b, h, w, cout);
                }
                "maxpool2" => {
                    if shape.rank != 4 {
                        return Err(Error::backend(format!(
                            "maxpool2: input shape {:?}, want NHWC",
                            shape.as_slice()
                        )));
                    }
                    let in_shape = shape.dims;
                    let [b, h, w, c] = in_shape;
                    let (oh, ow) = (h / 2, w / 2);
                    let out_len = b * oh * ow * c;
                    let mut out = ws.buf_uninit(out_len);
                    let mut arg = ws.buf_u8(if record { out_len } else { 0 });
                    maxpool_forward(data.as_slice(), in_shape, &mut out, &mut arg);
                    let prev = std::mem::replace(&mut data, Buf::Owned(out));
                    prev.recycle(ws);
                    if record {
                        tape.push(FastTape::Pool { in_shape, arg });
                    } else {
                        ws.recycle_u8(arg);
                    }
                    shape = ActShape::d4(b, oh, ow, c);
                }
                "flatten" => {
                    let b = shape.dims[0];
                    let rest: usize = shape.as_slice()[1..].iter().product();
                    if record {
                        tape.push(FastTape::Flatten);
                    }
                    shape = ActShape::d2(b, rest);
                }
                "residual_begin" => {
                    res_stack.push(tape.len());
                    let skip = match &data {
                        Buf::Borrowed(s) => Buf::Borrowed(*s),
                        Buf::Owned(v) => {
                            let mut c = ws.buf_uninit(v.len());
                            c.copy_from_slice(v);
                            Buf::Owned(c)
                        }
                    };
                    tape.push(FastTape::ResBegin { skip });
                }
                "residual_add" => {
                    let begin = res_stack.pop().ok_or_else(|| {
                        Error::backend("residual_add without residual_begin")
                    })?;
                    let z = {
                        let skip = match &tape[begin] {
                            FastTape::ResBegin { skip } => skip.as_slice(),
                            _ => unreachable!("res_stack points at ResBegin entries"),
                        };
                        if skip.len() != data.as_slice().len() {
                            return Err(Error::backend(
                                "residual_add: branch/skip shape mismatch",
                            ));
                        }
                        let mut z = ws.buf_uninit(skip.len());
                        resadd_forward(data.as_slice(), skip, &mut z);
                        z
                    };
                    let relu = if record {
                        let mut m = ws.buf_u8(z.len());
                        keep_mask_into(&mut m, &z);
                        Some(m)
                    } else {
                        None
                    };
                    let prev = std::mem::replace(&mut data, Buf::Owned(z));
                    prev.recycle(ws);
                    if let Some(relu) = relu {
                        tape.push(FastTape::ResAdd { begin, relu });
                    }
                }
                other => {
                    return Err(Error::backend(format!(
                        "reference interpreter: unknown layer kind {other:?}"
                    )))
                }
            }
        }
        Ok((shape, data, tape))
    }

    /// Fast reverse pass; consumes the tape (recycling each entry as it
    /// is processed) and returns per-param gradients in flat param
    /// order, all in workspace buffers.
    fn backward_fast(
        &self,
        base: &BaseArgs,
        lws: &[Option<LayerWeights>],
        tape: Vec<FastTape>,
        dlogits: Vec<f32>,
        ws: &mut Workspace,
    ) -> Result<Vec<Vec<f32>>> {
        let mut grads: Vec<Vec<f32>> =
            base.params.iter().map(|p| ws.buf(p.len())).collect();
        let mut g = dlogits;
        // gradient contributions waiting at a ResBegin tape index
        let mut pending: Vec<Option<Vec<f32>>> = (0..tape.len()).map(|_| None).collect();

        for (t, entry) in tape.into_iter().enumerate().rev() {
            match entry {
                FastTape::Dense { x, xq, relu, li } => {
                    let l = &self.variant.layers[li];
                    let lw = lws[li].as_ref().expect("weights prepared for dense layer");
                    if let Some(m) = &relu {
                        apply_keep(&mut g, m);
                    }
                    let xs = x.as_slice();
                    let b = xs.len() / l.in_dim;
                    let w = base.params[l.param_w as usize];
                    let mask = base.masks[l.mask_idx as usize];
                    bias_grad_into(&mut grads[l.param_b as usize], &g, b, l.out_dim);
                    let mut dx = ws.buf_uninit(b * l.in_dim);
                    matmul_bt_masked(&mut dx, &g, &lw.mw, b, l.out_dim, l.in_dim);
                    if lw.q.enabled() {
                        for (d, &xv) in dx.iter_mut().zip(xs) {
                            *d *= lw.q.ste(xv);
                        }
                    }
                    let mut dw = ws.buf_uninit(l.in_dim * l.out_dim);
                    {
                        let src = match &xq {
                            Some(v) => v.as_slice(),
                            None => xs,
                        };
                        matmul_at(&mut dw, src, &g, b, l.in_dim, l.out_dim, &mut ws.pack);
                    }
                    if lw.q.enabled() {
                        for ((d, &mv), &wv) in dw.iter_mut().zip(mask).zip(w) {
                            *d *= mv * lw.q.ste(wv);
                        }
                    } else {
                        for (d, &mv) in dw.iter_mut().zip(mask) {
                            *d *= mv;
                        }
                    }
                    ws.recycle(std::mem::replace(&mut grads[l.param_w as usize], dw));
                    x.recycle(ws);
                    if let Some(v) = xq {
                        ws.recycle(v);
                    }
                    if let Some(m) = relu {
                        ws.recycle_u8(m);
                    }
                    ws.recycle(std::mem::replace(&mut g, dx));
                }
                FastTape::Conv { cols, colsq, in_shape, relu, li } => {
                    let l = &self.variant.layers[li];
                    let lw = lws[li].as_ref().expect("weights prepared for conv layer");
                    if let Some(m) = &relu {
                        apply_keep(&mut g, m);
                    }
                    let [_, _, _, cin] = in_shape;
                    let (k, cout) = (l.kernel, l.out_dim);
                    let fk = cin * k * k;
                    let rows = cols.len() / fk;
                    bias_grad_into(&mut grads[l.param_b as usize], &g, rows, cout);
                    let mut dcols = ws.buf_uninit(rows * fk);
                    matmul_bt_masked(&mut dcols, &g, &lw.mw, rows, cout, fk);
                    if lw.q.enabled() {
                        for (d, &cv) in dcols.iter_mut().zip(&cols) {
                            *d *= lw.q.ste(cv);
                        }
                    }
                    let mut dw2 = ws.buf_uninit(fk * cout);
                    {
                        let src = match &colsq {
                            Some(v) => v.as_slice(),
                            None => cols.as_slice(),
                        };
                        matmul_at(&mut dw2, src, &g, rows, fk, cout, &mut ws.pack);
                    }
                    if lw.q.enabled() {
                        for ((d, &mv), &wv) in dw2.iter_mut().zip(&lw.m2).zip(&lw.w2) {
                            *d *= mv * lw.q.ste(wv);
                        }
                    } else {
                        for (d, &mv) in dw2.iter_mut().zip(&lw.m2) {
                            *d *= mv;
                        }
                    }
                    let mut dw4 = ws.buf_uninit(k * k * cin * cout);
                    kernels::hwio_from_2d(&mut dw4, &dw2, k, cin, cout);
                    ws.recycle(std::mem::replace(&mut grads[l.param_w as usize], dw4));
                    let mut dx = ws.buf_uninit(rows * cin);
                    kernels::col2im(&mut dx, &dcols, in_shape, k)?;
                    ws.recycle(dcols);
                    ws.recycle(dw2);
                    ws.recycle(cols);
                    if let Some(v) = colsq {
                        ws.recycle(v);
                    }
                    if let Some(m) = relu {
                        ws.recycle_u8(m);
                    }
                    ws.recycle(std::mem::replace(&mut g, dx));
                }
                FastTape::Pool { in_shape, arg } => {
                    let [b, h, w, c] = in_shape;
                    let mut dx = ws.buf(b * h * w * c);
                    maxpool_backward(&g, &arg, in_shape, &mut dx);
                    ws.recycle_u8(arg);
                    ws.recycle(std::mem::replace(&mut g, dx));
                }
                FastTape::Flatten => {
                    // pure reshape: the gradient buffer is already flat
                }
                FastTape::ResAdd { begin, relu } => {
                    apply_keep(&mut g, &relu);
                    ws.recycle_u8(relu);
                    if let Some(acc) = pending[begin].as_mut() {
                        for (dst, &src) in acc.iter_mut().zip(&g) {
                            *dst += src;
                        }
                    } else {
                        let mut c = ws.buf_uninit(g.len());
                        c.copy_from_slice(&g);
                        pending[begin] = Some(c);
                    }
                }
                FastTape::ResBegin { skip } => {
                    skip.recycle(ws);
                    if let Some(skip_g) = pending[t].take() {
                        for (dst, &src) in g.iter_mut().zip(&skip_g) {
                            *dst += src;
                        }
                        ws.recycle(skip_g);
                    }
                }
            }
        }
        ws.recycle(g);
        Ok(grads)
    }

    fn train_step_fast(
        &self,
        base: &BaseArgs,
        x: &HostTensor,
        y: &[i32],
        lr: f32,
        ws: &mut Workspace,
    ) -> Result<(Vec<HostTensor>, f32, f32)> {
        let lws = self.prepare_weights(base, ws)?;
        let (shape, logits, tape) = self.forward_fast(base, x, &lws, ws, true)?;
        let mut dlogits = ws.buf_uninit(0);
        let (loss, acc) =
            self.loss_acc_core(shape.as_slice(), logits.as_slice(), y, Some(&mut dlogits))?;
        logits.recycle(ws);
        let grads = self.backward_fast(base, &lws, tape, dlogits, ws)?;
        let mut new_params = Vec::with_capacity(base.params.len());
        for (i, (p, gr)) in base.params.iter().zip(&grads).enumerate() {
            let data: Vec<f32> = p.iter().zip(gr).map(|(&pv, &gv)| pv - lr * gv).collect();
            let shape = &self.variant.param_shapes[i].1;
            new_params.push(HostTensor::F32 { shape: shape.clone(), data });
        }
        for gr in grads {
            ws.recycle(gr);
        }
        recycle_weights(ws, lws);
        Ok((new_params, loss, acc))
    }

    fn eval_step_fast(
        &self,
        base: &BaseArgs,
        x: &HostTensor,
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(f32, f32)> {
        let lws = self.prepare_weights(base, ws)?;
        let out = self.eval_forward_fast(base, x, y, &lws, ws);
        recycle_weights(ws, lws);
        out
    }

    /// One eval forward against already-prepared weights (the shared
    /// core of [`Self::eval_step_fast`] and the batched eval run).
    fn eval_forward_fast(
        &self,
        base: &BaseArgs,
        x: &HostTensor,
        y: &[i32],
        lws: &[Option<LayerWeights>],
        ws: &mut Workspace,
    ) -> Result<(f32, f32)> {
        let (shape, logits, tape) = self.forward_fast(base, x, lws, ws, false)?;
        let out = self.loss_acc_core(shape.as_slice(), logits.as_slice(), y, None);
        logits.recycle(ws);
        recycle_tape(ws, tape);
        out
    }

    /// The fast branch of [`ModelExec::eval_batches`]: prepare weights
    /// once, then run every batch against them.
    fn eval_batches_fast(
        &self,
        base: &BaseArgs,
        batches: &[(HostTensor, HostTensor)],
        out: &mut Vec<(f32, f32)>,
        ws: &mut Workspace,
    ) -> Result<()> {
        let lws = self.prepare_weights(base, ws)?;
        for (x, y) in batches {
            let t0 = Instant::now();
            let y = y.as_i32()?;
            check_labels(x, y)?;
            out.push(self.eval_forward_fast(base, x, y, &lws, ws)?);
            self.stats.add_execute(t0.elapsed());
        }
        recycle_weights(ws, lws);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// naive path (the original implementation, kept as oracle + baseline)
// ---------------------------------------------------------------------------

impl RefModel {
    /// The original forward pass: per-call `fq(w) * mask`
    /// requantization, naive triple-loop matmuls, fresh `Vec`s
    /// throughout.  Bit-identical to [`Self::forward_fast`].
    fn forward_naive(&self, base: &BaseArgs, x: &HostTensor, record: bool) -> Result<Forward> {
        let mut act = Act { shape: x.shape().to_vec(), data: x.as_f32()?.to_vec() };
        let mut tape: Vec<Tape> = Vec::with_capacity(self.variant.layers.len());
        let mut res_stack: Vec<usize> = Vec::new();

        for (li, l) in self.variant.layers.iter().enumerate() {
            match l.kind.as_str() {
                "dense" => {
                    if act.shape.len() != 2 || act.shape[1] != l.in_dim {
                        return Err(Error::backend(format!(
                            "dense {}: input shape {:?}, want [B, {}]",
                            l.name, act.shape, l.in_dim
                        )));
                    }
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    let b = act.shape[0];
                    let w = base.params[l.param_w as usize];
                    let bias = base.params[l.param_b as usize];
                    let mask = base.masks[l.mask_idx as usize];
                    let wq = naive::quantized_masked(w, mask, wb, ib);
                    let xq: Vec<f32> =
                        act.data.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut z = naive::mm(&xq, &wq, b, l.in_dim, l.out_dim);
                    apply_bias_activation(&mut z, bias, l.out_dim, &l.activation)?;
                    if record {
                        tape.push(Tape::Dense {
                            x: std::mem::take(&mut act.data),
                            out: z.clone(),
                            li,
                        });
                    }
                    act = Act { shape: vec![b, l.out_dim], data: z };
                }
                "conv2d" => {
                    if act.shape.len() != 4 || act.shape[3] != l.in_dim {
                        return Err(Error::backend(format!(
                            "conv2d {}: input shape {:?}, want [B,H,W,{}]",
                            l.name, act.shape, l.in_dim
                        )));
                    }
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    let in_shape =
                        [act.shape[0], act.shape[1], act.shape[2], act.shape[3]];
                    let [b, h, w, cin] = in_shape;
                    let k = l.kernel;
                    let cout = l.out_dim;
                    let cols = im2col_vec(&act.data, in_shape, k)?;
                    let w2 = hwio_to_2d_vec(base.params[l.param_w as usize], k, cin, cout);
                    let m2 = hwio_to_2d_vec(base.masks[l.mask_idx as usize], k, cin, cout);
                    let wq2 = naive::quantized_masked(&w2, &m2, wb, ib);
                    let colsq: Vec<f32> =
                        cols.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let rows = b * h * w;
                    let mut z = naive::mm(&colsq, &wq2, rows, cin * k * k, cout);
                    apply_bias_activation(
                        &mut z,
                        base.params[l.param_b as usize],
                        cout,
                        &l.activation,
                    )?;
                    if record {
                        tape.push(Tape::Conv { cols, in_shape, out: z.clone(), li });
                    }
                    act = Act { shape: vec![b, h, w, cout], data: z };
                }
                "maxpool2" => {
                    if act.shape.len() != 4 {
                        return Err(Error::backend(format!(
                            "maxpool2: input shape {:?}, want NHWC",
                            act.shape
                        )));
                    }
                    let in_shape =
                        [act.shape[0], act.shape[1], act.shape[2], act.shape[3]];
                    let [b, h, w, c] = in_shape;
                    let (oh, ow) = (h / 2, w / 2);
                    let mut out = vec![0.0f32; b * oh * ow * c];
                    let mut arg = if record { vec![0u8; b * oh * ow * c] } else { Vec::new() };
                    maxpool_forward(&act.data, in_shape, &mut out, &mut arg);
                    if record {
                        tape.push(Tape::Pool { in_shape, arg });
                    }
                    act = Act { shape: vec![b, oh, ow, c], data: out };
                }
                "flatten" => {
                    let b = act.shape[0];
                    let rest: usize = act.shape[1..].iter().product();
                    if record {
                        tape.push(Tape::Flatten);
                    }
                    act.shape = vec![b, rest];
                }
                "residual_begin" => {
                    res_stack.push(tape.len());
                    tape.push(Tape::ResBegin { skip: act.data.clone() });
                }
                "residual_add" => {
                    let begin = res_stack.pop().ok_or_else(|| {
                        Error::backend("residual_add without residual_begin")
                    })?;
                    let skip = match &tape[begin] {
                        Tape::ResBegin { skip } => skip,
                        _ => unreachable!("res_stack points at ResBegin entries"),
                    };
                    if skip.len() != act.data.len() {
                        return Err(Error::backend(
                            "residual_add: branch/skip shape mismatch",
                        ));
                    }
                    let mut z = vec![0.0f32; skip.len()];
                    resadd_forward(&act.data, skip, &mut z);
                    if record {
                        tape.push(Tape::ResAdd { begin, out: z.clone() });
                    }
                    act.data = z;
                }
                other => {
                    return Err(Error::backend(format!(
                        "reference interpreter: unknown layer kind {other:?}"
                    )))
                }
            }
        }
        Ok(Forward { logits: act, tape })
    }

    /// The original reverse pass over the naive tape; returns per-param
    /// gradients in flat param order.
    fn backward_naive(
        &self,
        base: &BaseArgs,
        fwd: &Forward,
        dlogits: Vec<f32>,
    ) -> Result<Vec<Vec<f32>>> {
        let mut grads: Vec<Vec<f32>> =
            base.params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let mut g = dlogits;
        // gradient contributions waiting at a ResBegin tape index
        let mut pending: Vec<Option<Vec<f32>>> = (0..fwd.tape.len()).map(|_| None).collect();

        for (t, entry) in fwd.tape.iter().enumerate().rev() {
            match entry {
                Tape::Dense { x, out, li } => {
                    let l = &self.variant.layers[*li];
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    if l.activation == "relu" {
                        relu_mask(&mut g, out);
                    }
                    let b = x.len() / l.in_dim;
                    let w = base.params[l.param_w as usize];
                    let mask = base.masks[l.mask_idx as usize];
                    bias_grad_into(&mut grads[l.param_b as usize], &g, b, l.out_dim);
                    let wq = naive::quantized_masked(w, mask, wb, ib);
                    let mut dx = naive::mm_bt(&g, &wq, b, l.out_dim, l.in_dim);
                    for (d, &xv) in dx.iter_mut().zip(x) {
                        *d *= ste(xv, wb, ib);
                    }
                    let xq: Vec<f32> =
                        x.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut dw = naive::mm_at(&xq, &g, b, l.in_dim, l.out_dim);
                    for ((d, &mv), &wv) in dw.iter_mut().zip(mask).zip(w) {
                        *d *= mv * ste(wv, wb, ib);
                    }
                    grads[l.param_w as usize] = dw;
                    g = dx;
                }
                Tape::Conv { cols, in_shape, out, li } => {
                    let l = &self.variant.layers[*li];
                    let (wb, ib) = self.layer_q(base.qcfg, l)?;
                    if l.activation == "relu" {
                        relu_mask(&mut g, out);
                    }
                    let [_, _, _, cin] = *in_shape;
                    let (k, cout) = (l.kernel, l.out_dim);
                    let fk = cin * k * k;
                    let rows = cols.len() / fk;
                    bias_grad_into(&mut grads[l.param_b as usize], &g, rows, cout);
                    let w2 = hwio_to_2d_vec(base.params[l.param_w as usize], k, cin, cout);
                    let m2 = hwio_to_2d_vec(base.masks[l.mask_idx as usize], k, cin, cout);
                    let wq2 = naive::quantized_masked(&w2, &m2, wb, ib);
                    let mut dcols = naive::mm_bt(&g, &wq2, rows, cout, fk);
                    for (d, &cv) in dcols.iter_mut().zip(cols) {
                        *d *= ste(cv, wb, ib);
                    }
                    let colsq: Vec<f32> =
                        cols.iter().map(|&v| fake_quant(v, wb, ib)).collect();
                    let mut dw2 = naive::mm_at(&colsq, &g, rows, fk, cout);
                    for ((d, &mv), &wv) in dw2.iter_mut().zip(&m2).zip(&w2) {
                        *d *= mv * ste(wv, wb, ib);
                    }
                    grads[l.param_w as usize] = hwio_from_2d_vec(&dw2, k, cin, cout);
                    g = col2im_vec(&dcols, *in_shape, k)?;
                }
                Tape::Pool { in_shape, arg } => {
                    let [b, h, w, c] = *in_shape;
                    let mut dx = vec![0.0f32; b * h * w * c];
                    maxpool_backward(&g, arg, *in_shape, &mut dx);
                    g = dx;
                }
                Tape::Flatten => {
                    // pure reshape: the gradient buffer is already flat
                }
                Tape::ResAdd { begin, out } => {
                    relu_mask(&mut g, out);
                    if let Some(acc) = pending[*begin].as_mut() {
                        for (dst, &src) in acc.iter_mut().zip(&g) {
                            *dst += src;
                        }
                    } else {
                        pending[*begin] = Some(g.clone());
                    }
                }
                Tape::ResBegin { .. } => {
                    if let Some(skip_g) = pending[t].take() {
                        for (dst, &src) in g.iter_mut().zip(&skip_g) {
                            *dst += src;
                        }
                    }
                }
            }
        }
        Ok(grads)
    }

    fn train_step_naive(
        &self,
        base: &BaseArgs,
        x: &HostTensor,
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<HostTensor>, f32, f32)> {
        let fwd = self.forward_naive(base, x, true)?;
        let mut dlogits = Vec::new();
        let (loss, acc) = self.loss_acc_core(
            &fwd.logits.shape,
            &fwd.logits.data,
            y,
            Some(&mut dlogits),
        )?;
        let grads = self.backward_naive(base, &fwd, dlogits)?;
        let mut new_params = Vec::with_capacity(base.params.len());
        for (i, (p, gr)) in base.params.iter().zip(&grads).enumerate() {
            let data: Vec<f32> = p.iter().zip(gr).map(|(&pv, &gv)| pv - lr * gv).collect();
            let shape = &self.variant.param_shapes[i].1;
            new_params.push(HostTensor::F32 { shape: shape.clone(), data });
        }
        Ok((new_params, loss, acc))
    }

    fn eval_step_naive(&self, base: &BaseArgs, x: &HostTensor, y: &[i32]) -> Result<(f32, f32)> {
        let fwd = self.forward_naive(base, x, false)?;
        self.loss_acc_core(&fwd.logits.shape, &fwd.logits.data, y, None)
    }
}

/// Per-batch label validation shared by the step and batched-eval entry
/// points.
fn check_labels(x: &HostTensor, y: &[i32]) -> Result<()> {
    let batch = *x.shape().first().unwrap_or(&0);
    if y.len() != batch {
        return Err(Error::backend(format!(
            "labels: expected {batch} entries, got {}",
            y.len()
        )));
    }
    Ok(())
}

impl ModelExec for RefModel {
    fn variant(&self) -> &ModelVariant {
        &self.variant
    }

    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let t0 = Instant::now();
        let (base, x, y, lr) = self.split_step(args, true)?;
        let lr = lr.expect("split_step(with_lr)");
        let out = match self.mode {
            KernelMode::Naive => self.train_step_naive(&base, x, y, lr)?,
            _ => {
                let mut ws = self.take_ws();
                let out = self.train_step_fast(&base, x, y, lr, &mut ws);
                self.put_ws(ws);
                out?
            }
        };
        self.stats.add_execute(t0.elapsed());
        Ok(out)
    }

    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        let (base, x, y, _) = self.split_step(args, false)?;
        let out = match self.mode {
            KernelMode::Naive => self.eval_step_naive(&base, x, y)?,
            _ => {
                let mut ws = self.take_ws();
                let out = self.eval_step_fast(&base, x, y, &mut ws);
                self.put_ws(ws);
                out?
            }
        };
        self.stats.add_execute(t0.elapsed());
        Ok(out)
    }

    /// Batched evaluation: the weight preparation (`fq(w) * mask` +
    /// sparse index lists) is hoisted over the whole run instead of
    /// repeated per batch — the eval-loop analogue of the per-step
    /// hoisting in [`RefModel::train_step_fast`].
    fn eval_batches(
        &self,
        base_args: &[HostTensor],
        batches: &[(HostTensor, HostTensor)],
    ) -> Result<Vec<(f32, f32)>> {
        let base = self.split_base(base_args)?;
        let mut out = Vec::with_capacity(batches.len());
        match self.mode {
            KernelMode::Naive => {
                for (x, y) in batches {
                    let t0 = Instant::now();
                    let y = y.as_i32()?;
                    check_labels(x, y)?;
                    out.push(self.eval_step_naive(&base, x, y)?);
                    self.stats.add_execute(t0.elapsed());
                }
            }
            _ => {
                let mut ws = self.take_ws();
                let run = self.eval_batches_fast(&base, batches, &mut out, &mut ws);
                self.put_ws(ws);
                run?;
            }
        }
        Ok(out)
    }
}

/// Reject malformed manifests up front so the interpreter can index
/// params/masks/qcfg by layer descriptor — and slice weight buffers by
/// layer dims — without panicking.
fn validate_layer_indices(variant: &ModelVariant) -> Result<()> {
    let n_p = variant.n_params() as i64;
    let n_m = variant.n_masks() as i64;
    for l in &variant.layers {
        if !matches!(l.kind.as_str(), "dense" | "conv2d") {
            continue;
        }
        if l.param_w < 0 || l.param_w >= n_p || l.param_b < 0 || l.param_b >= n_p {
            return Err(Error::backend(format!(
                "layer {}: param indices ({}, {}) out of range [0, {n_p})",
                l.name, l.param_w, l.param_b
            )));
        }
        if l.mask_idx < 0 || l.mask_idx >= n_m || l.mask_idx as usize >= variant.qcfg_rows {
            return Err(Error::backend(format!(
                "layer {}: mask/qcfg row {} out of range ({} masks, {} qcfg rows)",
                l.name, l.mask_idx, n_m, variant.qcfg_rows
            )));
        }
        if l.kind == "conv2d" && l.kernel == 0 {
            return Err(Error::backend(format!(
                "conv2d layer {}: kernel size must be positive",
                l.name
            )));
        }
        // dims recorded on the layer must agree with the declared
        // param/mask shapes the interpreter slices by
        let w_shape = &variant.param_shapes[l.param_w as usize].1;
        let b_shape = &variant.param_shapes[l.param_b as usize].1;
        let m_shape = &variant.mask_shapes[l.mask_idx as usize].1;
        let want_w: Vec<usize> = if l.kind == "dense" {
            vec![l.in_dim, l.out_dim]
        } else {
            vec![l.kernel, l.kernel, l.in_dim, l.out_dim]
        };
        if w_shape.as_slice() != want_w.as_slice() {
            return Err(Error::backend(format!(
                "layer {}: weight shape {w_shape:?} does not match layer dims {want_w:?}",
                l.name
            )));
        }
        if b_shape.len() != 1 || b_shape[0] != l.out_dim {
            return Err(Error::backend(format!(
                "layer {}: bias shape {b_shape:?} does not match out_dim {}",
                l.name, l.out_dim
            )));
        }
        if m_shape != w_shape {
            return Err(Error::backend(format!(
                "layer {}: mask shape {m_shape:?} does not match weight shape {w_shape:?}",
                l.name
            )));
        }
    }
    Ok(())
}

/// The reference-interpreter backend: no artifacts, no native libraries.
pub struct RefBackend {
    stats: Arc<StatsCell>,
    mode: KernelMode,
}

impl RefBackend {
    /// The default backend: kernel mode from `METAML_INTERP` (fast
    /// unless overridden).
    pub fn new() -> Self {
        Self::with_mode(KernelMode::from_env())
    }

    pub fn with_mode(mode: KernelMode) -> Self {
        RefBackend { stats: Arc::new(StatsCell::new()), mode }
    }

    /// The original per-call-allocating implementation (test oracle and
    /// benchmark baseline).
    pub fn naive() -> Self {
        Self::with_mode(KernelMode::Naive)
    }

    /// The fast path with the compressed sparse path disabled (for
    /// measuring the sparse win in isolation).
    pub fn dense_only() -> Self {
        Self::with_mode(KernelMode::DenseOnly)
    }

    pub fn mode(&self) -> KernelMode {
        self.mode
    }
}

impl Default for RefBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for RefBackend {
    fn platform(&self) -> String {
        "reference-interpreter".to_string()
    }

    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Arc<dyn ModelExec>> {
        let t0 = Instant::now();
        let variant = manifest.get(tag)?.clone();
        if variant.layers.is_empty() {
            return Err(Error::backend(format!(
                "variant {tag:?} carries no layer descriptions; the reference \
                 interpreter executes from manifest layers"
            )));
        }
        validate_layer_indices(&variant)?;
        self.stats.add_compile(t0.elapsed());
        Ok(Arc::new(RefModel {
            variant,
            stats: self.stats.clone(),
            mode: self.mode,
            workspaces: Mutex::new(Vec::new()),
        }))
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ste_boundary() {
        // enabled <7,3>: representable magnitude bound 2^(3-1) = 4
        assert_eq!(ste(3.9, 7.0, 3.0), 1.0);
        assert_eq!(ste(4.0, 7.0, 3.0), 1.0);
        assert_eq!(ste(4.1, 7.0, 3.0), 0.0);
        assert_eq!(ste(-4.1, 7.0, 3.0), 0.0);
        assert_eq!(ste(100.0, 0.0, 0.0), 1.0);
    }

    #[test]
    fn backend_mode_constructors() {
        assert_eq!(RefBackend::naive().mode(), KernelMode::Naive);
        assert_eq!(RefBackend::dense_only().mode(), KernelMode::DenseOnly);
        assert_eq!(RefBackend::with_mode(KernelMode::Fast).mode(), KernelMode::Fast);
    }
}
