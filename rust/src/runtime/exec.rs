//! PJRT execution backend (`--features xla`): compiles and runs the AOT
//! HLO-text artifacts produced by `python/compile/aot.py`.
//!
//! One [`PjrtBackend`] per process (the PJRT CPU client is not Send/Sync
//! in the `xla` crate, so everything executes on the coordinator thread).
//! Compiled executables are cached by artifact file name — re-entering a
//! flow task never recompiles.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * artifacts are HLO *text* (`HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id proto incompatibility
//!   between jax >= 0.5 and xla_extension 0.5.1);
//! * all computations return a tuple (lowered with `return_tuple=True`).
//!
//! By default the `xla` dependency resolves to the in-tree `xla-stub`
//! crate, which type-checks this whole path offline but fails client
//! construction at runtime; point it at the real xla-rs crate to execute.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{ExecBackend, ModelExec, RuntimeStats};
use crate::runtime::manifest::{Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Owns the PJRT client and the compiled-executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: Rc<RefCell<RuntimeStats>>,
}

impl PjrtBackend {
    /// Create a CPU PJRT backend.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtBackend {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            stats: Rc::new(RefCell::new(RuntimeStats::default())),
        })
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&self, manifest: &Manifest, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = manifest.artifact_path(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::other("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

}

/// Shared execution path: marshal host tensors to borrowed literals,
/// execute, decompose the output tuple (computations are lowered with
/// `return_tuple=True`), unmarshal, account stats.
fn run_marshaled(
    exe: &xla::PjRtLoadedExecutable,
    args: &[HostTensor],
    stats: &Rc<RefCell<RuntimeStats>>,
) -> Result<Vec<HostTensor>> {
    let literals = args
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;
    let refs: Vec<&xla::Literal> = literals.iter().collect();
    let t0 = Instant::now();
    let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    {
        let mut stats = stats.borrow_mut();
        stats.executions += 1;
        stats.execute_secs += t0.elapsed().as_secs_f64();
    }
    parts.iter().map(HostTensor::from_literal).collect()
}

impl ExecBackend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Rc<dyn ModelExec>> {
        let variant = manifest.get(tag)?.clone();
        let train = self.load(manifest, &variant.train_artifact)?;
        let eval = self.load(manifest, &variant.eval_artifact)?;
        Ok(Rc::new(PjrtModel {
            variant,
            train,
            eval,
            stats: self.stats.clone(),
        }))
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

/// A (model, scale) variant bound to its compiled train/eval executables.
///
/// Marshaling note: every step converts the full argument list host →
/// literal and the outputs back.  The pre-backend-trait trainer kept
/// parameters in the literal domain across steps; that staging is
/// incompatible with a backend-agnostic step interface, so the PJRT
/// path pays one round-trip per step (the reference backend, which CI
/// exercises, never marshals at all).
pub struct PjrtModel {
    variant: ModelVariant,
    train: Rc<xla::PjRtLoadedExecutable>,
    eval: Rc<xla::PjRtLoadedExecutable>,
    stats: Rc<RefCell<RuntimeStats>>,
}

impl PjrtModel {
    fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        run_marshaled(exe, args, &self.stats)
    }
}

impl ModelExec for PjrtModel {
    fn variant(&self) -> &ModelVariant {
        &self.variant
    }

    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 4;
        if args.len() != expect {
            return Err(Error::other(format!(
                "train_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = self.execute(&self.train, args)?;
        let n = self.variant.n_params();
        if out.len() != n + 2 {
            return Err(Error::other(format!(
                "train_step: expected {} outputs, got {}",
                n + 2,
                out.len()
            )));
        }
        let mut out = out;
        let acc = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        Ok((out, loss, acc))
    }

    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 3;
        if args.len() != expect {
            return Err(Error::other(format!(
                "eval_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = self.execute(&self.eval, args)?;
        if out.len() != 2 {
            return Err(Error::other(format!(
                "eval_step: expected 2 outputs, got {}",
                out.len()
            )));
        }
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }
}
