//! PJRT execution backend (`--features xla`): compiles and runs the AOT
//! HLO-text artifacts produced by `python/compile/aot.py`.
//!
//! One [`PjrtBackend`] per process.  Compiled executables are cached by
//! artifact file name behind a `Mutex` — re-entering a flow task never
//! recompiles, and concurrent probe workers share the cache safely.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * artifacts are HLO *text* (`HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id proto incompatibility
//!   between jax >= 0.5 and xla_extension 0.5.1);
//! * all computations return a tuple (lowered with `return_tuple=True`).
//!
//! By default the `xla` dependency resolves to the in-tree `xla-stub`
//! crate, which type-checks this whole path offline but fails client
//! construction at runtime; point it at the real xla-rs crate to execute.
//!
//! Thread-safety note: [`crate::runtime::ExecBackend`] requires
//! `Send + Sync`, which the stub types satisfy.  The real xla-rs PJRT
//! client is not `Sync`; linking it requires wrapping the client in a
//! dispatch thread (or a `Send`-able fork of xla-rs) — the offline
//! `cargo check --features xla` contract only covers the stub.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{ExecBackend, ModelExec, RuntimeStats, StatsCell};
use crate::runtime::manifest::{Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Owns the PJRT client and the compiled-executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Arc<StatsCell>,
}

impl PjrtBackend {
    /// Create a CPU PJRT backend.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtBackend {
            client: xla::PjRtClient::cpu()?,
            cache: Mutex::new(HashMap::new()),
            stats: Arc::new(StatsCell::new()),
        })
    }

    /// Load + compile an HLO-text artifact (cached by file name).  The
    /// cache lock is held across compilation so two workers racing on
    /// the same artifact compile it once.
    pub fn load(&self, manifest: &Manifest, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(exe) = cache.get(file) {
            return Ok(exe.clone());
        }
        let path = manifest.artifact_path(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::other("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.stats.add_compile(t0.elapsed());
        cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }
}

/// Shared execution path: marshal host tensors to borrowed literals,
/// execute, decompose the output tuple (computations are lowered with
/// `return_tuple=True`), unmarshal, account stats.
fn run_marshaled(
    exe: &xla::PjRtLoadedExecutable,
    args: &[HostTensor],
    stats: &StatsCell,
) -> Result<Vec<HostTensor>> {
    let literals = args
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;
    let refs: Vec<&xla::Literal> = literals.iter().collect();
    let t0 = Instant::now();
    let result = exe.execute::<&xla::Literal>(&refs)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    stats.add_execute(t0.elapsed());
    parts.iter().map(HostTensor::from_literal).collect()
}

impl ExecBackend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Arc<dyn ModelExec>> {
        let variant = manifest.get(tag)?.clone();
        let train = self.load(manifest, &variant.train_artifact)?;
        let eval = self.load(manifest, &variant.eval_artifact)?;
        Ok(Arc::new(PjrtModel {
            variant,
            train,
            eval,
            stats: self.stats.clone(),
        }))
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }
}

/// A (model, scale) variant bound to its compiled train/eval executables.
///
/// Marshaling note: every step converts the full argument list host →
/// literal and the outputs back.  The pre-backend-trait trainer kept
/// parameters in the literal domain across steps; that staging is
/// incompatible with a backend-agnostic step interface, so the PJRT
/// path pays one round-trip per step (the reference backend, which CI
/// exercises, never marshals at all).
pub struct PjrtModel {
    variant: ModelVariant,
    train: Arc<xla::PjRtLoadedExecutable>,
    eval: Arc<xla::PjRtLoadedExecutable>,
    stats: Arc<StatsCell>,
}

impl PjrtModel {
    fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        run_marshaled(exe, args, &self.stats)
    }
}

impl ModelExec for PjrtModel {
    fn variant(&self) -> &ModelVariant {
        &self.variant
    }

    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 4;
        if args.len() != expect {
            return Err(Error::other(format!(
                "train_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = self.execute(&self.train, args)?;
        let n = self.variant.n_params();
        if out.len() != n + 2 {
            return Err(Error::other(format!(
                "train_step: expected {} outputs, got {}",
                n + 2,
                out.len()
            )));
        }
        let mut out = out;
        let acc = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        Ok((out, loss, acc))
    }

    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 3;
        if args.len() != expect {
            return Err(Error::other(format!(
                "eval_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = self.execute(&self.eval, args)?;
        if out.len() != 2 {
            return Err(Error::other(format!(
                "eval_step: expected 2 outputs, got {}",
                out.len()
            )));
        }
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }
}
