//! PJRT client wrapper + compiled-executable cache.
//!
//! One `Runtime` per process (the PJRT CPU client is not Send/Sync in the
//! `xla` crate, so everything executes on the coordinator thread).  Compiled
//! executables are cached by artifact file name — re-entering a flow task
//! never recompiles.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::manifest::{Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Execution statistics (perf accounting; see EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// Owns the PJRT client and the executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&self, manifest: &Manifest, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = manifest.artifact_path(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::other("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let literals = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let parts = self.execute_literals(exe, &literals)?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Literal-level execution (the hot path): no HostTensor marshaling.
    ///
    /// `fit()` keeps parameters as Literals across steps — outputs of one
    /// step feed the next directly, so per-step host<->literal copies are
    /// limited to the batch upload and the loss/acc scalars (§Perf L3).
    pub fn execute_literals(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // Computations are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.execute_secs += t0.elapsed().as_secs_f64();
        Ok(parts)
    }

    /// Borrowed-literal execution: constant operands are passed by
    /// reference (zero copies per step).
    pub fn execute_literals_ref(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.execute_secs += t0.elapsed().as_secs_f64();
        Ok(parts)
    }
}

/// A (model, scale) variant bound to its compiled train/eval executables.
pub struct ModelExecutable {
    pub variant: ModelVariant,
    train: Rc<xla::PjRtLoadedExecutable>,
    eval: Rc<xla::PjRtLoadedExecutable>,
}

impl ModelExecutable {
    /// The raw compiled train-step executable (hot-path literal API).
    pub fn train_exe(&self) -> &xla::PjRtLoadedExecutable {
        &self.train
    }

    /// The raw compiled eval executable (hot-path literal API).
    pub fn eval_exe(&self) -> &xla::PjRtLoadedExecutable {
        &self.eval
    }

    pub fn load(runtime: &Runtime, manifest: &Manifest, tag: &str) -> Result<Self> {
        let variant = manifest.get(tag)?.clone();
        let train = runtime.load(manifest, &variant.train_artifact)?;
        let eval = runtime.load(manifest, &variant.eval_artifact)?;
        Ok(ModelExecutable { variant, train, eval })
    }

    /// One SGD step. `args` = params ++ masks ++ [qcfg, x, y, lr].
    /// Returns (new_params, loss, acc).
    pub fn train_step(
        &self,
        runtime: &Runtime,
        args: &[HostTensor],
    ) -> Result<(Vec<HostTensor>, f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 4;
        if args.len() != expect {
            return Err(Error::other(format!(
                "train_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = runtime.execute(&self.train, args)?;
        let n = self.variant.n_params();
        if out.len() != n + 2 {
            return Err(Error::other(format!(
                "train_step: expected {} outputs, got {}",
                n + 2,
                out.len()
            )));
        }
        let mut out = out;
        let acc = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        Ok((out, loss, acc))
    }

    /// Evaluate one batch. `args` = params ++ masks ++ [qcfg, x, y].
    /// Returns (loss, acc).
    pub fn eval_step(&self, runtime: &Runtime, args: &[HostTensor]) -> Result<(f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 3;
        if args.len() != expect {
            return Err(Error::other(format!(
                "eval_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let out = runtime.execute(&self.eval, args)?;
        if out.len() != 2 {
            return Err(Error::other(format!(
                "eval_step: expected 2 outputs, got {}",
                out.len()
            )));
        }
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }
}
