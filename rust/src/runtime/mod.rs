//! L3 runtime: pluggable execution backends behind [`ExecBackend`].
//!
//! Design-flow tasks never talk to an execution substrate directly —
//! they hold a [`Runtime`] (a boxed backend) and [`ModelExecutable`]s
//! (manifest variants bound to that backend) and exchange
//! [`HostTensor`]s in the flat argument order recorded per variant by
//! `manifest.json` (params, masks, qcfg, batch, labels[, lr]).
//!
//! Backends:
//! * [`interp::RefBackend`] (default) — a pure-Rust reference
//!   interpreter executing the train/eval step semantics from the
//!   manifest's layer descriptions; zero native dependencies.
//! * [`exec::PjrtBackend`] (`--features xla`) — loads AOT artifacts
//!   (HLO text) produced by `python/compile/aot.py` and executes them
//!   via PJRT.  Python never runs on this path — the rust binary is
//!   self-contained once `make artifacts` has produced the directory.

pub mod backend;
#[cfg(feature = "xla")]
pub mod exec;
pub mod interp;
pub mod kernels;
pub mod manifest;
pub mod tensor;

pub use backend::{ExecBackend, ModelExec, ModelExecutable, Runtime, RuntimeStats};
#[cfg(feature = "xla")]
pub use exec::PjrtBackend;
pub use interp::{KernelMode, RefBackend};
pub use manifest::{LayerDesc, Manifest, ModelVariant};
pub use tensor::HostTensor;
