//! L3 runtime: load AOT artifacts (HLO text) and execute them via PJRT.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * artifacts are HLO *text* (`HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id proto incompatibility
//!   between jax >= 0.5 and xla_extension 0.5.1);
//! * `manifest.json` records, per (model, scale) variant, the exact flat
//!   argument order (params, masks, qcfg, batch, labels[, lr]) and the
//!   output arity (params' + loss + acc for train; loss + acc for eval);
//! * all computations return a tuple (lowered with `return_tuple=True`).
//!
//! Python never runs on this path — the rust binary is self-contained
//! once `make artifacts` has produced the directory.

pub mod exec;
pub mod manifest;
pub mod tensor;

pub use exec::{ModelExecutable, Runtime};
pub use manifest::{LayerDesc, Manifest, ModelVariant};
pub use tensor::HostTensor;
