//! AOT manifest: the contract between `python/compile/aot.py` and rust.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::{self, Value};

/// One layer of a model (feeds the HLS4ML λ-task's IR translation).
#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub kind: String, // dense | conv2d | maxpool2 | flatten | residual_*
    pub name: String,
    pub activation: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub kernel: usize,
    pub h: usize,
    pub w: usize,
    pub param_w: i64,
    pub param_b: i64,
    pub mask_idx: i64,
    pub macs: usize,
}

impl LayerDesc {
    pub fn is_weight(&self) -> bool {
        self.param_w >= 0
    }
}

/// One exported (model, scale) variant.
#[derive(Debug, Clone)]
pub struct ModelVariant {
    pub model: String,
    pub scale: f64,
    pub tag: String,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    /// (name, shape) in flat-argument order: w0, b0, w1, b1, ...
    pub param_shapes: Vec<(String, Vec<usize>)>,
    /// (aligned param index, shape) per weight tensor, in qcfg-row order.
    pub mask_shapes: Vec<(usize, Vec<usize>)>,
    pub qcfg_rows: usize,
    pub layers: Vec<LayerDesc>,
    pub train_artifact: String,
    pub eval_artifact: String,
}

impl ModelVariant {
    fn from_json(v: &Value) -> Result<Self> {
        let params = v
            .req_array("params")?
            .iter()
            .map(|p| Ok((p.req_str("name")?.to_string(), p.req_shape("shape")?)))
            .collect::<Result<Vec<_>>>()?;
        let masks = v
            .req_array("masks")?
            .iter()
            .map(|m| Ok((m.req_usize("param")?, m.req_shape("shape")?)))
            .collect::<Result<Vec<_>>>()?;
        let layers = v
            .req_array("layers")?
            .iter()
            .map(|l| {
                Ok(LayerDesc {
                    kind: l.req_str("kind")?.to_string(),
                    name: l.req_str("name")?.to_string(),
                    activation: l.req_str("activation")?.to_string(),
                    in_dim: l.req_usize("in_dim")?,
                    out_dim: l.req_usize("out_dim")?,
                    kernel: l.req_usize("kernel")?,
                    h: l.req_usize("h")?,
                    w: l.req_usize("w")?,
                    param_w: l.req_f64("param_w")? as i64,
                    param_b: l.req_f64("param_b")? as i64,
                    mask_idx: l.req_f64("mask_idx")? as i64,
                    macs: l.req_usize("macs")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifacts = v.req("artifacts")?;
        Ok(ModelVariant {
            model: v.req_str("model")?.to_string(),
            scale: v.req_f64("scale")?,
            tag: v.req_str("tag")?.to_string(),
            input_shape: v.req_shape("input_shape")?,
            n_classes: v.req_usize("n_classes")?,
            train_batch: v.req_usize("train_batch")?,
            eval_batch: v.req_usize("eval_batch")?,
            param_shapes: params,
            mask_shapes: masks,
            qcfg_rows: v.req_usize("qcfg_rows")?,
            layers,
            train_artifact: artifacts.req_str("train")?.to_string(),
            eval_artifact: artifacts.req_str("eval")?.to_string(),
        })
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes.len()
    }

    pub fn n_masks(&self) -> usize {
        self.mask_shapes.len()
    }

    /// Total trainable parameter count.
    pub fn total_weights(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Weight layers in qcfg-row order (dense/conv only).
    pub fn weight_layers(&self) -> Vec<&LayerDesc> {
        self.layers.iter().filter(|l| l.is_weight()).collect()
    }
}

/// The parsed artifacts/manifest.json plus its directory.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<ModelVariant>,
    by_tag: HashMap<String, usize>,
}

impl Manifest {
    /// Empty manifest (mock/test sessions without artifacts).
    pub fn empty() -> Self {
        Self::from_variants(Vec::new())
    }

    /// In-memory manifest from already-built variant descriptions — for
    /// tests and reference-backend sessions that never touch AOT
    /// artifact files.
    pub fn from_variants(variants: Vec<ModelVariant>) -> Self {
        let by_tag = variants
            .iter()
            .enumerate()
            .map(|(i, v)| (v.tag.clone(), i))
            .collect();
        Manifest { dir: PathBuf::from("."), variants, by_tag }
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = json::parse(&text)?;
        let variants = root
            .req_array("models")?
            .iter()
            .map(ModelVariant::from_json)
            .collect::<Result<Vec<_>>>()?;
        let by_tag = variants
            .iter()
            .enumerate()
            .map(|(i, v)| (v.tag.clone(), i))
            .collect();
        Ok(Manifest { dir, variants, by_tag })
    }

    pub fn get(&self, tag: &str) -> Result<&ModelVariant> {
        self.by_tag
            .get(tag)
            .map(|&i| &self.variants[i])
            .ok_or_else(|| Error::Manifest(format!("unknown variant {tag:?}")))
    }

    /// All scales exported for a model, descending (1.0 first).
    pub fn scales_for(&self, model: &str) -> Vec<f64> {
        let mut scales: Vec<f64> = self
            .variants
            .iter()
            .filter(|v| v.model == model)
            .map(|v| v.scale)
            .collect();
        scales.sort_by(|a, b| b.partial_cmp(a).unwrap());
        scales
    }

    /// Variant lookup by (model, scale).
    pub fn variant(&self, model: &str, scale: f64) -> Result<&ModelVariant> {
        self.variants
            .iter()
            .find(|v| v.model == model && (v.scale - scale).abs() < 1e-9)
            .ok_or_else(|| {
                Error::Manifest(format!("no variant {model}@{scale}"))
            })
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}
