//! Host-side tensors and Literal marshaling.

use crate::error::{Error, Result};

/// A host tensor: dense row-major f32 or i32 data + shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(Error::ShapeMismatch {
                expected: shape.to_vec(),
                got: vec![data.len()],
            });
        }
        Ok(HostTensor::F32 { shape: shape.to_vec(), data })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(Error::ShapeMismatch {
                expected: shape.to_vec(),
                got: vec![data.len()],
            });
        }
        Ok(HostTensor::I32 { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::other("tensor is not f32")),
        }
    }

    /// Shape and f32 data in one borrow — the interpreter hot path
    /// reads both per step and must not clone either.
    pub fn as_f32_shaped(&self) -> Result<(&[usize], &[f32])> {
        match self {
            HostTensor::F32 { shape, data } => Ok((shape, data)),
            _ => Err(Error::other("tensor is not f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::other("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::other("tensor is not i32")),
        }
    }

    /// Scalar extraction (for loss/acc outputs).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(Error::other(format!(
                "expected scalar, got {:?}",
                self.shape()
            )));
        }
        Ok(d[0])
    }

    /// Convert to an xla Literal with this tensor's shape.
    ///
    /// Zero-element tensors are rejected: `Literal::vec1` of an empty
    /// slice misbehaves in the native crate, and no computation in the
    /// AOT contract takes a zero-element operand.
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        if self.is_empty() {
            return Err(Error::other(format!(
                "cannot marshal zero-element tensor (shape {:?}) to a literal",
                self.shape()
            )));
        }
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a Literal back into a host tensor.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            ty => Err(Error::other(format!("unsupported literal type {ty:?}"))),
        }
    }

    /// Fraction of exact zeros (sparsity accounting for pruning).
    pub fn zero_fraction(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => {
                if data.is_empty() {
                    return 0.0;
                }
                data.iter().filter(|v| **v == 0.0).count() as f64 / data.len() as f64
            }
            HostTensor::I32 { data, .. } => {
                if data.is_empty() {
                    return 0.0;
                }
                data.iter().filter(|v| **v == 0).count() as f64 / data.len() as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::from_f32(&[2, 3], vec![1.0; 6]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(HostTensor::from_f32(&[2, 3], vec![1.0; 5]).is_err());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn zero_fraction() {
        let t = HostTensor::from_f32(&[4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.zero_fraction(), 0.5);
        assert_eq!(HostTensor::zeros(&[3]).zero_fraction(), 1.0);
        assert_eq!(HostTensor::ones(&[3]).zero_fraction(), 0.0);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(2.5);
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        assert!(HostTensor::ones(&[2]).scalar_f32().is_err());
    }

    #[test]
    fn zero_element_tensors_are_well_formed() {
        let t = HostTensor::from_f32(&[0], vec![]).unwrap();
        assert!(t.is_empty());
        assert_eq!(t.len(), 0);
        assert_eq!(t.zero_fraction(), 0.0);
        let t2 = HostTensor::from_f32(&[2, 0, 3], vec![]).unwrap();
        assert_eq!(t2.shape(), &[2, 0, 3]);
        assert!(t2.scalar_f32().is_err());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::from_i32(&[3], vec![7, -1, 0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    /// Regression: `to_literal` on a zero-element tensor must error, not
    /// panic (Literal::vec1 of an empty slice misbehaves natively).
    #[cfg(feature = "xla")]
    #[test]
    fn empty_tensor_to_literal_errors_cleanly() {
        let t = HostTensor::from_f32(&[0], vec![]).unwrap();
        assert!(t.to_literal().is_err());
        let t2 = HostTensor::from_i32(&[4, 0], vec![]).unwrap();
        assert!(t2.to_literal().is_err());
        // scalars (shape [], one element) still marshal
        assert!(HostTensor::scalar(1.5).to_literal().is_ok());
    }
}
