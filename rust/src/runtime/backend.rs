//! The pluggable execution-backend abstraction (MetaML-Pro-style
//! cross-stage decoupling): design-flow tasks describe *what* to run —
//! train/eval steps over [`HostTensor`]s in the flat argument convention —
//! and an [`ExecBackend`] decides *how*.
//!
//! Two backends exist:
//! * the default pure-Rust **reference interpreter**
//!   ([`crate::runtime::interp::RefBackend`]) executes the step semantics
//!   directly from the manifest's layer descriptions — zero native
//!   dependencies, runs anywhere;
//! * the **PJRT backend** (`--features xla`,
//!   [`crate::runtime::exec::PjrtBackend`]) compiles and executes the
//!   AOT HLO artifacts produced by `python/compile/aot.py`.
//!
//! Selection: [`Runtime::cpu`] honors `METAML_BACKEND`
//! (`reference` default, `xla` when compiled in).

use std::rc::Rc;

use crate::error::{Error, Result};
use crate::runtime::manifest::{Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Execution statistics (perf accounting; see EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// A (model, scale) variant bound to a backend, ready to step.
///
/// The flat argument convention (the contract with
/// `python/compile/train.py`):
/// * train: `params ++ masks ++ [qcfg, x, y, lr]` → `(params', loss, acc)`
/// * eval:  `params ++ masks ++ [qcfg, x, y]` → `(loss, acc)`
pub trait ModelExec {
    fn variant(&self) -> &ModelVariant;

    /// One SGD step; returns (new_params, loss, acc).
    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)>;

    /// Evaluate one batch; returns (loss, acc).
    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)>;
}

/// An execution substrate that can realize manifest variants.
pub trait ExecBackend {
    /// Human-readable platform name ("reference-interpreter", "cpu", …).
    fn platform(&self) -> String;

    /// Bind a manifest variant to an executable model.
    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Rc<dyn ModelExec>>;

    fn stats(&self) -> RuntimeStats;
}

#[cfg(feature = "xla")]
fn xla_cpu() -> Result<Runtime> {
    Runtime::pjrt_cpu()
}

#[cfg(not(feature = "xla"))]
fn xla_cpu() -> Result<Runtime> {
    Err(Error::backend(
        "METAML_BACKEND=xla requires building with `--features xla` \
         (and linking the real xla-rs crate)",
    ))
}

/// The process-wide execution runtime: a boxed [`ExecBackend`].
pub struct Runtime {
    backend: Box<dyn ExecBackend>,
}

impl Runtime {
    /// The pure-Rust reference-interpreter backend (always available).
    pub fn reference() -> Runtime {
        Runtime { backend: Box::new(crate::runtime::interp::RefBackend::new()) }
    }

    /// The PJRT CPU backend executing AOT HLO artifacts.
    #[cfg(feature = "xla")]
    pub fn pjrt_cpu() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(crate::runtime::exec::PjrtBackend::cpu()?) })
    }

    /// Wrap a custom backend.
    pub fn from_backend(backend: Box<dyn ExecBackend>) -> Runtime {
        Runtime { backend }
    }

    /// Default CPU runtime, selected by `METAML_BACKEND`:
    /// `reference` (default) or `xla` (requires `--features xla`).
    pub fn cpu() -> Result<Runtime> {
        match std::env::var("METAML_BACKEND").unwrap_or_default().as_str() {
            "" | "reference" | "ref" => Ok(Runtime::reference()),
            "xla" | "pjrt" => xla_cpu(),
            other => Err(Error::backend(format!(
                "unknown METAML_BACKEND {other:?} (expected \"reference\" or \"xla\")"
            ))),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    pub fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Rc<dyn ModelExec>> {
        self.backend.load_model(manifest, tag)
    }
}

/// A variant bound to its backend executable — the object tasks, the
/// trainer and the benches hold on to (cached per tag in
/// [`crate::flow::Session`]).
pub struct ModelExecutable {
    pub variant: ModelVariant,
    exec: Rc<dyn ModelExec>,
}

impl ModelExecutable {
    pub fn load(runtime: &Runtime, manifest: &Manifest, tag: &str) -> Result<Self> {
        let exec = runtime.load_model(manifest, tag)?;
        let variant = exec.variant().clone();
        Ok(ModelExecutable { variant, exec })
    }

    /// One SGD step. `args` = params ++ masks ++ [qcfg, x, y, lr].
    /// Returns (new_params, loss, acc).
    pub fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 4;
        if args.len() != expect {
            return Err(Error::other(format!(
                "train_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let (params, loss, acc) = self.exec.train_step(args)?;
        if params.len() != self.variant.n_params() {
            return Err(Error::other(format!(
                "train_step: expected {} output params, got {}",
                self.variant.n_params(),
                params.len()
            )));
        }
        Ok((params, loss, acc))
    }

    /// Evaluate one batch. `args` = params ++ masks ++ [qcfg, x, y].
    /// Returns (loss, acc).
    pub fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 3;
        if args.len() != expect {
            return Err(Error::other(format!(
                "eval_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        self.exec.eval_step(args)
    }
}
