//! The pluggable execution-backend abstraction (MetaML-Pro-style
//! cross-stage decoupling): design-flow tasks describe *what* to run —
//! train/eval steps over [`HostTensor`]s in the flat argument convention —
//! and an [`ExecBackend`] decides *how*.
//!
//! Two backends exist:
//! * the default pure-Rust **reference interpreter**
//!   ([`crate::runtime::interp::RefBackend`]) executes the step semantics
//!   directly from the manifest's layer descriptions — zero native
//!   dependencies, runs anywhere;
//! * the **PJRT backend** (`--features xla`,
//!   [`crate::runtime::exec::PjrtBackend`]) compiles and executes the
//!   AOT HLO artifacts produced by `python/compile/aot.py`.
//!
//! Selection: [`Runtime::cpu`] honors `METAML_BACKEND`
//! (`reference` default, `xla` when compiled in).
//!
//! ## Thread-safety contract
//!
//! The whole substrate is `Send + Sync`: [`ExecBackend`] and
//! [`ModelExec`] require both as supertraits, executables are shared via
//! [`Arc`], and stats accumulate through the lock-free [`StatsCell`].
//! This is what lets the DSE probe pool ([`crate::dse`]) evaluate
//! candidate models from scoped worker threads while sharing one
//! [`crate::flow::Session`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::manifest::{Manifest, ModelVariant};
use crate::runtime::tensor::HostTensor;

/// Execution statistics snapshot (perf accounting; see EXPERIMENTS.md
/// §Perf).  Produced by [`StatsCell::snapshot`]; plain host data.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// Lock-free stats accumulator shared between a backend and the models
/// it loads.  Counters are relaxed atomics: worker threads bump them
/// concurrently and only aggregate totals are ever read (durations
/// accumulate as integer nanoseconds so no CAS loop is needed).
#[derive(Debug, Default)]
pub struct StatsCell {
    compiles: AtomicUsize,
    compile_nanos: AtomicU64,
    executions: AtomicUsize,
    execute_nanos: AtomicU64,
}

impl StatsCell {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_compile(&self, elapsed: Duration) {
        self.compiles.fetch_add(1, Ordering::Relaxed);
        self.compile_nanos
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_execute(&self, elapsed: Duration) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.execute_nanos
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> RuntimeStats {
        RuntimeStats {
            compiles: self.compiles.load(Ordering::Relaxed),
            compile_secs: self.compile_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            executions: self.executions.load(Ordering::Relaxed),
            execute_secs: self.execute_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// A (model, scale) variant bound to a backend, ready to step.
///
/// The flat argument convention (the contract with
/// `python/compile/train.py`):
/// * train: `params ++ masks ++ [qcfg, x, y, lr]` → `(params', loss, acc)`
/// * eval:  `params ++ masks ++ [qcfg, x, y]` → `(loss, acc)`
///
/// `Send + Sync` is part of the contract: one loaded model is stepped
/// concurrently by DSE probe workers.  Implementations must not keep
/// per-call mutable state outside the argument list.
pub trait ModelExec: Send + Sync {
    fn variant(&self) -> &ModelVariant;

    /// One SGD step; returns (new_params, loss, acc).
    fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)>;

    /// Evaluate one batch; returns (loss, acc).
    fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)>;

    /// Evaluate many batches against one fixed model operand prefix
    /// (`base` = params ++ masks ++ [qcfg]); returns per-batch
    /// (loss, acc) in order.
    ///
    /// The default loops [`Self::eval_step`]; backends override it to
    /// hoist per-run work (the reference interpreter quantizes and
    /// sparsifies the weights once for the whole run).
    fn eval_batches(
        &self,
        base: &[HostTensor],
        batches: &[(HostTensor, HostTensor)],
    ) -> Result<Vec<(f32, f32)>> {
        let mut args: Vec<HostTensor> = base.to_vec();
        let mut out = Vec::with_capacity(batches.len());
        for (x, y) in batches {
            args.truncate(base.len());
            args.push(x.clone());
            args.push(y.clone());
            out.push(self.eval_step(&args)?);
        }
        Ok(out)
    }
}

/// An execution substrate that can realize manifest variants.
///
/// Backends are shared across probe-pool worker threads, so the trait
/// requires `Send + Sync`; interior caches must be lock-guarded.
pub trait ExecBackend: Send + Sync {
    /// Human-readable platform name ("reference-interpreter", "cpu", …).
    fn platform(&self) -> String;

    /// Bind a manifest variant to an executable model.
    fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Arc<dyn ModelExec>>;

    fn stats(&self) -> RuntimeStats;
}

#[cfg(feature = "xla")]
fn xla_cpu() -> Result<Runtime> {
    Runtime::pjrt_cpu()
}

#[cfg(not(feature = "xla"))]
fn xla_cpu() -> Result<Runtime> {
    Err(Error::backend(
        "METAML_BACKEND=xla requires building with `--features xla` \
         (and linking the real xla-rs crate)",
    ))
}

/// The process-wide execution runtime: a boxed [`ExecBackend`].
pub struct Runtime {
    backend: Box<dyn ExecBackend>,
}

impl Runtime {
    /// The pure-Rust reference-interpreter backend (always available).
    pub fn reference() -> Runtime {
        Runtime { backend: Box::new(crate::runtime::interp::RefBackend::new()) }
    }

    /// The PJRT CPU backend executing AOT HLO artifacts.
    #[cfg(feature = "xla")]
    pub fn pjrt_cpu() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(crate::runtime::exec::PjrtBackend::cpu()?) })
    }

    /// Wrap a custom backend.
    pub fn from_backend(backend: Box<dyn ExecBackend>) -> Runtime {
        Runtime { backend }
    }

    /// Default CPU runtime, selected by `METAML_BACKEND`:
    /// `reference` (default) or `xla` (requires `--features xla`).
    pub fn cpu() -> Result<Runtime> {
        match std::env::var("METAML_BACKEND").unwrap_or_default().as_str() {
            "" | "reference" | "ref" => Ok(Runtime::reference()),
            "xla" | "pjrt" => xla_cpu(),
            other => Err(Error::backend(format!(
                "unknown METAML_BACKEND {other:?} (expected \"reference\" or \"xla\")"
            ))),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }

    pub fn load_model(&self, manifest: &Manifest, tag: &str) -> Result<Arc<dyn ModelExec>> {
        self.backend.load_model(manifest, tag)
    }
}

/// A variant bound to its backend executable — the object tasks, the
/// trainer and the benches hold on to (cached per tag in
/// [`crate::flow::Session`], shared across probe workers via `Arc`).
pub struct ModelExecutable {
    pub variant: ModelVariant,
    exec: Arc<dyn ModelExec>,
}

impl ModelExecutable {
    pub fn load(runtime: &Runtime, manifest: &Manifest, tag: &str) -> Result<Self> {
        let exec = runtime.load_model(manifest, tag)?;
        let variant = exec.variant().clone();
        Ok(ModelExecutable { variant, exec })
    }

    /// One SGD step. `args` = params ++ masks ++ [qcfg, x, y, lr].
    /// Returns (new_params, loss, acc).
    pub fn train_step(&self, args: &[HostTensor]) -> Result<(Vec<HostTensor>, f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 4;
        if args.len() != expect {
            return Err(Error::other(format!(
                "train_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        let (params, loss, acc) = self.exec.train_step(args)?;
        if params.len() != self.variant.n_params() {
            return Err(Error::other(format!(
                "train_step: expected {} output params, got {}",
                self.variant.n_params(),
                params.len()
            )));
        }
        Ok((params, loss, acc))
    }

    /// Evaluate one batch. `args` = params ++ masks ++ [qcfg, x, y].
    /// Returns (loss, acc).
    pub fn eval_step(&self, args: &[HostTensor]) -> Result<(f32, f32)> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 3;
        if args.len() != expect {
            return Err(Error::other(format!(
                "eval_step: expected {expect} args, got {}",
                args.len()
            )));
        }
        self.exec.eval_step(args)
    }

    /// Evaluate many batches against one fixed model operand prefix.
    /// `base` = params ++ masks ++ [qcfg]; returns per-batch (loss, acc).
    pub fn eval_batches(
        &self,
        base: &[HostTensor],
        batches: &[(HostTensor, HostTensor)],
    ) -> Result<Vec<(f32, f32)>> {
        let expect = self.variant.n_params() + self.variant.n_masks() + 1;
        if base.len() != expect {
            return Err(Error::other(format!(
                "eval_batches: expected {expect} base args, got {}",
                base.len()
            )));
        }
        let out = self.exec.eval_batches(base, batches)?;
        if out.len() != batches.len() {
            return Err(Error::other(format!(
                "eval_batches: expected {} results, got {}",
                batches.len(),
                out.len()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compile-time guarantees the DSE pool depends on: the whole
    // execution stack can be shared across scoped worker threads.
    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn runtime_stack_is_send_sync() {
        assert_send_sync::<Runtime>();
        assert_send_sync::<ModelExecutable>();
        assert_send_sync::<StatsCell>();
        assert_send_sync::<Arc<dyn ModelExec>>();
        assert_send_sync::<Box<dyn ExecBackend>>();
    }

    #[test]
    fn stats_cell_accumulates_across_threads() {
        let cell = StatsCell::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        cell.add_execute(Duration::from_nanos(1_000));
                    }
                });
            }
        });
        let snap = cell.snapshot();
        assert_eq!(snap.executions, 400);
        assert!((snap.execute_secs - 400.0 * 1e-6).abs() < 1e-12);
        assert_eq!(snap.compiles, 0);
    }
}
