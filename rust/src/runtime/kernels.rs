//! Fast interpreter kernels: the hot math of the reference interpreter
//! ([`crate::runtime::interp`]), extracted so the step loops stay
//! readable and every search probe pays kernel cost, not allocator and
//! transcendental-call cost.
//!
//! Three layers, all bound by one **bit-identity contract**: for every
//! output element the f32 additions happen in exactly the ascending
//! reduction-index order the naive triple loops use, so the blocked,
//! sparse and row-panel-parallel paths produce bit-identical results to
//! [`naive`] (pinned by `rust/tests/kernel_parity.rs`) and the
//! backend-parity goldens never move:
//!
//! * **Blocked dense matmuls** — register-tiled microkernels over a
//!   packed-B panel cache ([`matmul`], [`matmul_bt`], [`matmul_at`]).
//!   Each output element accumulates over the full reduction dimension
//!   in its own register accumulator (ascending `t`, single store), so
//!   tiling changes memory traffic only, never arithmetic order.  No
//!   `mul_add`: fused multiply-add would change rounding.
//! * **Sparse-aware masked matmul** — [`MaskedWeight`] precomputes
//!   `fq(w) * mask` once per step (or once per eval run) and, when
//!   density falls below [`SPARSE_DENSITY_THRESHOLD`], a compressed
//!   row-major index list of the *exactly-zero* entries' complements.
//!   Skipping a `+= a * 0.0` term is bit-identical as long as `a` is
//!   finite (the accumulator can never sit at `-0.0`: it starts at
//!   `+0.0` and IEEE round-to-nearest addition only yields `-0.0` from
//!   two `-0.0` operands), so the sparse kernels scan the dense operand
//!   once and fall back to the dense path whenever it contains a
//!   non-finite value — `0 * NaN = NaN` propagation is preserved
//!   exactly.  NaN *weights* are no problem: `fq(NaN) * mask` is NaN,
//!   NaN ≠ 0.0, so the entry lands in the index list and propagates.
//! * **Deterministic intra-probe parallelism** — [`for_row_panels`]
//!   splits large matmuls into fixed [`ROW_PANEL`]-row output panels.
//!   The partition depends only on the output shape, never on the
//!   thread count; each panel is computed start-to-finish by the same
//!   sequential microkernel, so any worker assignment (including fully
//!   sequential) yields bit-identical results.  The thread budget comes
//!   from a scoped thread-local ([`with_intra_threads`]) that
//!   [`crate::dse::ProbePool`] sets when it has idle workers to lend a
//!   probe.
//!
//! [`Workspace`] is the per-step allocation sink: a free-list of f32 /
//! u32 / u8 buffers plus the packed-panel scratch, owned per
//! interpreter execution (checked out of a small pool on the model, so
//! concurrent probe workers never contend on one workspace).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::error::{Error, Result};
use crate::obs::trace;

// ---------------------------------------------------------------------------
// fake quantization (hoisted-constant form)
// ---------------------------------------------------------------------------

/// Round half to even (`jnp.round` semantics; `f32::round` rounds half
/// away from zero, which would diverge from the reference kernels).
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - x.signum()
    } else {
        r
    }
}

/// ap_fixed<W,I> fake quantization: round to nearest (ties to even) at
/// `2^(W-I)` resolution, saturate to the representable range.  `W <= 0`
/// disables quantization (identity).
pub fn fake_quant(v: f32, total_bits: f32, int_bits: f32) -> f32 {
    Quant::new(total_bits, int_bits).fq(v)
}

/// One layer's quantization constants, computed once per step instead
/// of once per element (`exp2` twice per weight was a measurable slice
/// of small-model probe time).  Arithmetic is identical to the
/// per-element form: the same `exp2` inputs produce the same constants.
#[derive(Debug, Clone, Copy)]
pub struct Quant {
    enabled: bool,
    scale: f32,
    hi: f32,
    lo: f32,
    /// STE saturation bound `2^(I-1)` (not the forward clamp bound).
    ste_hi: f32,
}

impl Quant {
    pub fn new(total_bits: f32, int_bits: f32) -> Quant {
        if total_bits <= 0.0 {
            return Quant { enabled: false, scale: 1.0, hi: 0.0, lo: 0.0, ste_hi: 0.0 };
        }
        let scale = (total_bits - int_bits).exp2();
        Quant {
            enabled: true,
            scale,
            hi: (int_bits - 1.0).exp2() - 1.0 / scale,
            lo: -(int_bits - 1.0).exp2(),
            ste_hi: (int_bits - 1.0).exp2(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// `fake_quant(v)` with the precomputed constants.
    #[inline]
    pub fn fq(&self, v: f32) -> f32 {
        if !self.enabled {
            return v;
        }
        (round_ties_even(v * self.scale) / self.scale).clamp(self.lo, self.hi)
    }

    /// Straight-through gradient mask: 1 inside the representable range
    /// (or when quantization is disabled), 0 where the forward saturated.
    #[inline]
    pub fn ste(&self, v: f32) -> f32 {
        if !self.enabled || v.abs() <= self.ste_hi {
            1.0
        } else {
            0.0
        }
    }

    /// `dst = fq(src)` elementwise into a caller-provided buffer.
    pub fn fq_into(&self, dst: &mut [f32], src: &[f32]) {
        debug_assert_eq!(dst.len(), src.len());
        if !self.enabled {
            dst.copy_from_slice(src);
            return;
        }
        for (d, &v) in dst.iter_mut().zip(src) {
            *d = self.fq(v);
        }
    }
}

// ---------------------------------------------------------------------------
// intra-probe parallelism: scoped thread budget + fixed row panels
// ---------------------------------------------------------------------------

/// Output rows per parallel panel.  Fixed: the work partition depends
/// only on the output shape, so results are identical for any budget.
pub const ROW_PANEL: usize = 64;

/// Default multiply-add floor below which a matmul never goes parallel
/// (scope-spawn overhead dominates tiny probes; they parallelize at the
/// probe-batch level instead).
pub const PAR_MIN_FLOPS_DEFAULT: usize = 1 << 22;

static PAR_MIN_FLOPS: AtomicUsize = AtomicUsize::new(PAR_MIN_FLOPS_DEFAULT);

/// Multiply-add count a matmul must exceed before the row-panel
/// parallel driver engages.  Tunable (tests drop it to 0 to exercise
/// the parallel path on tiny models); never affects results, only
/// whether idle workers are used.
pub fn par_min_flops() -> usize {
    PAR_MIN_FLOPS.load(Ordering::Relaxed)
}

/// Override the parallelism floor (process-wide; see [`par_min_flops`]).
pub fn set_par_min_flops(min_mul_adds: usize) {
    PAR_MIN_FLOPS.store(min_mul_adds, Ordering::Relaxed);
}

thread_local! {
    static INTRA_THREADS: Cell<usize> = const { Cell::new(1) };
}

/// Current intra-op thread budget for this thread (default 1).
pub fn intra_threads() -> usize {
    INTRA_THREADS.with(|c| c.get()).max(1)
}

/// Run `f` with the intra-op thread budget set to `n` (restored on
/// exit).  [`crate::dse::ProbePool`] wraps probe closures in this to
/// lend idle workers to a large probe; results are bit-identical for
/// every budget by the fixed-partition contract.
pub fn with_intra_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    INTRA_THREADS.with(|c| {
        let prev = c.get();
        c.set(n.max(1));
        let out = f();
        c.set(prev);
        out
    })
}

/// Split `out` (`m` rows of `row_width`) into [`ROW_PANEL`]-row panels
/// and run `body(first_row, panel)` over each.  Parallel across the
/// intra-thread budget when it is > 1 and the work (`mul_adds`) clears
/// the floor; panels are assigned round-robin but each is computed by
/// the same sequential `body`, so the schedule never affects results.
pub fn for_row_panels<F>(out: &mut [f32], m: usize, row_width: usize, mul_adds: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), m * row_width);
    let chunk = ROW_PANEL * row_width;
    let threads = intra_threads();
    let n_panels = if chunk == 0 { 0 } else { m.div_ceil(ROW_PANEL) };
    if threads <= 1 || n_panels <= 1 || mul_adds < par_min_flops() {
        for (p, panel) in out.chunks_mut(chunk.max(1)).enumerate() {
            body(p * ROW_PANEL, panel);
        }
        return;
    }
    let threads = threads.min(n_panels);
    let mut buckets: Vec<Vec<(usize, &mut [f32])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (p, panel) in out.chunks_mut(chunk).enumerate() {
        buckets[p % threads].push((p * ROW_PANEL, panel));
    }
    std::thread::scope(|scope| {
        let body = &body;
        for bucket in buckets {
            scope.spawn(move || {
                for (row0, panel) in bucket {
                    body(row0, panel);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// blocked dense matmuls
// ---------------------------------------------------------------------------

/// Packed-B panel width (f32 lanes per register tile column).
const NR: usize = 16;
/// Rows of A per microkernel tile.
const MR: usize = 4;

/// Pack `b[k, n]` into `ceil(n/NR)` column panels of `k * NR` each
/// (remainder lanes zero-padded; they feed accumulator lanes that are
/// never stored).  Reused across row panels, so packing cost is
/// `O(k*n)` per matmul regardless of `m` or the thread count.
fn pack_b(pack: &mut Vec<f32>, b: &[f32], k: usize, n: usize) {
    let panels = n.div_ceil(NR).max(1);
    pack.clear();
    pack.resize(panels * k * NR, 0.0);
    for jp in 0..panels {
        let j0 = jp * NR;
        let width = NR.min(n - j0);
        let base = jp * k * NR;
        for t in 0..k {
            let src = &b[t * n + j0..t * n + j0 + width];
            pack[base + t * NR..base + t * NR + width].copy_from_slice(src);
        }
    }
}

/// `out = a[m,k] @ b[k,n]` (row-major, f32): blocked, packed-B,
/// row-panel parallel.  Bit-identical to [`naive::mm`]: each output
/// element accumulates over the full reduction in its own register
/// lane (ascending `t` from a `+0.0` start, single store).
pub fn matmul(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pack: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    if m == 0 || n == 0 {
        return;
    }
    let mut span = trace::kernel_span("kernel.matmul");
    span.arg("m", m);
    span.arg("k", k);
    span.arg("n", n);
    pack_b(pack, b, k, n);
    let pack = &*pack;
    let panels = n.div_ceil(NR);
    for_row_panels(out, m, n, m * k * n, |row0, chunk| {
        let rows = chunk.len() / n;
        let mut i = 0;
        while i < rows {
            let tile = MR.min(rows - i);
            for jp in 0..panels {
                let j0 = jp * NR;
                let width = NR.min(n - j0);
                let panel = &pack[jp * k * NR..(jp + 1) * k * NR];
                let mut acc = [[0.0f32; NR]; MR];
                for t in 0..k {
                    let bp = &panel[t * NR..t * NR + NR];
                    for r in 0..tile {
                        let av = a[(row0 + i + r) * k + t];
                        let lane = &mut acc[r];
                        for j in 0..NR {
                            lane[j] += av * bp[j];
                        }
                    }
                }
                for r in 0..tile {
                    chunk[(i + r) * n + j0..(i + r) * n + j0 + width]
                        .copy_from_slice(&acc[r][..width]);
                }
            }
            i += tile;
        }
    });
}

/// `out = a[m,n] @ b[k,n]^T` → `[m,k]`: register-blocked dot products
/// (IRxJR tile of independent scalar accumulators, ascending inner
/// index).  Bit-identical to [`naive::mm_bt`].
pub fn matmul_bt(out: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(out.len(), m * k);
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    if m == 0 || k == 0 {
        return;
    }
    let mut span = trace::kernel_span("kernel.matmul_bt");
    span.arg("m", m);
    span.arg("n", n);
    span.arg("k", k);
    const JR: usize = 4;
    for_row_panels(out, m, k, m * n * k, |row0, chunk| {
        let rows = chunk.len() / k;
        for i in 0..rows {
            let arow = &a[(row0 + i) * n..(row0 + i + 1) * n];
            let orow = &mut chunk[i * k..(i + 1) * k];
            let mut j = 0;
            while j + JR <= k {
                let b0 = &b[j * n..(j + 1) * n];
                let b1 = &b[(j + 1) * n..(j + 2) * n];
                let b2 = &b[(j + 2) * n..(j + 3) * n];
                let b3 = &b[(j + 3) * n..(j + 4) * n];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for t in 0..n {
                    let av = arow[t];
                    s0 += av * b0[t];
                    s1 += av * b1[t];
                    s2 += av * b2[t];
                    s3 += av * b3[t];
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                j += JR;
            }
            while j < k {
                let brow = &b[j * n..(j + 1) * n];
                let mut acc = 0.0f32;
                for t in 0..n {
                    acc += arow[t] * brow[t];
                }
                orow[j] = acc;
                j += 1;
            }
        }
    });
}

/// `out = a[m,k]^T @ b[m,n]` → `[k,n]`: the gradient-weight matmul.
/// Same packed-B microkernel as [`matmul`], with the A operand read
/// column-wise (`a[t*k + i]`).  Bit-identical to [`naive::mm_at`].
pub fn matmul_at(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pack: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), k * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    if k == 0 || n == 0 {
        return;
    }
    let mut span = trace::kernel_span("kernel.matmul_at");
    span.arg("m", m);
    span.arg("k", k);
    span.arg("n", n);
    pack_b(pack, b, m, n);
    let pack = &*pack;
    let panels = n.div_ceil(NR);
    for_row_panels(out, k, n, m * k * n, |row0, chunk| {
        let rows = chunk.len() / n;
        let mut i = 0;
        while i < rows {
            let tile = MR.min(rows - i);
            for jp in 0..panels {
                let j0 = jp * NR;
                let width = NR.min(n - j0);
                let panel = &pack[jp * m * NR..(jp + 1) * m * NR];
                let mut acc = [[0.0f32; NR]; MR];
                for t in 0..m {
                    let bp = &panel[t * NR..t * NR + NR];
                    for r in 0..tile {
                        let av = a[t * k + row0 + i + r];
                        let lane = &mut acc[r];
                        for j in 0..NR {
                            lane[j] += av * bp[j];
                        }
                    }
                }
                for r in 0..tile {
                    chunk[(i + r) * n + j0..(i + r) * n + j0 + width]
                        .copy_from_slice(&acc[r][..width]);
                }
            }
            i += tile;
        }
    });
}

// ---------------------------------------------------------------------------
// sparse-aware masked weights
// ---------------------------------------------------------------------------

/// Sparsity threshold: the compressed path engages when the fraction of
/// nonzero `fq(w)*mask` entries drops below this (scalar gather/scatter
/// only beats the vectorized dense microkernel once most terms vanish).
pub const SPARSE_DENSITY_THRESHOLD: f32 = 0.25;

static SPARSE_MATMULS: AtomicU64 = AtomicU64::new(0);

/// Number of matmuls served by the compressed sparse path since process
/// start (bench/CI telemetry: proves the sparse path engages on a
/// pruned model).
pub fn sparse_matmul_count() -> u64 {
    SPARSE_MATMULS.load(Ordering::Relaxed)
}

/// Compressed row-major index list of the nonzero entries of a
/// `[k, n]` masked-quantized weight matrix.  Entries are *value*-zero
/// tested (`v == 0.0` catches both `±0.0`; NaN entries compare unequal
/// and stay in the list, preserving propagation).
#[derive(Debug, Default)]
pub struct SparseRows {
    /// `k + 1` prefix offsets into `col`/`val`.
    pub row_ptr: Vec<u32>,
    pub col: Vec<u32>,
    pub val: Vec<f32>,
}

/// `fq(w) * mask` evaluated once per step, plus the compressed index
/// list when density is below `threshold`.
#[derive(Debug, Default)]
pub struct MaskedWeight {
    /// Dense `[k, n]` quantized-masked weights.
    pub wq: Vec<f32>,
    pub sparse: Option<SparseRows>,
    /// Fraction of nonzero entries in `wq`.
    pub density: f32,
}

impl MaskedWeight {
    /// Build from raw weights + mask (both `[k, n]`).  Buffers come
    /// from `ws` and return to it via [`Workspace::recycle_weight`].
    pub fn build(
        ws: &mut Workspace,
        w: &[f32],
        mask: &[f32],
        q: &Quant,
        k: usize,
        n: usize,
        threshold: f32,
    ) -> MaskedWeight {
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(mask.len(), k * n);
        let mut wq = ws.buf_uninit(k * n);
        let mut nnz = 0usize;
        for ((d, &wv), &mv) in wq.iter_mut().zip(w).zip(mask) {
            let v = q.fq(wv) * mv;
            *d = v;
            nnz += usize::from(v != 0.0);
        }
        let density = if wq.is_empty() { 1.0 } else { nnz as f32 / wq.len() as f32 };
        let sparse = if density < threshold {
            let mut row_ptr = ws.buf_u32(k + 1);
            let mut col = ws.buf_u32(nnz);
            let mut val = ws.buf_uninit(nnz);
            row_ptr.clear();
            col.clear();
            val.clear();
            row_ptr.push(0);
            for t in 0..k {
                for (j, &v) in wq[t * n..(t + 1) * n].iter().enumerate() {
                    if v != 0.0 {
                        col.push(j as u32);
                        val.push(v);
                    }
                }
                row_ptr.push(col.len() as u32);
            }
            Some(SparseRows { row_ptr, col, val })
        } else {
            None
        };
        MaskedWeight { wq, sparse, density }
    }
}

/// True when every element is finite (no NaN/±inf).  The sparse kernels
/// require this of their *dense* operand: skipping an exact-zero weight
/// term is only bit-identical when the factor it would have multiplied
/// is finite.
pub fn all_finite(v: &[f32]) -> bool {
    v.iter().all(|x| x.is_finite())
}

/// Forward masked matmul `out = a[m,k] @ wq[k,n]`: compressed path when
/// the index list exists and `a` is finite, dense blocked otherwise.
pub fn matmul_masked(
    out: &mut [f32],
    a: &[f32],
    mw: &MaskedWeight,
    m: usize,
    k: usize,
    n: usize,
    pack: &mut Vec<f32>,
) {
    let mut span = trace::kernel_span("kernel.matmul_masked");
    span.arg("m", m);
    span.arg("k", k);
    span.arg("n", n);
    if let Some(sp) = &mw.sparse {
        if all_finite(a) {
            SPARSE_MATMULS.fetch_add(1, Ordering::Relaxed);
            let nnz = sp.val.len();
            for_row_panels(out, m, n, m * nnz, |row0, chunk| {
                chunk.fill(0.0);
                let rows = chunk.len() / n.max(1);
                for i in 0..rows {
                    let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
                    let orow = &mut chunk[i * n..(i + 1) * n];
                    for (t, &av) in arow.iter().enumerate() {
                        let (s, e) = (sp.row_ptr[t] as usize, sp.row_ptr[t + 1] as usize);
                        for (&c, &v) in sp.col[s..e].iter().zip(&sp.val[s..e]) {
                            orow[c as usize] += av * v;
                        }
                    }
                }
            });
            return;
        }
    }
    matmul(out, a, &mw.wq, m, k, n, pack);
}

/// Backward input-gradient matmul `out = g[m,n] @ wq[k,n]^T`:
/// compressed when possible (requires finite `g`), dense blocked
/// otherwise.  Row `j` of the index list holds exactly the ascending-`t`
/// nonzeros of `wq[j, :]`, so the per-element accumulation order
/// matches [`naive::mm_bt`] minus the exact-zero terms.
pub fn matmul_bt_masked(
    out: &mut [f32],
    g: &[f32],
    mw: &MaskedWeight,
    m: usize,
    n: usize,
    k: usize,
) {
    let mut span = trace::kernel_span("kernel.matmul_bt_masked");
    span.arg("m", m);
    span.arg("n", n);
    span.arg("k", k);
    if let Some(sp) = &mw.sparse {
        if all_finite(g) {
            SPARSE_MATMULS.fetch_add(1, Ordering::Relaxed);
            let nnz = sp.val.len();
            for_row_panels(out, m, k, m * nnz, |row0, chunk| {
                let rows = chunk.len() / k.max(1);
                for i in 0..rows {
                    let grow = &g[(row0 + i) * n..(row0 + i + 1) * n];
                    let orow = &mut chunk[i * k..(i + 1) * k];
                    for (j, o) in orow.iter_mut().enumerate() {
                        let (s, e) = (sp.row_ptr[j] as usize, sp.row_ptr[j + 1] as usize);
                        let mut acc = 0.0f32;
                        for (&c, &v) in sp.col[s..e].iter().zip(&sp.val[s..e]) {
                            acc += grow[c as usize] * v;
                        }
                        *o = acc;
                    }
                }
            });
            return;
        }
    }
    matmul_bt(out, g, &mw.wq, m, n, k);
}

// ---------------------------------------------------------------------------
// convolution layout transforms (guarded)
// ---------------------------------------------------------------------------

/// Validate a conv/pool NHWC shape + kernel size before the layout
/// transforms index into it.  Degenerate shapes (zero batch/spatial/
/// channel dims, kernel exceeding the padded input) return a clean
/// error instead of silently producing empty output or panicking on
/// index underflow in debug builds.
pub fn check_conv_shape(shape: [usize; 4], k: usize) -> Result<()> {
    let [b, h, w, c] = shape;
    if b == 0 || h == 0 || w == 0 || c == 0 {
        return Err(Error::backend(format!(
            "im2col: degenerate input shape {shape:?} (zero-sized dimension)"
        )));
    }
    if k == 0 {
        return Err(Error::backend("im2col: kernel size must be positive"));
    }
    if k > h || k > w {
        return Err(Error::backend(format!(
            "im2col: kernel {k} exceeds spatial dims of input {shape:?}"
        )));
    }
    Ok(())
}

/// Channel-major im2col: `[B,H,W,C]` → `[B*H*W, C*k*k]`, SAME padding,
/// stride 1, feature index `c*k*k + kh*k + kw` (matching
/// `conv_general_dilated_patches` + the HWIO→(C,k,k,Cout) weight
/// transpose in `layers.qconv2d`).  `cols` is fully overwritten.
pub fn im2col(cols: &mut [f32], x: &[f32], shape: [usize; 4], k: usize) -> Result<()> {
    check_conv_shape(shape, k)?;
    let [b, h, w, c] = shape;
    let pad = (k - 1) / 2;
    let fk = c * k * k;
    debug_assert_eq!(cols.len(), b * h * w * fk);
    cols.fill(0.0);
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let row = ((bi * h + i) * w + j) * fk;
                for kh in 0..k {
                    let y = i + kh;
                    if y < pad || y - pad >= h {
                        continue;
                    }
                    let y = y - pad;
                    for kw in 0..k {
                        let xx = j + kw;
                        if xx < pad || xx - pad >= w {
                            continue;
                        }
                        let xx = xx - pad;
                        let src = ((bi * h + y) * w + xx) * c;
                        for ci in 0..c {
                            cols[row + ci * k * k + kh * k + kw] = x[src + ci];
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Scatter-add transpose of [`im2col`]: `[B*H*W, C*k*k]` → `[B,H,W,C]`.
/// `dx` is zeroed then accumulated.
pub fn col2im(dx: &mut [f32], dcols: &[f32], shape: [usize; 4], k: usize) -> Result<()> {
    check_conv_shape(shape, k)?;
    let [b, h, w, c] = shape;
    let pad = (k - 1) / 2;
    let fk = c * k * k;
    debug_assert_eq!(dx.len(), b * h * w * c);
    dx.fill(0.0);
    for bi in 0..b {
        for i in 0..h {
            for j in 0..w {
                let row = ((bi * h + i) * w + j) * fk;
                for kh in 0..k {
                    let y = i + kh;
                    if y < pad || y - pad >= h {
                        continue;
                    }
                    let y = y - pad;
                    for kw in 0..k {
                        let xx = j + kw;
                        if xx < pad || xx - pad >= w {
                            continue;
                        }
                        let xx = xx - pad;
                        let dst = ((bi * h + y) * w + xx) * c;
                        for ci in 0..c {
                            dx[dst + ci] += dcols[row + ci * k * k + kh * k + kw];
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// HWIO `[k,k,Cin,Cout]` → matmul operand `[Cin*k*k, Cout]`.
pub fn hwio_to_2d(w2: &mut [f32], w4: &[f32], k: usize, cin: usize, cout: usize) {
    debug_assert_eq!(w2.len(), cin * k * k * cout);
    for kh in 0..k {
        for kw in 0..k {
            for c in 0..cin {
                let src = (((kh * k) + kw) * cin + c) * cout;
                let dst = (c * k * k + kh * k + kw) * cout;
                w2[dst..dst + cout].copy_from_slice(&w4[src..src + cout]);
            }
        }
    }
}

/// Inverse of [`hwio_to_2d`].
pub fn hwio_from_2d(w4: &mut [f32], w2: &[f32], k: usize, cin: usize, cout: usize) {
    debug_assert_eq!(w4.len(), k * k * cin * cout);
    for kh in 0..k {
        for kw in 0..k {
            for c in 0..cin {
                let dst = (((kh * k) + kw) * cin + c) * cout;
                let src = (c * k * k + kh * k + kw) * cout;
                w4[dst..dst + cout].copy_from_slice(&w2[src..src + cout]);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// reusable per-execution workspace
// ---------------------------------------------------------------------------

/// Per-execution scratch: free-lists of typed buffers plus the packed
/// matmul panel cache, so train/eval steps stop allocating `Vec`s per
/// call.  Checked out of a small pool on the model (`RefModel` keeps
/// one per concurrent probe worker), never shared across threads.
#[derive(Debug, Default)]
pub struct Workspace {
    free_f32: Vec<Vec<f32>>,
    free_u32: Vec<Vec<u32>>,
    free_u8: Vec<Vec<u8>>,
    /// Packed-B panel scratch for the blocked matmuls.
    pub pack: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zero-initialized f32 buffer of exactly `len` elements, reusing
    /// capacity from the free-list when available.
    pub fn buf(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free_f32.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Like [`Self::buf`] but the contents are unspecified (callers
    /// overwrite every element).  Still zero-fills — profiling showed
    /// the memset is noise next to the kernels — but the name records
    /// the contract so a future unsafe variant can skip it.
    pub fn buf_uninit(&mut self, len: usize) -> Vec<f32> {
        self.buf(len)
    }

    pub fn buf_u32(&mut self, len: usize) -> Vec<u32> {
        let mut v = self.free_u32.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    pub fn buf_u8(&mut self, len: usize) -> Vec<u8> {
        let mut v = self.free_u8.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    pub fn recycle(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.free_f32.push(v);
        }
    }

    pub fn recycle_u32(&mut self, v: Vec<u32>) {
        if v.capacity() > 0 {
            self.free_u32.push(v);
        }
    }

    pub fn recycle_u8(&mut self, v: Vec<u8>) {
        if v.capacity() > 0 {
            self.free_u8.push(v);
        }
    }

    /// Return a [`MaskedWeight`]'s buffers to the free-lists.
    pub fn recycle_weight(&mut self, mw: MaskedWeight) {
        self.recycle(mw.wq);
        if let Some(sp) = mw.sparse {
            self.recycle_u32(sp.row_ptr);
            self.recycle_u32(sp.col);
            self.recycle(sp.val);
        }
    }
}

// ---------------------------------------------------------------------------
// naive reference kernels (test oracle + "before" benchmark baseline)
// ---------------------------------------------------------------------------

/// The original triple-loop kernels, kept verbatim as (a) the bit-exact
/// oracle the blocked/sparse paths are tested against and (b) the
/// honest "before" baseline for the `interp` section of
/// `benches/perf_runtime.rs` (`RefBackend::naive()`).
pub mod naive {
    use super::fake_quant;

    /// `a[m,k] @ b[k,n]` (row-major, f32 accumulation).
    ///
    /// No zero-skipping: `0 * NaN = NaN` must propagate exactly as in
    /// the XLA matmul, so a diverged model reports NaN loss instead of
    /// a plausible finite value.
    pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for t in 0..k {
                let av = a[i * k + t];
                let brow = &b[t * n..(t + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// `a[m,n] @ b[k,n]^T` → `[m,k]`.
    pub fn mm_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * k];
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            for j in 0..k {
                let brow = &b[j * n..(j + 1) * n];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                out[i * k + j] = acc;
            }
        }
        out
    }

    /// `a[m,k]^T @ b[m,n]` → `[k,n]` (same NaN contract as [`mm`]).
    pub fn mm_at(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; k * n];
        for t in 0..m {
            let arow = &a[t * k..(t + 1) * k];
            let brow = &b[t * n..(t + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// `fq(w) * mask`, elementwise (per-element constant recomputation,
    /// as the original interpreter did).
    pub fn quantized_masked(w: &[f32], mask: &[f32], wb: f32, ib: f32) -> Vec<f32> {
        w.iter()
            .zip(mask)
            .map(|(&wv, &mv)| fake_quant(wv, wb, ib) * mv)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        let shapes = [(1, 1, 1), (2, 3, 2), (7, 5, 9), (33, 17, 65), (64, 64, 64), (65, 1, 16)];
        for &(m, k, n) in &shapes {
            let a = seq(m * k, |i| ((i * 37 % 23) as f32 - 11.0) / 7.0);
            let b = seq(k * n, |i| ((i * 29 % 19) as f32 - 9.0) / 5.0);
            let want = naive::mm(&a, &b, m, k, n);
            let mut got = vec![f32::NAN; m * n];
            matmul(&mut got, &a, &b, m, k, n, &mut Vec::new());
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "m={m} k={k} n={n}"
            );
        }
    }

    #[test]
    fn blocked_bt_at_match_naive_bitwise() {
        let (m, k, n) = (13, 21, 17);
        let a = seq(m * n, |i| ((i * 13 % 31) as f32 - 15.0) / 8.0);
        let b = seq(k * n, |i| ((i * 7 % 27) as f32 - 13.0) / 4.0);
        let want = naive::mm_bt(&a, &b, m, n, k);
        let mut got = vec![0.0f32; m * k];
        matmul_bt(&mut got, &a, &b, m, n, k);
        assert_eq!(got, want);

        let a2 = seq(m * k, |i| ((i * 11 % 29) as f32 - 14.0) / 16.0);
        let b2 = seq(m * n, |i| ((i * 5 % 33) as f32 - 16.0) / 32.0);
        let want2 = naive::mm_at(&a2, &b2, m, k, n);
        let mut got2 = vec![0.0f32; k * n];
        matmul_at(&mut got2, &a2, &b2, m, k, n, &mut Vec::new());
        assert_eq!(got2, want2);
    }

    #[test]
    fn quant_matches_scalar_fake_quant() {
        for &(wb, ib) in &[(0.0f32, 0.0f32), (6.0, 3.0), (7.0, 3.0), (12.0, 6.0)] {
            let q = Quant::new(wb, ib);
            for v in [-9.0f32, -0.51, -0.0, 0.0, 0.13, 1.0, 3.875, 7.9, f32::NAN] {
                let a = q.fq(v);
                let b = fake_quant(v, wb, ib);
                assert_eq!(a.to_bits(), b.to_bits(), "fq({v}) under <{wb},{ib}>");
            }
        }
    }

    #[test]
    fn masked_weight_sparse_engages_below_threshold() {
        let ws = &mut Workspace::new();
        let (k, n) = (8, 8);
        let w = seq(k * n, |i| i as f32 / 8.0);
        let mut mask = vec![0.0f32; k * n];
        mask[3] = 1.0;
        mask[40] = 1.0;
        let q = Quant::new(0.0, 0.0);
        let mw = MaskedWeight::build(ws, &w, &mask, &q, k, n, SPARSE_DENSITY_THRESHOLD);
        let sp = mw.sparse.as_ref().expect("density 2/64 engages sparse");
        assert_eq!(sp.val.len(), 2);
        assert_eq!(sp.row_ptr.len(), k + 1);
        // dense mask never engages
        let ones = vec![1.0f32; k * n];
        let dense = MaskedWeight::build(ws, &w, &ones, &q, k, n, SPARSE_DENSITY_THRESHOLD);
        assert!(dense.sparse.is_none());
        ws.recycle_weight(mw);
        ws.recycle_weight(dense);
    }

    #[test]
    fn row_panel_partition_is_thread_invariant() {
        let m = 3 * ROW_PANEL + 7;
        let n = 5;
        let run = |threads: usize| {
            with_intra_threads(threads, || {
                let mut out = vec![0.0f32; m * n];
                // engage the parallel driver regardless of size
                let saved = par_min_flops();
                set_par_min_flops(0);
                for_row_panels(&mut out, m, n, usize::MAX, |row0, chunk| {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = (row0 * n + i) as f32;
                    }
                });
                set_par_min_flops(saved);
                out
            })
        };
        let seq = run(1);
        for t in [2, 3, 8] {
            assert_eq!(run(t), seq, "threads={t}");
        }
    }

    #[test]
    fn im2col_guards_degenerate_shapes() {
        let mut cols = [0.0f32; 9];
        assert!(im2col(&mut cols, &[], [0, 2, 2, 1], 3).is_err());
        assert!(im2col(&mut cols, &[1.0; 4], [1, 2, 2, 1], 0).is_err());
        assert!(im2col(&mut cols, &[1.0; 4], [1, 2, 2, 1], 5).is_err());
        let mut dx = [0.0f32; 4];
        assert!(col2im(&mut dx, &[1.0; 36], [1, 0, 2, 1], 3).is_err());
    }

    #[test]
    fn workspace_recycles_capacity() {
        let mut ws = Workspace::new();
        let b = ws.buf(128);
        let p = b.as_ptr();
        ws.recycle(b);
        let b2 = ws.buf(64);
        assert_eq!(b2.as_ptr(), p, "free-list reuses the allocation");
        assert_eq!(b2.len(), 64);
        assert!(b2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn round_ties_even_matches_jnp_round() {
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.5), 4.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        assert_eq!(round_ties_even(-3.5), -4.0);
        assert_eq!(round_ties_even(2.4), 2.0);
        assert_eq!(round_ties_even(2.6), 3.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(0.0), 0.0);
    }

    #[test]
    fn fake_quant_disabled_is_identity() {
        for v in [-7.3f32, -0.1, 0.0, 0.49, 123.4] {
            assert_eq!(fake_quant(v, 0.0, 0.0), v);
        }
    }

    #[test]
    fn fake_quant_rounds_and_saturates() {
        // ap_fixed<6,3>: scale 8, range [-4, 3.875]
        assert_eq!(fake_quant(7.9, 6.0, 3.0), 3.875);
        assert_eq!(fake_quant(-9.0, 6.0, 3.0), -4.0);
        assert_eq!(fake_quant(0.13, 6.0, 3.0), 0.125);
        assert_eq!(fake_quant(1.0, 6.0, 3.0), 1.0);
    }

    #[test]
    fn matmul_variants_agree() {
        // a: 2x3, b: 3x2
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = naive::mm(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
        // b^T is 2x3; mm_bt(a2x3 @ (bt)^T) must equal mm with b
        let bt = [7.0f32, 9.0, 11.0, 8.0, 10.0, 12.0];
        assert_eq!(naive::mm_bt(&a, &bt, 2, 3, 2), c);
        // a^T path: (a^T)^T @ b
        let at = [1.0f32, 4.0, 2.0, 5.0, 3.0, 6.0];
        assert_eq!(naive::mm_at(&at, &b, 3, 2, 2), c);
    }

    #[test]
    fn im2col_col2im_roundtrip_shapes() {
        // 1x4x4x1 input, k=3: each pixel sees its 3x3 SAME neighborhood
        let x: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let mut cols = vec![f32::NAN; 16 * 9];
        im2col(&mut cols, &x, [1, 4, 4, 1], 3).unwrap();
        // center of patch (kh=1, kw=1) is the pixel itself
        for (p, &v) in x.iter().enumerate() {
            assert_eq!(cols[p * 9 + 4], v);
        }
        // col2im of all-ones gradient counts each pixel's patch
        // memberships: 4 at corners, 6 on edges, 9 in the interior
        let mut dx = vec![f32::NAN; 16];
        col2im(&mut dx, &[1.0f32; 16 * 9], [1, 4, 4, 1], 3).unwrap();
        #[rustfmt::skip]
        let want = [
            4.0, 6.0, 6.0, 4.0,
            6.0, 9.0, 9.0, 6.0,
            6.0, 9.0, 9.0, 6.0,
            4.0, 6.0, 6.0, 4.0,
        ];
        assert_eq!(dx, want);
    }

    #[test]
    fn hwio_transpose_roundtrip() {
        let (k, cin, cout) = (3, 2, 4);
        let w4: Vec<f32> = (0..k * k * cin * cout).map(|i| i as f32).collect();
        let mut w2 = vec![0.0f32; w4.len()];
        hwio_to_2d(&mut w2, &w4, k, cin, cout);
        let mut back = vec![0.0f32; w4.len()];
        hwio_from_2d(&mut back, &w2, k, cin, cout);
        assert_eq!(back, w4);
    }
}
