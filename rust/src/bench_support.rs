//! Shared helpers for the bench harness (benches/*.rs).
//!
//! Benches are `harness = false` binaries (criterion is not in the
//! offline crate set); each regenerates one paper table/figure, printing
//! the same rows/series the paper reports and writing CSVs under
//! `bench_out/`.

use std::sync::Arc;

use crate::data::Dataset;
use crate::error::Result;
use crate::flow::Session;
use crate::model::ModelState;
use crate::runtime::{LayerDesc, Manifest, ModelExecutable, ModelVariant};
use crate::train::{TrainConfig, Trainer};

/// Artifacts dir (env-overridable, matching the CLI).
pub fn artifacts_dir() -> String {
    std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Output dir for bench CSVs.
pub fn bench_out() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("METAML_BENCH_OUT").unwrap_or_else(|_| "bench_out".into()),
    )
}

/// Fast mode trims epochs for smoke runs (METAML_FAST=1).
pub fn fast_mode() -> bool {
    std::env::var("METAML_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Which models a bench should cover (METAML_BENCH_MODELS=jet_dnn,...).
pub fn bench_models(default: &[&str]) -> Vec<String> {
    match std::env::var("METAML_BENCH_MODELS") {
        Ok(s) if !s.is_empty() => s.split(',').map(str::to_string).collect(),
        _ => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Dense-layer descriptor for hand-built manifest variants (benches and
/// tests that run the reference interpreter without artifacts).
/// Convention: `param_b = param_w + 1`, `macs = in_dim * out_dim`.
pub fn dense_layer(name: &str, activation: &str, in_dim: usize, out_dim: usize, param_w: i64, mask_idx: i64) -> LayerDesc {
    LayerDesc {
        kind: "dense".into(),
        name: name.into(),
        activation: activation.into(),
        in_dim,
        out_dim,
        kernel: 0,
        h: 0,
        w: 0,
        param_w,
        param_b: param_w + 1,
        mask_idx,
        macs: in_dim * out_dim,
    }
}

/// An MLP-chain variant (`dims[0] → … → dims.last()`) for a model
/// family, tagged `"{model}_s{scale*1000:04}"` like the AOT exporter.
pub fn mlp_chain_variant(model: &str, scale: f64, dims: &[usize]) -> ModelVariant {
    let n_layers = dims.len() - 1;
    let mut param_shapes = Vec::new();
    let mut mask_shapes = Vec::new();
    let mut layers = Vec::new();
    for l in 0..n_layers {
        let (d_in, d_out) = (dims[l], dims[l + 1]);
        let param_w = (2 * l) as i64;
        param_shapes.push((format!("w{l}"), vec![d_in, d_out]));
        param_shapes.push((format!("b{l}"), vec![d_out]));
        mask_shapes.push((2 * l, vec![d_in, d_out]));
        let activation = if l == n_layers - 1 { "linear" } else { "relu" };
        layers.push(dense_layer(
            &format!("fc{}", l + 1),
            activation,
            d_in,
            d_out,
            param_w,
            l as i64,
        ));
    }
    ModelVariant {
        model: model.into(),
        scale,
        tag: format!("{model}_s{:04}", (scale * 1000.0).round() as usize),
        input_shape: vec![dims[0]],
        n_classes: *dims.last().unwrap(),
        train_batch: 64,
        eval_batch: 256,
        param_shapes,
        mask_shapes,
        qcfg_rows: n_layers,
        layers,
        train_artifact: "unused".into(),
        eval_artifact: "unused".into(),
    }
}

/// Hidden layer widths of the jet MLP at a scale (floor 2 units).
fn jet_dims(dims: &[usize], scale: f64) -> Vec<usize> {
    let last = dims.len() - 1;
    dims.iter()
        .enumerate()
        .map(|(i, &d)| {
            if i == 0 || i == last {
                d
            } else {
                ((d as f64 * scale).round() as usize).max(2)
            }
        })
        .collect()
}

/// In-memory manifest describing the paper's jet-tagging MLP
/// (16 → 64 → 32 → 32 → 5, the hls4ml benchmark architecture) for the
/// reference interpreter.  Lets benches exercise the real `jet_dnn`
/// probe hot path on machines where `make artifacts` has not run.
pub fn synthetic_jet_manifest() -> Manifest {
    synthetic_jet_manifest_scales(&[1.0])
}

/// Jet manifest with a scale grid (hidden widths scaled per variant)
/// so SCALING has something to walk without AOT artifacts — used by the
/// `metaml explore --synthetic` path and the flow-control tests.
pub fn synthetic_jet_manifest_scales(scales: &[f64]) -> Manifest {
    let dims = [16usize, 64, 32, 32, 5];
    Manifest::from_variants(
        scales
            .iter()
            .map(|&s| mlp_chain_variant("jet_dnn", s, &jet_dims(&dims, s)))
            .collect(),
    )
}

/// A shrunken jet-style family ("jet_mini", 16 → 16 → 8 → 5) with a
/// scale grid: the same flow semantics as `jet_dnn` at a fraction of
/// the FLOPs, so flow-control and explorer tests stay fast in debug
/// builds.
pub fn synthetic_jet_mini_manifest() -> Manifest {
    let dims = [16usize, 16, 8, 5];
    Manifest::from_variants(
        [1.0, 0.75, 0.5]
            .iter()
            .map(|&s| mlp_chain_variant("jet_mini", s, &jet_dims(&dims, s)))
            .collect(),
    )
}

/// Train a fresh base model for a (model, scale) variant; returns the
/// state + the bound executable + dataset for further probing.
pub fn trained_base(
    session: &Session,
    model: &str,
    scale: f64,
    seed: u64,
) -> Result<(ModelState, Arc<ModelExecutable>, Arc<Dataset>)> {
    let variant = session.manifest.variant(model, scale)?;
    let exec = session.executable(&variant.tag)?;
    let data = session.dataset(model)?;
    let mut cfg = TrainConfig::for_model(model);
    if fast_mode() {
        cfg.epochs = cfg.epochs.div_ceil(2);
    }
    cfg.seed = seed;
    let mut state = ModelState::init(variant, seed);
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    trainer.fit(&mut state, &cfg)?;
    Ok((state, exec, data))
}
