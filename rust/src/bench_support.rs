//! Shared helpers for the bench harness (benches/*.rs).
//!
//! Benches are `harness = false` binaries (criterion is not in the
//! offline crate set); each regenerates one paper table/figure, printing
//! the same rows/series the paper reports and writing CSVs under
//! `bench_out/`.

use crate::data::Dataset;
use crate::error::Result;
use crate::flow::Session;
use crate::model::ModelState;
use crate::runtime::ModelExecutable;
use crate::train::{TrainConfig, Trainer};

/// Artifacts dir (env-overridable, matching the CLI).
pub fn artifacts_dir() -> String {
    std::env::var("METAML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Output dir for bench CSVs.
pub fn bench_out() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("METAML_BENCH_OUT").unwrap_or_else(|_| "bench_out".into()),
    )
}

/// Fast mode trims epochs for smoke runs (METAML_FAST=1).
pub fn fast_mode() -> bool {
    std::env::var("METAML_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Which models a bench should cover (METAML_BENCH_MODELS=jet_dnn,...).
pub fn bench_models(default: &[&str]) -> Vec<String> {
    match std::env::var("METAML_BENCH_MODELS") {
        Ok(s) if !s.is_empty() => s.split(',').map(str::to_string).collect(),
        _ => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Train a fresh base model for a (model, scale) variant; returns the
/// state + the bound executable + dataset for further probing.
pub fn trained_base<'a>(
    session: &'a Session,
    model: &str,
    scale: f64,
    seed: u64,
) -> Result<(ModelState, std::rc::Rc<ModelExecutable>, std::rc::Rc<Dataset>)> {
    let variant = session.manifest.variant(model, scale)?;
    let exec = session.executable(&variant.tag)?;
    let data = session.dataset(model)?;
    let mut cfg = TrainConfig::for_model(model);
    if fast_mode() {
        cfg.epochs = cfg.epochs.div_ceil(2);
    }
    cfg.seed = seed;
    let mut state = ModelState::init(variant, seed);
    let trainer = Trainer::new(&session.runtime, &exec, &data);
    trainer.fit(&mut state, &cfg)?;
    Ok((state, exec, data))
}
