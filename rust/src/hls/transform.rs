//! Artisan-style source-to-source transformation passes over the HLS IR.
//!
//! The paper implements QUANTIZATION "using C++ source-to-source
//! transformations via the Artisan framework" — a meta-programming engine
//! that pattern-matches code and rewrites it.  Our equivalent operates on
//! the typed IR (the codegen emits the rewritten C++ afterwards): each
//! pass selects layers by predicate and rewrites their attributes.

use crate::error::Result;
use crate::hls::ir::HlsModel;
use crate::model::state::Precision;

/// A rewrite pass over the HLS model.
pub trait HlsTransform {
    fn name(&self) -> &str;
    fn apply(&self, model: &mut HlsModel) -> Result<usize>;
}

/// Ordered pass pipeline (mirrors Artisan's strategy scripts).
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn HlsTransform>>,
}

impl PassManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(mut self, pass: impl HlsTransform + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    /// Run all passes; returns (pass name, rewrite count) per pass.
    pub fn run(&self, model: &mut HlsModel) -> Result<Vec<(String, usize)>> {
        let mut log = Vec::new();
        for pass in &self.passes {
            let n = pass.apply(model)?;
            log.push((pass.name().to_string(), n));
        }
        Ok(log)
    }
}

/// Rewrite the `ap_fixed<W,I>` typedef of selected layers.
pub struct SetPrecision {
    /// Layer-name predicate; `None` = all compute layers.
    pub layer: Option<String>,
    pub precision: Precision,
}

impl SetPrecision {
    pub fn all(precision: Precision) -> Self {
        SetPrecision { layer: None, precision }
    }

    pub fn layer(name: impl Into<String>, precision: Precision) -> Self {
        SetPrecision { layer: Some(name.into()), precision }
    }
}

impl HlsTransform for SetPrecision {
    fn name(&self) -> &str {
        "set-precision"
    }

    fn apply(&self, model: &mut HlsModel) -> Result<usize> {
        let mut n = 0;
        for l in model.layers.iter_mut().filter(|l| l.is_compute()) {
            if self.layer.as_deref().map_or(true, |want| want == l.name) {
                l.precision = self.precision;
                n += 1;
            }
        }
        Ok(n)
    }
}

/// Re-derive nnz from a sparsity observation (constant-fold zero weights,
/// what Vivado HLS does to literal zeros in fully-unrolled MAC arrays).
pub struct FoldZeroWeights {
    /// (layer name, nnz) observations from the DNN state.
    pub nnz_by_layer: Vec<(String, usize)>,
}

impl HlsTransform for FoldZeroWeights {
    fn name(&self) -> &str {
        "fold-zero-weights"
    }

    fn apply(&self, model: &mut HlsModel) -> Result<usize> {
        let mut n = 0;
        for (name, nnz) in &self.nnz_by_layer {
            if let Some(l) = model.layers.iter_mut().find(|l| &l.name == name) {
                l.nnz = (*nnz).min(l.total_weights);
                n += 1;
            }
        }
        Ok(n)
    }
}

/// Set the reuse factor (time-multiplexing) of all compute layers.
/// The requested factor snaps per layer onto the legality grid (the
/// largest divisor of the layer's fan-in that is <= the request, >= 1)
/// — hls4ml's "closest valid reuse factor" behaviour.
pub struct SetReuseFactor(pub usize);

impl HlsTransform for SetReuseFactor {
    fn name(&self) -> &str {
        "set-reuse-factor"
    }

    fn apply(&self, model: &mut HlsModel) -> Result<usize> {
        let mut n = 0;
        for l in model.layers.iter_mut().filter(|l| l.is_compute()) {
            l.reuse_factor = l.snap_reuse_factor(self.0);
            n += 1;
        }
        Ok(n)
    }
}

/// Set one layer's reuse factor (snapped to its legality grid) — the
/// per-layer rewrite the REUSE_SEARCH O-task applies to its winner.
pub struct SetLayerReuse {
    pub layer: String,
    pub reuse_factor: usize,
}

impl HlsTransform for SetLayerReuse {
    fn name(&self) -> &str {
        "set-layer-reuse"
    }

    fn apply(&self, model: &mut HlsModel) -> Result<usize> {
        let mut n = 0;
        for l in model.layers.iter_mut().filter(|l| l.is_compute()) {
            if l.name == self.layer {
                l.reuse_factor = l.snap_reuse_factor(self.reuse_factor);
                n += 1;
            }
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ir::tests::toy_model;

    #[test]
    fn set_precision_all_and_single() {
        let mut m = toy_model();
        let n = SetPrecision::all(Precision::new(8, 3)).apply(&mut m).unwrap();
        assert_eq!(n, 2);
        assert!(m.layers.iter().all(|l| l.precision == Precision::new(8, 3)));

        let n = SetPrecision::layer("fc1", Precision::new(6, 2))
            .apply(&mut m)
            .unwrap();
        assert_eq!(n, 1);
        assert_eq!(m.layers[0].precision, Precision::new(6, 2));
        assert_eq!(m.layers[1].precision, Precision::new(8, 3));
    }

    #[test]
    fn fold_zero_weights_clamps() {
        let mut m = toy_model();
        let pass = FoldZeroWeights {
            nnz_by_layer: vec![("fc1".into(), 100), ("out".into(), 9999)],
        };
        assert_eq!(pass.apply(&mut m).unwrap(), 2);
        assert_eq!(m.layers[0].nnz, 100);
        assert_eq!(m.layers[1].nnz, 320); // clamped to total
    }

    #[test]
    fn pass_manager_runs_in_order() {
        let mut m = toy_model();
        let log = PassManager::new()
            .add(SetPrecision::all(Precision::new(10, 4)))
            .add(SetReuseFactor(4))
            .run(&mut m)
            .unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], ("set-precision".to_string(), 2));
        assert!(m.layers.iter().all(|l| l.reuse_factor == 4));
    }

    #[test]
    fn set_reuse_snaps_to_legal_divisors() {
        let mut m = toy_model(); // fan-ins 16 and 64
        SetReuseFactor(6).apply(&mut m).unwrap();
        assert_eq!(m.layers[0].reuse_factor, 4); // largest divisor of 16 <= 6
        assert_eq!(m.layers[1].reuse_factor, 4); // largest divisor of 64 <= 6
        SetReuseFactor(0).apply(&mut m).unwrap();
        assert!(m.layers.iter().all(|l| l.reuse_factor == 1));
        assert!(m.validate().is_ok());
    }

    #[test]
    fn set_layer_reuse_targets_one_layer() {
        let mut m = toy_model();
        let n = SetLayerReuse { layer: "out".into(), reuse_factor: 64 }
            .apply(&mut m)
            .unwrap();
        assert_eq!(n, 1);
        assert_eq!(m.layers[0].reuse_factor, 1);
        assert_eq!(m.layers[1].reuse_factor, 64);
        assert!(m.validate().is_ok());
    }
}
