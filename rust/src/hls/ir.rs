//! Layer-wise IR of an HLS C++ design (the hls4ml project abstraction).

use crate::error::{Error, Result};
use crate::model::state::Precision;
use crate::model::ModelState;
use crate::runtime::ModelVariant;

/// hls4ml IOType (io_parallel = fully unrolled, the paper's low-latency
/// LHC-trigger configuration; io_stream = dataflow FIFOs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoType {
    Parallel,
    Stream,
}

impl std::fmt::Display for IoType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoType::Parallel => write!(f, "io_parallel"),
            IoType::Stream => write!(f, "io_stream"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HlsLayerKind {
    Dense,
    Conv2D,
    MaxPool2,
    Flatten,
    ResidualAdd,
}

/// One layer instance of the HLS design.
#[derive(Debug, Clone)]
pub struct HlsLayer {
    pub name: String,
    pub kind: HlsLayerKind,
    pub n_in: usize,
    pub n_out: usize,
    pub kernel: usize,
    pub h: usize,
    pub w: usize,
    pub activation: String,
    /// ap_fixed<W,I> datapath precision of this layer.
    pub precision: Precision,
    /// hls4ml reuse factor (1 = fully unrolled, the paper's setting).
    pub reuse_factor: usize,
    /// Total weights before pruning.
    pub total_weights: usize,
    /// Non-zero weights after pruning (zero weights are folded away by
    /// HLS constant propagation in fully-unrolled designs).
    pub nnz: usize,
    /// Multiply-accumulates per inference (dense basis).
    pub macs: usize,
}

impl HlsLayer {
    pub fn is_compute(&self) -> bool {
        matches!(self.kind, HlsLayerKind::Dense | HlsLayerKind::Conv2D)
    }

    /// MAC fan-in of the layer: inputs accumulated per output element
    /// (kernel²·channels for conv, n_in for dense).
    pub fn fan_in(&self) -> usize {
        match self.kind {
            HlsLayerKind::Conv2D => (self.kernel * self.kernel * self.n_in).max(1),
            _ => self.n_in.max(1),
        }
    }

    /// Is `rf` a legal reuse factor for this layer?  hls4ml's rule:
    /// the MAC loop is split into `rf` equal passes, so `rf` must
    /// divide the fan-in exactly (RF = 1 is always legal).
    pub fn reuse_legal(&self, rf: usize) -> bool {
        rf >= 1 && self.fan_in() % rf == 0
    }

    /// All legal reuse factors, ascending (the divisors of the fan-in).
    pub fn legal_reuse_factors(&self) -> Vec<usize> {
        let fan = self.fan_in();
        (1..=fan).filter(|rf| fan % rf == 0).collect()
    }

    /// Next larger legal reuse factor after the current one, if any
    /// (the reuse search's per-layer step).
    pub fn next_reuse_factor(&self) -> Option<usize> {
        let fan = self.fan_in();
        (self.reuse_factor + 1..=fan).find(|&rf| fan % rf == 0)
    }

    /// Largest legal reuse factor <= `want` (>= 1) — how requested
    /// factors snap onto the legality grid.
    pub fn snap_reuse_factor(&self, want: usize) -> usize {
        let fan = self.fan_in();
        (1..=want.max(1).min(fan)).rev().find(|&rf| fan % rf == 0).unwrap_or(1)
    }

    /// Effective multiplier count: one multiplier per nonzero weight.
    ///
    /// Dense RF=1 fully unrolls (hls4ml io_parallel).  Conv instantiates
    /// one MAC array for the kernel and streams it across the h*w output
    /// positions (fpgaConvNet-style spatial reuse) — so area scales with
    /// nnz while the spatial loop shows up in latency, matching how a
    /// ResNet9 can be placed on a U250 at all (paper Fig 4d).
    pub fn multipliers(&self) -> usize {
        self.nnz
    }

    /// Spatial iterations the conv MAC array is reused for (1 for dense).
    pub fn spatial_iters(&self) -> usize {
        match self.kind {
            HlsLayerKind::Conv2D => (self.h * self.w).max(1),
            _ => 1,
        }
    }

    /// Density (fraction of weights kept) for latency fan-in modeling.
    pub fn density(&self) -> f64 {
        if self.total_weights == 0 {
            1.0
        } else {
            self.nnz as f64 / self.total_weights as f64
        }
    }
}

/// The HLS C++ model stored in the model space.
#[derive(Debug, Clone)]
pub struct HlsModel {
    pub name: String,
    pub source_model: String,
    pub io_type: IoType,
    pub fpga_part: String,
    pub clock_period_ns: f64,
    pub layers: Vec<HlsLayer>,
}

impl HlsModel {
    /// Translate a trained DNN (manifest variant + live state) into the
    /// HLS abstraction — the HLS4ML λ-task's core operation.
    pub fn from_dnn(
        variant: &ModelVariant,
        state: &ModelState,
        default_precision: Precision,
        io_type: IoType,
        fpga_part: &str,
        clock_period_ns: f64,
    ) -> Result<Self> {
        let mut layers = Vec::new();
        for l in &variant.layers {
            let kind = match l.kind.as_str() {
                "dense" => HlsLayerKind::Dense,
                "conv2d" => HlsLayerKind::Conv2D,
                "maxpool2" => HlsLayerKind::MaxPool2,
                "flatten" => HlsLayerKind::Flatten,
                "residual_add" => HlsLayerKind::ResidualAdd,
                "residual_begin" => continue, // structural marker only
                other => {
                    return Err(Error::other(format!("unknown layer kind {other}")))
                }
            };
            let (total, nnz, precision) = if l.is_weight() {
                let mask_idx = l.mask_idx as usize;
                let mask = &state.masks[mask_idx];
                let total = mask.len();
                let nnz = mask
                    .as_f32()?
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count();
                // per-layer precision from the DNN state if the
                // quantization O-task already set one, else the default
                let p = state.precisions[mask_idx];
                let p = if p.enabled() { p } else { default_precision };
                (total, nnz, p)
            } else {
                (0, 0, default_precision)
            };
            layers.push(HlsLayer {
                name: l.name.clone(),
                kind,
                n_in: l.in_dim,
                n_out: l.out_dim,
                kernel: l.kernel,
                h: l.h,
                w: l.w,
                activation: l.activation.clone(),
                precision,
                reuse_factor: 1,
                total_weights: total,
                nnz,
                macs: l.macs,
            });
        }
        Ok(HlsModel {
            name: format!("{}_hls", variant.tag),
            source_model: variant.tag.clone(),
            io_type,
            fpga_part: fpga_part.to_string(),
            clock_period_ns,
            layers,
        })
    }

    /// Build an HLS model from a manifest variant and per-weight-layer
    /// nnz counts (mask order) — used by benches to synthesize search
    /// candidates without materializing a full ModelState.
    pub fn from_nnz(
        variant: &ModelVariant,
        nnz_by_layer: &[usize],
        precision: Precision,
        fpga_part: &str,
        clock_period_ns: f64,
    ) -> Result<Self> {
        let mut layers = Vec::new();
        for l in &variant.layers {
            let kind = match l.kind.as_str() {
                "dense" => HlsLayerKind::Dense,
                "conv2d" => HlsLayerKind::Conv2D,
                "maxpool2" => HlsLayerKind::MaxPool2,
                "flatten" => HlsLayerKind::Flatten,
                "residual_add" => HlsLayerKind::ResidualAdd,
                "residual_begin" => continue,
                other => {
                    return Err(Error::other(format!("unknown layer kind {other}")))
                }
            };
            let (total, nnz) = if l.is_weight() {
                let idx = l.mask_idx as usize;
                let total: usize = variant.mask_shapes[idx].1.iter().product();
                let nnz = nnz_by_layer.get(idx).copied().unwrap_or(total).min(total);
                (total, nnz)
            } else {
                (0, 0)
            };
            layers.push(HlsLayer {
                name: l.name.clone(),
                kind,
                n_in: l.in_dim,
                n_out: l.out_dim,
                kernel: l.kernel,
                h: l.h,
                w: l.w,
                activation: l.activation.clone(),
                precision,
                reuse_factor: 1,
                total_weights: total,
                nnz,
                macs: l.macs,
            });
        }
        Ok(HlsModel {
            name: format!("{}_hls", variant.tag),
            source_model: variant.tag.clone(),
            io_type: IoType::Parallel,
            fpga_part: fpga_part.to_string(),
            clock_period_ns,
            layers,
        })
    }

    /// Validate the hardware configuration: every compute layer's
    /// reuse factor must be >= 1 and divide its fan-in.  An IR built
    /// directly (bypassing the snapping transforms) with RF = 0 would
    /// otherwise reach the estimator's divisions unchecked.
    pub fn validate(&self) -> Result<()> {
        for l in self.compute_layers() {
            if l.reuse_factor == 0 {
                return Err(Error::Synth(format!(
                    "layer {:?}: reuse_factor must be >= 1",
                    l.name
                )));
            }
            if !l.reuse_legal(l.reuse_factor) {
                return Err(Error::Synth(format!(
                    "layer {:?}: reuse_factor {} does not divide fan-in {}",
                    l.name,
                    l.reuse_factor,
                    l.fan_in()
                )));
            }
        }
        Ok(())
    }

    /// Largest reuse factor across compute layers (the design's
    /// initiation interval under II = RF pipelining).
    pub fn max_reuse_factor(&self) -> usize {
        self.compute_layers().map(|l| l.reuse_factor).max().unwrap_or(1)
    }

    pub fn compute_layers(&self) -> impl Iterator<Item = &HlsLayer> {
        self.layers.iter().filter(|l| l.is_compute())
    }

    pub fn total_multipliers(&self) -> usize {
        self.compute_layers().map(|l| l.multipliers()).sum()
    }

    /// Index of compute layer `i` within `layers` (for transforms).
    pub fn compute_layer_indices(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_compute())
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn toy_model() -> HlsModel {
        HlsModel {
            name: "toy_hls".into(),
            source_model: "toy".into(),
            io_type: IoType::Parallel,
            fpga_part: "xcvu9p".into(),
            clock_period_ns: 5.0,
            layers: vec![
                HlsLayer {
                    name: "fc1".into(),
                    kind: HlsLayerKind::Dense,
                    n_in: 16,
                    n_out: 64,
                    kernel: 0,
                    h: 0,
                    w: 0,
                    activation: "relu".into(),
                    precision: Precision::new(18, 8),
                    reuse_factor: 1,
                    total_weights: 1024,
                    nnz: 1024,
                    macs: 1024,
                },
                HlsLayer {
                    name: "out".into(),
                    kind: HlsLayerKind::Dense,
                    n_in: 64,
                    n_out: 5,
                    kernel: 0,
                    h: 0,
                    w: 0,
                    activation: "linear".into(),
                    precision: Precision::new(18, 8),
                    reuse_factor: 1,
                    total_weights: 320,
                    nnz: 160,
                    macs: 320,
                },
            ],
        }
    }

    #[test]
    fn multiplier_accounting() {
        let m = toy_model();
        assert_eq!(m.total_multipliers(), 1024 + 160);
        assert_eq!(m.layers[1].density(), 0.5);
        assert_eq!(m.compute_layer_indices(), vec![0, 1]);
    }

    #[test]
    fn reuse_factor_legality_is_divisors_of_fan_in() {
        let m = toy_model();
        let fc1 = &m.layers[0]; // fan-in 16
        assert_eq!(fc1.fan_in(), 16);
        assert_eq!(fc1.legal_reuse_factors(), vec![1, 2, 4, 8, 16]);
        assert!(fc1.reuse_legal(4));
        assert!(!fc1.reuse_legal(3));
        assert!(!fc1.reuse_legal(0));
        assert_eq!(fc1.next_reuse_factor(), Some(2));
        assert_eq!(fc1.snap_reuse_factor(3), 2);
        assert_eq!(fc1.snap_reuse_factor(100), 16);
        assert_eq!(fc1.snap_reuse_factor(0), 1);
    }

    #[test]
    fn validate_rejects_zero_and_non_divisor_reuse() {
        let mut m = toy_model();
        assert!(m.validate().is_ok());
        m.layers[0].reuse_factor = 0;
        assert!(m.validate().is_err());
        m.layers[0].reuse_factor = 3; // does not divide 16
        assert!(m.validate().is_err());
        m.layers[0].reuse_factor = 8;
        assert!(m.validate().is_ok());
        assert_eq!(m.max_reuse_factor(), 8);
    }
}
