//! HLS C++ model substrate (the HLS4ML output abstraction).
//!
//! The paper's QUANTIZATION O-task works "at the HLS C++ level, providing
//! more direct control over hardware optimizations", using Artisan-style
//! source-to-source transformations.  This module provides that substrate:
//!
//! * [ir] — a typed layer-wise IR of the generated HLS C++ design
//!   (precision per layer as `ap_fixed<W,I>`, reuse factor, nnz after
//!   zero-weight folding);
//! * [transform] — a pass manager with Artisan-like rewrite passes
//!   (set-precision, fold-zero-weights, reuse-factor);
//! * [codegen] — emits actual hls4ml-style C++ so every HLS artifact in
//!   the model space carries inspectable source as a supporting file.

pub mod codegen;
pub mod ir;
pub mod transform;

pub use ir::{HlsLayer, HlsLayerKind, HlsModel, IoType};
pub use transform::{
    FoldZeroWeights, HlsTransform, PassManager, SetLayerReuse, SetPrecision, SetReuseFactor,
};
