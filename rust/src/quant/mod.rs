//! Quantization substrate: fixed-point search at the HLS level.

pub mod search;

pub use search::{quantize_search, QuantConfig, QuantProbe, QuantTrace};
