//! The QUANTIZATION O-task's mixed-precision search (paper §V-B).
//!
//! Operates at the HLS level: precisions are per-layer `ap_fixed<W,I>`
//! types instrumented into the C++ kernel (our HLS IR + SetPrecision
//! pass), and accuracy is checked by "co-design simulation" — here the
//! AOT eval executable, whose qcfg operand reproduces ap_fixed semantics
//! bit-exactly (the fused Pallas kernel).
//!
//! Greedy descent: starting from the default precision, repeatedly try
//! shaving one total bit off the single layer whose reduction costs the
//! least accuracy, while total accuracy loss stays < α_q.  Integer bits
//! shrink once the fractional part is exhausted.
//!
//! The `2·L` candidates of each round are independent, so they are
//! submitted as one batch through the [`ProbeService`] and evaluated
//! concurrently under `jobs > 1`.  (Each round's candidates are
//! genuinely new networks — an accepted cut changes the base precision
//! vector — so the pool's memo only fires on exact repeats, e.g. when a
//! pool is reused across searches; per-candidate state clones are
//! O(params) but the probe evaluations they feed dominate by orders of
//! magnitude.)  Selection is deterministic for any worker count: the
//! full batch is scanned in candidate order with an explicit tie-break
//! — highest accuracy, then lowest layer index, then fewest integer
//! bits — so the trace is bit-identical to sequential execution.

use crate::dse::{ProbeRequest, ProbeService};
use crate::error::Result;
use crate::model::state::Precision;
use crate::model::ModelState;
use crate::train::Trainer;

#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// α_q: tolerated accuracy loss (paper: 1% headline, 4% aggressive).
    pub tolerate_acc_loss: f64,
    /// Starting precision (the HLS4ML default, 18 total / 8 integer).
    pub start: Precision,
    /// Smallest allowed total bits per layer.
    pub min_bits: u32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            tolerate_acc_loss: 0.01,
            start: Precision::new(18, 8),
            min_bits: 2,
        }
    }
}

#[derive(Debug, Clone)]
pub struct QuantProbe {
    pub round: usize,
    pub layer: usize,
    pub tried: Precision,
    pub accuracy: f64,
    pub accepted: bool,
}

#[derive(Debug)]
pub struct QuantTrace {
    pub base_accuracy: f64,
    pub final_accuracy: f64,
    pub precisions: Vec<Precision>,
    pub probes: Vec<QuantProbe>,
    /// Total bits across layers, before → after.
    pub bits_before: u32,
    pub bits_after: u32,
}

/// The one-bit-narrower candidates of a precision: shaving a fraction
/// bit (coarser grid) or an integer bit (smaller range).  The search
/// tries both — integer bits are usually free on sub-unit weights, which
/// is how the paper's mixed-precision configs reach ap_fixed<8,3>-class
/// types from the 18,8 default.
fn reduce_candidates(p: Precision) -> Vec<Precision> {
    let mut out = Vec::with_capacity(2);
    if p.total_bits <= 2 {
        return out;
    }
    if p.frac_bits() > 0 {
        out.push(Precision::new(p.total_bits - 1, p.int_bits));
    }
    if p.int_bits > 1 {
        out.push(Precision::new(p.total_bits - 1, p.int_bits - 1));
    }
    out
}

/// Run the greedy mixed-precision search on `state` in place, fanning
/// each round's candidate batch out across `pool`.
pub fn quantize_search(
    trainer: &Trainer,
    state: &mut ModelState,
    cfg: &QuantConfig,
    pool: &dyn ProbeService,
) -> Result<QuantTrace> {
    let n_layers = state.n_weight_layers();
    // instrument the starting precision everywhere
    for p in state.precisions.iter_mut() {
        *p = cfg.start;
    }
    let base = trainer.evaluate(state)?;
    let floor = base.accuracy - cfg.tolerate_acc_loss;
    let bits_before = cfg.start.total_bits * n_layers as u32;

    let mut probes = Vec::new();
    let mut final_acc = base.accuracy;
    let mut round = 0usize;
    loop {
        round += 1;
        // enumerate this round's candidates in fixed order: layer
        // ascending, fraction cut before integer cut (the
        // reduce_candidates order)
        let mut cands: Vec<(usize, Precision)> = Vec::new();
        for l in 0..n_layers {
            for next in reduce_candidates(state.precisions[l]) {
                if next.total_bits >= cfg.min_bits {
                    cands.push((l, next));
                }
            }
        }
        if cands.is_empty() {
            break; // every layer is at the floor
        }

        let requests: Vec<ProbeRequest> = cands
            .iter()
            .enumerate()
            .map(|(i, &(l, p))| {
                let mut cand = state.clone();
                cand.precisions[l] = p;
                ProbeRequest::new(i, cand)
            })
            .collect();
        let results = pool.evaluate_batch(trainer, &requests)?;

        // keep the best acceptable reduction across all candidates;
        // ties break to the lowest layer index, then fewest int bits
        let mut best: Option<(usize, Precision, f64)> = None;
        for (&(l, p), r) in cands.iter().zip(&results) {
            let acc = r.eval.accuracy;
            let ok = acc >= floor;
            probes.push(QuantProbe {
                round,
                layer: l,
                tried: p,
                accuracy: acc,
                accepted: ok,
            });
            if !ok {
                continue;
            }
            let better = match best {
                None => true,
                Some((bl, bp, ba)) => {
                    acc > ba
                        || (acc == ba
                            && (l < bl || (l == bl && p.int_bits < bp.int_bits)))
                }
            };
            if better {
                best = Some((l, p, acc));
            }
        }
        match best {
            Some((l, p, acc)) => {
                state.precisions[l] = p;
                final_acc = acc;
            }
            None => break, // no layer can shrink within tolerance
        }
    }

    let bits_after = state.precisions.iter().map(|p| p.total_bits).sum();
    Ok(QuantTrace {
        base_accuracy: base.accuracy,
        final_accuracy: final_acc,
        precisions: state.precisions.clone(),
        probes,
        bits_before,
        bits_after,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_offers_fraction_and_integer_cuts() {
        let cands = reduce_candidates(Precision::new(10, 8));
        assert_eq!(cands.len(), 2);
        assert!(cands.contains(&Precision::new(9, 8))); // fewer frac bits
        assert!(cands.contains(&Precision::new(9, 7))); // fewer int bits
        // fraction exhausted: only the integer cut remains
        let cands = reduce_candidates(Precision::new(8, 8));
        assert_eq!(cands, vec![Precision::new(7, 7)]);
        // floor
        assert!(reduce_candidates(Precision::new(2, 2)).is_empty());
    }

    #[test]
    fn reduce_terminates_from_any_start() {
        let mut frontier = vec![Precision::new(18, 8)];
        let mut steps = 0;
        while let Some(p) = frontier.pop() {
            for next in reduce_candidates(p) {
                assert!(next.total_bits < p.total_bits);
                assert!(next.frac_bits() >= 0, "{next}");
                assert!(next.int_bits >= 1);
                if next.total_bits > 3 {
                    frontier.push(next);
                }
            }
            steps += 1;
            assert!(steps < 100_000);
        }
    }
}
