//! SGD training loop over a backend-bound [`ModelExecutable`].
//!
//! Matches the Keras fit/evaluate surface the paper's O-tasks rely on:
//! `fit(state, epochs)` and `evaluate(state)`, with cosine-decayed lr and
//! deterministic shuffling.  The loop is backend-agnostic: each step
//! passes the flat argument list (params ++ masks ++ [qcfg, x, y, lr])
//! through [`ModelExecutable::train_step`] and feeds the returned
//! parameters straight into the next step.  Constant operands (masks,
//! qcfg) are staged once per fit()/evaluate() call and the argument
//! vector is reused across steps, so the host side allocates only for
//! the batch.  Whether a step marshals beyond that is the backend's
//! concern: the reference interpreter reads the tensors in place; the
//! PJRT backend converts host ↔ literal once per step (see
//! `runtime::exec::PjrtModel`).

use crate::data::{Batcher, Dataset};
use crate::error::Result;
use crate::model::ModelState;
use crate::runtime::{HostTensor, ModelExecutable, Runtime};

/// Hyper-parameters for a fit() call.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub base_lr: f32,
    pub min_lr: f32,
    pub seed: u64,
    /// Print a line per epoch when true (flows log through the metamodel).
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 5, base_lr: 0.5, min_lr: 0.02, seed: 17, verbose: false }
    }
}

impl TrainConfig {
    /// Per-model defaults (CNNs need gentler SGD than the jet MLP).
    pub fn for_model(model: &str) -> Self {
        match model {
            "vgg7_mini" => TrainConfig {
                epochs: 8,
                base_lr: 0.12,
                min_lr: 0.01,
                ..Default::default()
            },
            "resnet9_mini" => TrainConfig {
                epochs: 8,
                base_lr: 0.06,
                min_lr: 0.005,
                ..Default::default()
            },
            _ => TrainConfig { epochs: 6, ..Default::default() },
        }
    }
}

/// Aggregated evaluation over the full test split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Binds a runtime + backend-bound executable + dataset into a
/// Keras-like trainer.
pub struct Trainer<'a> {
    /// The runtime the executable is bound to.  The step loop drives
    /// [`ModelExecutable`] directly, but the handle stays here so
    /// trainer consumers can reach platform/stats accounting without
    /// re-threading the session.
    pub runtime: &'a Runtime,
    pub exec: &'a ModelExecutable,
    pub data: &'a Dataset,
}

impl<'a> Trainer<'a> {
    pub fn new(runtime: &'a Runtime, exec: &'a ModelExecutable, data: &'a Dataset) -> Self {
        Trainer { runtime, exec, data }
    }

    /// Cosine lr schedule over the whole fit() horizon.
    fn lr_at(cfg: &TrainConfig, step: usize, total: usize) -> f32 {
        if total <= 1 {
            return cfg.base_lr;
        }
        let t = step as f32 / (total - 1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        cfg.min_lr + (cfg.base_lr - cfg.min_lr) * cos
    }

    /// SGD-train `state` in place; returns final (train_loss, train_acc).
    pub fn fit(&self, state: &mut ModelState, cfg: &TrainConfig) -> Result<(f32, f32)> {
        let batch = self.exec.variant.train_batch;
        let mut batcher = Batcher::new(self.data, batch, cfg.seed);
        let steps_per_epoch = batcher.steps_per_epoch().max(1);
        let total = steps_per_epoch * cfg.epochs;
        let n_params = state.params.len();

        // args = params ++ masks ++ qcfg ++ [x, y, lr]; the constant
        // middle (masks, qcfg) is staged once, the params prefix is
        // overwritten with each step's outputs, and the x/y/lr tail is
        // replaced per step.
        let base = n_params + state.masks.len() + 1;
        let mut args: Vec<HostTensor> = Vec::with_capacity(base + 3);
        args.extend(state.params.iter().cloned());
        args.extend(state.masks.iter().cloned());
        args.push(state.qcfg_tensor());

        let mut last = (0.0f32, 0.0f32);
        let mut step = 0usize;
        for epoch in 0..cfg.epochs {
            let mut ep_loss = 0.0f64;
            let mut ep_acc = 0.0f64;
            for _ in 0..steps_per_epoch {
                let (x, y) = batcher.next_batch()?;
                let lr = Self::lr_at(cfg, step, total);
                args.truncate(base);
                args.push(x);
                args.push(y);
                args.push(HostTensor::scalar(lr));

                let (new_params, loss, acc) = self.exec.train_step(&args)?;
                for (slot, p) in args.iter_mut().zip(new_params) {
                    *slot = p;
                }
                ep_loss += loss as f64;
                ep_acc += acc as f64;
                last = (loss, acc);
                step += 1;
            }
            if cfg.verbose {
                println!(
                    "    epoch {:>2}: loss {:.4} acc {:.4}",
                    epoch + 1,
                    ep_loss / steps_per_epoch as f64,
                    ep_acc / steps_per_epoch as f64
                );
            }
        }
        // write the final parameters back into the model state
        args.truncate(n_params);
        state.params = args;
        Ok(last)
    }

    /// Evaluate on the full test split (tail batch padded, weighted by
    /// valid count — padding rows are repeats and slightly bias the tail
    /// batch, bounded by batch/n_test; acceptable for trend experiments).
    ///
    /// Model operands are staged once per evaluate() call, not once per
    /// batch — the quantization search calls this hundreds of times —
    /// and the whole split goes through [`ModelExecutable::eval_batches`]
    /// so the backend hoists per-run work (the reference interpreter
    /// quantizes and sparsifies the weights once for the full split).
    pub fn evaluate(&self, state: &ModelState) -> Result<EvalResult> {
        let batch = self.exec.variant.eval_batch;
        let base_len = state.params.len() + state.masks.len() + 1;
        let mut base: Vec<HostTensor> = Vec::with_capacity(base_len);
        base.extend(state.params.iter().cloned());
        base.extend(state.masks.iter().cloned());
        base.push(state.qcfg_tensor());

        let mut batches = Vec::new();
        let mut valids = Vec::new();
        for (x, y, valid) in self.data.test_batches(batch)? {
            batches.push((x, y));
            valids.push(valid);
        }
        let results = self.exec.eval_batches(&base, &batches)?;

        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut n = 0usize;
        for ((loss, acc), valid) in results.into_iter().zip(valids) {
            loss_sum += loss as f64 * valid as f64;
            acc_sum += acc as f64 * valid as f64;
            n += valid;
        }
        Ok(EvalResult {
            loss: loss_sum / n.max(1) as f64,
            accuracy: acc_sum / n.max(1) as f64,
            n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_endpoints() {
        let cfg = TrainConfig { base_lr: 1.0, min_lr: 0.1, ..Default::default() };
        assert!((Trainer::lr_at(&cfg, 0, 100) - 1.0).abs() < 1e-6);
        assert!((Trainer::lr_at(&cfg, 99, 100) - 0.1).abs() < 1e-6);
        let mid = Trainer::lr_at(&cfg, 50, 100);
        assert!(mid < 1.0 && mid > 0.1);
        // monotone non-increasing
        let mut prev = f32::MAX;
        for s in 0..100 {
            let lr = Trainer::lr_at(&cfg, s, 100);
            assert!(lr <= prev + 1e-6);
            prev = lr;
        }
    }
}
