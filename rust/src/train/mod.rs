//! Training driver: SGD loop over AOT train/eval executables.

pub mod trainer;

pub use trainer::{EvalResult, TrainConfig, Trainer};
