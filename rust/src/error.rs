//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("shape mismatch: expected {expected:?}, got {got:?}")]
    ShapeMismatch { expected: Vec<usize>, got: Vec<usize> },

    #[error("flow error: {0}")]
    Flow(String),

    #[error("task error in {task}: {msg}")]
    Task { task: String, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("model space error: {0}")]
    ModelSpace(String),

    #[error("synthesis error: {0}")]
    Synth(String),

    #[error("{0}")]
    Other(String),
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    pub fn task(task: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Task { task: task.into(), msg: msg.into() }
    }
}

pub type Result<T> = std::result::Result<T, Error>;
