//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no proc-macro dependencies: the
//! crate builds offline with an empty dependency set by default).  The
//! [`Error::Xla`] variant only exists when the `xla` feature enables the
//! PJRT backend.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    #[cfg(feature = "xla")]
    Xla(xla::Error),

    Io(std::io::Error),

    Json { offset: usize, msg: String },

    Manifest(String),

    ShapeMismatch { expected: Vec<usize>, got: Vec<usize> },

    Flow(String),

    Task { task: String, msg: String },

    Config(String),

    ModelSpace(String),

    Synth(String),

    /// Execution-backend failure (reference interpreter or PJRT).
    Backend(String),

    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "xla")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected {expected:?}, got {got:?}")
            }
            Error::Flow(msg) => write!(f, "flow error: {msg}"),
            Error::Task { task, msg } => write!(f, "task error in {task}: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::ModelSpace(msg) => write!(f, "model space error: {msg}"),
            Error::Synth(msg) => write!(f, "synthesis error: {msg}"),
            Error::Backend(msg) => write!(f, "backend error: {msg}"),
            Error::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "xla")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    pub fn task(task: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Task { task: task.into(), msg: msg.into() }
    }

    pub fn backend(msg: impl Into<String>) -> Self {
        Error::Backend(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_contract() {
        assert_eq!(Error::Manifest("x".into()).to_string(), "manifest error: x");
        assert_eq!(
            Error::task("prune", "boom").to_string(),
            "task error in prune: boom"
        );
        assert_eq!(Error::other("plain").to_string(), "plain");
        assert_eq!(
            Error::backend("no client").to_string(),
            "backend error: no client"
        );
        let e = Error::ShapeMismatch { expected: vec![2, 3], got: vec![5] };
        assert_eq!(e.to_string(), "shape mismatch: expected [2, 3], got [5]");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
