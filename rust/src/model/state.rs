//! Live model state: params + pruning masks + quantization config.
//!
//! This is the "DNN model" abstraction stored in the metamodel's model
//! space.  It is pure host data (no xla handles), so it can be cloned into
//! model-space snapshots, serialized, and moved between pipe tasks.

use crate::error::{Error, Result};
use crate::runtime::{HostTensor, ModelVariant};
use crate::util::Prng;

/// Per-layer ap_fixed precision (row of the qcfg tensor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precision {
    pub total_bits: u32,
    pub int_bits: u32,
}

impl Precision {
    pub const DISABLED: Precision = Precision { total_bits: 0, int_bits: 0 };

    pub fn new(total_bits: u32, int_bits: u32) -> Self {
        Precision { total_bits, int_bits }
    }

    pub fn enabled(&self) -> bool {
        self.total_bits > 0
    }

    pub fn frac_bits(&self) -> i64 {
        self.total_bits as i64 - self.int_bits as i64
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.enabled() {
            write!(f, "ap_fixed<{},{}>", self.total_bits, self.int_bits)
        } else {
            write!(f, "float")
        }
    }
}

/// Parameters + masks + per-layer precision for one model variant.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub tag: String,
    pub params: Vec<HostTensor>,
    pub masks: Vec<HostTensor>,
    pub precisions: Vec<Precision>,
    /// Indices into `params` of the weight tensors (mask-aligned).
    pub weight_param_idx: Vec<usize>,
}

impl ModelState {
    /// Glorot-initialized state with full masks and disabled quantization.
    pub fn init(variant: &ModelVariant, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut params = Vec::with_capacity(variant.n_params());
        for (name, shape) in &variant.param_shapes {
            let n: usize = shape.iter().product();
            if name.starts_with('w') {
                let fan_in: usize = shape[..shape.len() - 1].iter().product();
                let fan_out = shape[shape.len() - 1];
                let data = rng.fork(n as u64).glorot(fan_in, fan_out, n);
                params.push(HostTensor::F32 { shape: shape.clone(), data });
            } else {
                params.push(HostTensor::zeros(shape));
            }
        }
        let masks = variant
            .mask_shapes
            .iter()
            .map(|(_, shape)| HostTensor::ones(shape))
            .collect();
        ModelState {
            tag: variant.tag.clone(),
            params,
            masks,
            precisions: vec![Precision::DISABLED; variant.qcfg_rows],
            weight_param_idx: variant.mask_shapes.iter().map(|(p, _)| *p).collect(),
        }
    }

    pub fn n_weight_layers(&self) -> usize {
        self.masks.len()
    }

    /// The qcfg tensor in the layout the AOT graph expects: f32[L, 2].
    pub fn qcfg_tensor(&self) -> HostTensor {
        let mut data = Vec::with_capacity(self.precisions.len() * 2);
        for p in &self.precisions {
            data.push(p.total_bits as f32);
            data.push(p.int_bits as f32);
        }
        HostTensor::F32 { shape: vec![self.precisions.len(), 2], data }
    }

    /// Weight tensor of layer `l` (mask-aligned indexing).
    pub fn weight(&self, l: usize) -> &HostTensor {
        &self.params[self.weight_param_idx[l]]
    }

    pub fn weight_param_index(&self, l: usize) -> usize {
        self.weight_param_idx[l]
    }

    /// Apply the masks to the weights (zero out pruned entries).
    pub fn apply_masks(&mut self) -> Result<()> {
        for (l, &pidx) in self.weight_param_idx.clone().iter().enumerate() {
            let mask = self.masks[l].as_f32()?.to_vec();
            let w = self.params[pidx].as_f32_mut()?;
            if w.len() != mask.len() {
                return Err(Error::other("mask/weight length mismatch"));
            }
            for (wv, mv) in w.iter_mut().zip(&mask) {
                *wv *= mv;
            }
        }
        Ok(())
    }

    /// Global fraction of weights pruned (over maskable weight tensors).
    pub fn pruned_fraction(&self) -> f64 {
        let mut zero = 0usize;
        let mut total = 0usize;
        for m in &self.masks {
            if let HostTensor::F32 { data, .. } = m {
                zero += data.iter().filter(|v| **v == 0.0).count();
                total += data.len();
            }
        }
        if total == 0 {
            0.0
        } else {
            zero as f64 / total as f64
        }
    }

    /// Per-layer density (fraction kept) in mask order.
    pub fn layer_densities(&self) -> Vec<f64> {
        self.masks.iter().map(|m| 1.0 - m.zero_fraction()).collect()
    }

    /// Total number of remaining (unpruned) multiplies represented by masks.
    pub fn nonzero_weights(&self) -> usize {
        self.masks
            .iter()
            .map(|m| match m {
                HostTensor::F32 { data, .. } => {
                    data.iter().filter(|v| **v != 0.0).count()
                }
                _ => 0,
            })
            .sum()
    }

    /// Assemble the flat eval argument list: params ++ masks ++ [qcfg, x, y].
    pub fn eval_args(&self, x: HostTensor, y: HostTensor) -> Vec<HostTensor> {
        let mut args =
            Vec::with_capacity(self.params.len() + self.masks.len() + 3);
        args.extend(self.params.iter().cloned());
        args.extend(self.masks.iter().cloned());
        args.push(self.qcfg_tensor());
        args.push(x);
        args.push(y);
        args
    }

    /// Assemble the flat train argument list (eval args + lr).
    pub fn train_args(&self, x: HostTensor, y: HostTensor, lr: f32) -> Vec<HostTensor> {
        let mut args = self.eval_args(x, y);
        args.push(HostTensor::scalar(lr));
        args
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variant() -> ModelVariant {
        ModelVariant {
            model: "toy".into(),
            scale: 1.0,
            tag: "toy_s1000".into(),
            input_shape: vec![4],
            n_classes: 2,
            train_batch: 8,
            eval_batch: 8,
            param_shapes: vec![
                ("w0".into(), vec![4, 8]),
                ("b0".into(), vec![8]),
                ("w1".into(), vec![8, 2]),
                ("b1".into(), vec![2]),
            ],
            mask_shapes: vec![(0, vec![4, 8]), (2, vec![8, 2])],
            qcfg_rows: 2,
            layers: vec![],
            train_artifact: "t".into(),
            eval_artifact: "e".into(),
        }
    }

    #[test]
    fn init_shapes_and_biases_zero() {
        let s = ModelState::init(&variant(), 1);
        assert_eq!(s.params.len(), 4);
        assert_eq!(s.params[0].shape(), &[4, 8]);
        assert!(s.params[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(s.params[0].as_f32().unwrap().iter().any(|&v| v != 0.0));
        assert_eq!(s.pruned_fraction(), 0.0);
    }

    #[test]
    fn deterministic_init() {
        let a = ModelState::init(&variant(), 7);
        let b = ModelState::init(&variant(), 7);
        assert_eq!(a.params[0], b.params[0]);
        let c = ModelState::init(&variant(), 8);
        assert_ne!(a.params[0], c.params[0]);
    }

    #[test]
    fn qcfg_layout() {
        let mut s = ModelState::init(&variant(), 1);
        s.precisions[1] = Precision::new(8, 3);
        let q = s.qcfg_tensor();
        assert_eq!(q.shape(), &[2, 2]);
        assert_eq!(q.as_f32().unwrap(), &[0.0, 0.0, 8.0, 3.0]);
    }

    #[test]
    fn mask_application_and_sparsity() {
        let mut s = ModelState::init(&variant(), 1);
        // prune half of layer 0
        if let HostTensor::F32 { data, .. } = &mut s.masks[0] {
            for v in data.iter_mut().take(16) {
                *v = 0.0;
            }
        }
        s.apply_masks().unwrap();
        assert_eq!(s.weight(0).as_f32().unwrap()[..16], vec![0.0f32; 16][..]);
        let pf = s.pruned_fraction();
        assert!((pf - 16.0 / 48.0).abs() < 1e-9, "{pf}");
        assert_eq!(s.nonzero_weights(), 32);
        let d = s.layer_densities();
        assert!((d[0] - 0.5).abs() < 1e-9 && d[1] == 1.0);
    }

    #[test]
    fn arg_assembly_order() {
        let s = ModelState::init(&variant(), 1);
        let x = HostTensor::zeros(&[8, 4]);
        let y = HostTensor::from_i32(&[8], vec![0; 8]).unwrap();
        let args = s.train_args(x, y, 0.1);
        assert_eq!(args.len(), 4 + 2 + 3 + 1);
        assert_eq!(args[6].shape(), &[2, 2]); // qcfg
        assert_eq!(args[9].scalar_f32().unwrap(), 0.1); // lr last
    }
}
