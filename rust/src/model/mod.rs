//! DNN model abstraction: a manifest variant + its live training state.

pub mod state;

pub use state::ModelState;
