//! Multi-flow exploration: run many design-flow *architectures*
//! concurrently from one spec and report a Pareto front.
//!
//! The paper's O-tasks explore per-task candidate spaces; the wins that
//! remain (cf. "Software-defined Design Space Exploration") come from
//! exploring *alternative flow architectures* — different task orders,
//! different tolerance settings — against each other.  A spec declares a
//! variant grid in its `explore` section:
//!
//! ```json
//! "explore": {
//!   "orders": [["gen","scale","prune","hls4ml","quantize","synth"],
//!              ["gen","prune","scale","hls4ml","quantize","synth"]],
//!   "cfg_grid": {"prune.tolerate_acc_loss": [0.01, 0.03]}
//! }
//! ```
//!
//! [`expand_variants`] takes the cartesian product (orders ×
//! cfg-grid points), [`explore`] runs every variant's full flow
//! concurrently on a [`crate::dse::ProbePool`] — cloned `MetaModel`s
//! against the shared `Send + Sync` [`Session`], one shared tier
//! stack per probe kind ([`ProbeTiers`]) so identical candidate
//! evaluations — training probes and hardware-synthesis probes alike —
//! dedupe across variants — and [`front_of`] reports the non-dominated
//! set over (accuracy ↑, DSP ↓, LUT ↓, latency ↓) pulled from each
//! variant's final RTL report ([`crate::synth::estimate`]) via the
//! N-objective [`crate::search::pareto::pareto_front_min`] kernel.
//!
//! **Determinism:** variants expand in declaration order, results come
//! back in request order whatever the worker interleaving, every
//! variant's flow itself produces a jobs-invariant LOG, and cache
//! sharing can only skip recomputation of bit-identical results — so
//! the printed front is identical for every `--jobs` value.

use std::collections::{BTreeMap, BTreeSet, HashSet};

use crate::config::FlowSpec;
use crate::dse::ProbeTiers;
use crate::search::driver::SearchCost;
use crate::error::{Error, Result};
use crate::flow::graph::{FlowGraph, NodeKind};
use crate::flow::registry::TaskRegistry;
use crate::flow::session::Session;
use crate::flow::Engine;
use crate::json::Value;
use crate::metamodel::{Abstraction, LogEvent, MetaModel};
use crate::report::{CsvWriter, Table};

/// The `explore` section of a spec: task-order permutations and/or CFG
/// value grids.  Empty lists mean "just the base flow".
#[derive(Debug, Clone, Default)]
pub struct ExploreSpec {
    /// Each entry is a complete linear order over the flow's task
    /// instances; the variant replaces the base edges with that chain.
    pub orders: Vec<Vec<String>>,
    /// CFG key → candidate values; variants take the cartesian product.
    pub cfg_grid: Vec<(String, Vec<Value>)>,
}

impl ExploreSpec {
    /// Parse and validate against the flow's node set: every order must
    /// be a permutation of all task instances.
    pub fn parse(v: &Value, graph: &FlowGraph) -> Result<ExploreSpec> {
        let mut orders = Vec::new();
        if let Some(Value::Array(os)) = v.get("orders") {
            let mut all: Vec<&str> =
                graph.nodes().iter().map(|n| n.instance.as_str()).collect();
            all.sort_unstable();
            for o in os {
                let order: Vec<String> = o
                    .as_array()
                    .ok_or_else(|| Error::Config("explore order must be an array".into()))?
                    .iter()
                    .map(|e| {
                        e.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Config("explore order entries must be task ids".into())
                        })
                    })
                    .collect::<Result<_>>()?;
                let mut sorted: Vec<&str> = order.iter().map(String::as_str).collect();
                sorted.sort_unstable();
                if sorted != all {
                    return Err(Error::Config(format!(
                        "explore order {order:?} is not a permutation of the flow's \
                         tasks {all:?}"
                    )));
                }
                orders.push(order);
            }
        }
        let mut cfg_grid = Vec::new();
        if let Some(Value::Object(map)) = v.get("cfg_grid") {
            for (k, vals) in map {
                let vals = vals.as_array().ok_or_else(|| {
                    Error::Config(format!("explore cfg_grid {k:?} must be an array"))
                })?;
                if vals.is_empty() {
                    return Err(Error::Config(format!(
                        "explore cfg_grid {k:?} must not be empty"
                    )));
                }
                cfg_grid.push((k.clone(), vals.to_vec()));
            }
        }
        Ok(ExploreSpec { orders, cfg_grid })
    }

    /// Number of variants the grid expands to.
    pub fn n_variants(&self) -> usize {
        self.orders.len().max(1)
            * self.cfg_grid.iter().map(|(_, vs)| vs.len()).product::<usize>()
    }
}

/// One flow architecture to evaluate: a concrete graph + CFG overrides.
#[derive(Debug, Clone)]
pub struct FlowVariant {
    pub label: String,
    pub spec: FlowSpec,
    pub cfg: Vec<(String, Value)>,
}

/// The outcome of running one variant's full flow.
#[derive(Debug, Clone)]
pub struct VariantResult {
    pub label: String,
    /// The CFG overrides that distinguished this variant (grid point /
    /// sampled range values), echoed so reports are self-describing.
    pub cfg: Vec<(String, Value)>,
    /// Metrics of the final RTL artifact (accuracy, dsp, lut,
    /// latency_ns, power_w, …).
    pub metrics: BTreeMap<String, f64>,
    /// Number of models the flow stored in the model space.
    pub n_models: usize,
    /// The variant's replay-comparable LOG event stream.
    pub events: Vec<LogEvent>,
}

impl VariantResult {
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.get(name).copied()
    }

    /// The variant's objective vector in the shared minimization
    /// convention of [`crate::search::pareto`]: accuracy negated, DSP /
    /// LUT / latency as-is.  Every front in the system — explorer,
    /// budgeted search, bench hypervolume — is computed over exactly
    /// this vector.
    pub fn min_objectives(&self) -> Result<Vec<f64>> {
        let m = |name: &str| {
            self.metric(name).ok_or_else(|| {
                Error::Flow(format!(
                    "variant {:?} has no {name:?} metric on its RTL artifact",
                    self.label
                ))
            })
        };
        Ok(vec![-m("accuracy")?, m("dsp")?, m("lut")?, m("latency_ns")?])
    }
}

/// Everything one exploration run produced.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// Per-variant results, in deterministic grid-expansion order.
    pub results: Vec<VariantResult>,
    /// Indices into `results` on the Pareto front (ascending).
    pub front: Vec<usize>,
}

fn render_value(v: &Value) -> String {
    match v {
        Value::String(s) => s.clone(),
        Value::Number(n) => format!("{n}"),
        Value::Bool(b) => format!("{b}"),
        other => crate::json::to_string_pretty(other),
    }
}

/// Expand the spec's variant grid into concrete flow variants, in
/// deterministic declaration order (orders outer, cfg-grid points
/// inner, grid keys in BTree order).
pub fn expand_variants(spec: &FlowSpec) -> Result<Vec<FlowVariant>> {
    let explore = spec.explore.clone().unwrap_or_default();

    // cartesian product over the cfg grid, first key varying slowest
    let mut points: Vec<Vec<(String, Value)>> = vec![Vec::new()];
    for (key, vals) in &explore.cfg_grid {
        let mut next = Vec::with_capacity(points.len() * vals.len());
        for p in &points {
            for v in vals {
                let mut q = p.clone();
                q.push((key.clone(), v.clone()));
                next.push(q);
            }
        }
        points = next;
    }

    if !explore.orders.is_empty() {
        reject_unchainable_orders(spec)?;
    }

    let mut variants = Vec::new();
    let order_slots: Vec<Option<&Vec<String>>> = if explore.orders.is_empty() {
        vec![None]
    } else {
        explore.orders.iter().map(Some).collect()
    };
    for order in order_slots {
        let (order_label, variant_spec) = match order {
            None => (None, spec.clone()),
            Some(order) => {
                let label = order.join("-");
                (Some(label.clone()), spec.with_graph(chain_graph(spec, order, &label)?)?)
            }
        };
        for point in &points {
            variants.push(FlowVariant {
                label: variant_label(spec, order_label.as_deref(), point),
                spec: variant_spec.clone(),
                cfg: point.clone(),
            });
        }
    }
    Ok(variants)
}

/// Order variants are plain chains: silently discarding the base
/// flow's guards or back edges would compare architectures the user
/// never declared, so any traversal of an order-bearing variant space
/// ([`expand_variants`] and [`crate::search::SearchSpace`] alike) must
/// reject the combination outright.
pub(crate) fn reject_unchainable_orders(spec: &FlowSpec) -> Result<()> {
    if spec.graph.guarded_edges().any(|(_, _, g)| g.is_some()) {
        return Err(Error::Config(
            "explore orders cannot permute a flow with conditional edges \
             (order variants are plain chains; drop the guards or the orders)"
                .into(),
        ));
    }
    if !spec.graph.back_edges().is_empty() {
        return Err(Error::Config(
            "explore orders cannot permute a flow with back edges \
             (order variants are plain chains; drop the back edges or the orders)"
                .into(),
        ));
    }
    Ok(())
}

/// The label scheme shared by grid expansion and the budgeted search:
/// `"<order> <k>=<v> …"`, falling back to the flow's name for the bare
/// base variant.
fn variant_label(spec: &FlowSpec, order_label: Option<&str>, cfg: &[(String, Value)]) -> String {
    let mut parts: Vec<String> = order_label.map(str::to_string).into_iter().collect();
    for (k, v) in cfg {
        parts.push(format!("{k}={}", render_value(v)));
    }
    if parts.is_empty() {
        spec.graph.name.clone()
    } else {
        parts.join(" ")
    }
}

/// Build one concrete [`FlowVariant`] for an optional order permutation
/// and a CFG point — how [`crate::search`] strategies materialize the
/// candidates they propose, guaranteed label- and graph-identical to
/// what [`expand_variants`] would produce for the same coordinates.
pub fn variant_for(
    spec: &FlowSpec,
    order: Option<&[String]>,
    cfg: Vec<(String, Value)>,
) -> Result<FlowVariant> {
    let (order_label, variant_spec) = match order {
        None => (None, spec.clone()),
        Some(order) => {
            let label = order.join("-");
            let spec = spec.with_graph(chain_graph(spec, order, &label)?)?;
            (Some(label), spec)
        }
    };
    Ok(FlowVariant {
        label: variant_label(spec, order_label.as_deref(), &cfg),
        spec: variant_spec,
        cfg,
    })
}

/// Rebuild the spec's graph as a linear chain in `order` (same nodes,
/// chain edges; guards/back edges in the base flow were already
/// rejected by [`expand_variants`]).
fn chain_graph(spec: &FlowSpec, order: &[String], label: &str) -> Result<FlowGraph> {
    let mut g = FlowGraph::new(format!("{}[{label}]", spec.graph.name));
    let mut ids = Vec::with_capacity(order.len());
    for inst in order {
        let base_id = spec.graph.node_by_instance(inst).ok_or_else(|| {
            Error::Config(format!("explore order references unknown task {inst:?}"))
        })?;
        let node = spec.graph.node(base_id)?;
        let id = match &node.kind {
            NodeKind::Task { task_type } => g.add_task(inst.clone(), task_type.clone()),
            NodeKind::Strategy { arms } => g.add_strategy(inst.clone(), arms.clone())?,
        };
        ids.push(id);
    }
    for w in ids.windows(2) {
        g.connect(w[0], w[1])?;
    }
    Ok(g)
}

/// Expand the spec's grid and run it (see [`explore_variants`]).
pub fn explore(
    session: &Session,
    registry: &TaskRegistry,
    spec: &FlowSpec,
    extra_cfg: &[(String, Value)],
    jobs: usize,
) -> Result<ExploreOutcome> {
    explore_variants(session, registry, &expand_variants(spec)?, extra_cfg, jobs)
}

/// Run every variant's full flow concurrently and compute the Pareto
/// front.  Takes an already-expanded variant list so callers that
/// printed the grid don't expand it twice.  `extra_cfg` is applied to
/// every variant (CLI `--model` / `-c` overrides); `jobs` bounds
/// concurrent variants, with the leftover worker budget handed to each
/// variant's inner probe pools.
pub fn explore_variants(
    session: &Session,
    registry: &TaskRegistry,
    variants: &[FlowVariant],
    extra_cfg: &[(String, Value)],
    jobs: usize,
) -> Result<ExploreOutcome> {
    if variants.is_empty() {
        return Err(Error::Flow("explore: no variants to run".into()));
    }
    let shared = ProbeTiers::new();
    let results = run_variants(session, registry, variants, extra_cfg, jobs, &shared)?;
    let front = front_of(&results)?;
    Ok(ExploreOutcome { results, front })
}

/// Run a batch of variants concurrently against caller-provided shared
/// probe tiers and return their results in input order — the evaluation
/// primitive under both [`explore_variants`] (one batch, fresh tiers)
/// and the budgeted [`crate::search`] driver (many batches against one
/// persistent [`ProbeTiers`], so probes dedupe across the whole search
/// and, with a disk tier attached, across whole processes).
pub fn run_variants(
    session: &Session,
    registry: &TaskRegistry,
    variants: &[FlowVariant],
    extra_cfg: &[(String, Value)],
    jobs: usize,
    shared: &ProbeTiers,
) -> Result<Vec<VariantResult>> {
    if variants.is_empty() {
        return Ok(Vec::new());
    }
    // identical variants (duplicate grid entries) run once — keyed by
    // full structural identity (graph nodes/edges/guards, base cfg and
    // typed cfg point), never the rendered label, so caller-supplied
    // variants that merely share a name stay distinct
    let mut unique: Vec<usize> = Vec::new();
    let mut first_of: BTreeMap<String, usize> = BTreeMap::new();
    let mut source: Vec<usize> = Vec::with_capacity(variants.len());
    for (i, v) in variants.iter().enumerate() {
        let sig = format!("{:?} {:?} {:?}", v.spec.graph, v.spec.cfg_entries, v.cfg);
        match first_of.get(&sig) {
            Some(&slot) => source.push(slot),
            None => {
                first_of.insert(sig, unique.len());
                source.push(unique.len());
                unique.push(i);
            }
        }
    }

    // split the worker budget over the *unique* variants: `concurrent`
    // flows run at once, each O-task inside fans out over the leftover
    // share (results are jobs-invariant either way; this only balances
    // wall-clock)
    let jobs = jobs.max(1);
    let concurrent = jobs.min(unique.len()).max(1);
    let inner_jobs = (jobs / concurrent).max(1);

    let pool = shared.pool(concurrent);
    let ran: Vec<VariantResult> = pool.run_batch(unique.len(), |slot| {
        run_one_variant(session, registry, &variants[unique[slot]], extra_cfg, inner_jobs, shared)
    })?;

    Ok(source.into_iter().map(|slot| ran[slot].clone()).collect())
}

/// Run a single variant's full flow against the shared probe tiers —
/// the per-candidate unit of work under [`run_variants`] and the
/// pipelined search scheduler (which submits these one at a time
/// through the async [`crate::dse::ProbeService`] seam).  `inner_jobs`
/// is the worker budget handed to the variant's inner probe pools
/// (unless the variant's cfg pins `jobs` itself).
pub(crate) fn run_one_variant(
    session: &Session,
    registry: &TaskRegistry,
    variant: &FlowVariant,
    extra_cfg: &[(String, Value)],
    inner_jobs: usize,
    shared: &ProbeTiers,
) -> Result<VariantResult> {
    let engine = Engine::with_services(session, registry, shared.clone());
    let mut meta = MetaModel::new();
    variant.spec.apply_cfg(&mut meta.cfg);
    for (k, v) in extra_cfg {
        meta.cfg.set(k.clone(), v.clone());
    }
    for (k, v) in &variant.cfg {
        meta.cfg.set(k.clone(), v.clone());
    }
    if meta.cfg.get("jobs").is_none() {
        meta.cfg.set("jobs", inner_jobs);
    }
    engine.run_spec(&variant.spec, &mut meta).map_err(|e| {
        Error::Flow(format!("variant {:?}: {e}", variant.label))
    })?;
    let rtl = meta.space.latest(Abstraction::Rtl).ok_or_else(|| {
        Error::Flow(format!(
            "variant {:?} produced no RTL artifact (explored flows must \
             end in VIVADO-HLS)",
            variant.label
        ))
    })?;
    Ok(VariantResult {
        label: variant.label.clone(),
        cfg: variant.cfg.clone(),
        metrics: rtl.metrics.clone(),
        n_models: meta.space.len(),
        events: meta.log.events().cloned().collect(),
    })
}

/// The Pareto front (ascending indices) over a result set's
/// [`VariantResult::min_objectives`] vectors.
pub fn front_of(results: &[VariantResult]) -> Result<Vec<usize>> {
    let objectives = results
        .iter()
        .map(|r| r.min_objectives())
        .collect::<Result<Vec<_>>>()?;
    Ok(crate::search::pareto::pareto_front_min(&objectives))
}

/// Aligned table of all variants, front members marked.
pub fn front_table(out: &ExploreOutcome) -> Table {
    let on_front: HashSet<usize> = out.front.iter().copied().collect();
    let mut t = Table::new(&["variant", "accuracy", "DSP", "LUT", "latency_ns", "power_w", "front"]);
    for (i, r) in out.results.iter().enumerate() {
        let g = |name: &str| {
            r.metric(name).map(|v| format!("{v:.4}")).unwrap_or_default()
        };
        t.row(&[
            r.label.clone(),
            g("accuracy"),
            r.metric("dsp").map(|v| format!("{v:.0}")).unwrap_or_default(),
            r.metric("lut").map(|v| format!("{v:.0}")).unwrap_or_default(),
            g("latency_ns"),
            g("power_w"),
            if on_front.contains(&i) { "*".into() } else { String::new() },
        ]);
    }
    t
}

/// CSV of all variants for the `report/` directory.  Each variant's CFG
/// overrides become their own columns (the sorted union of keys across
/// the result set), so rows identify their grid point / sampled values
/// directly instead of only through the rendered label.
///
/// With `cost` set, run-level accounting columns are appended per row:
/// issued / computed / cache hit rate per probe kind (the
/// `*_cache_hit_rate` columns use the one shared definition,
/// [`crate::dse::ProbeCounts::cache_hit_rate`] = cached / issued, and
/// match the `explore` summary digit for digit), the search shape
/// (`grid_size`, `budget`, `spent`), when the run used the
/// learned surrogate its fit/prediction counts, probes saved, and
/// mean absolute prediction error per objective, and — when the caller
/// timed the run — the wall-clock seconds (`wall_s`) and computed
/// probes per second (`probes_per_s`).  Aggregates over the
/// whole run, identical on every row, so a CSV consumer can join cost
/// onto any slice of the result set.  Computed counts are
/// wall-clock-style diagnostics (see [`crate::dse::ProbeStats`]), not
/// replay-comparable data.
pub fn front_csv(out: &ExploreOutcome, cost: Option<&SearchCost>) -> CsvWriter {
    let on_front: HashSet<usize> = out.front.iter().copied().collect();
    let cfg_keys: BTreeSet<&str> = out
        .results
        .iter()
        .flat_map(|r| r.cfg.iter().map(|(k, _)| k.as_str()))
        .collect();
    let mut header =
        vec!["variant", "accuracy", "dsp", "lut", "latency_ns", "power_w", "on_front"];
    if cost.is_some() {
        header.extend([
            "train_issued",
            "train_computed",
            "train_cache_hit_rate",
            "hw_issued",
            "hw_computed",
            "hw_cache_hit_rate",
            "grid_size",
            "budget",
            "spent",
            "sur_fits",
            "sur_predictions",
            "sur_probes_saved",
            "sur_mae_accuracy",
            "sur_mae_dsp",
            "sur_mae_lut",
            "sur_mae_latency_ns",
            "wall_s",
            "probes_per_s",
        ]);
    }
    header.extend(cfg_keys.iter().copied());
    let hit_rate = |issued: usize, computed: usize| {
        crate::dse::ProbeCounts::cache_hit_rate(issued, computed)
            .map(|r| format!("{r:.4}"))
            .unwrap_or_default()
    };
    let mut w = CsvWriter::new(&header);
    for (i, r) in out.results.iter().enumerate() {
        let g = |name: &str| r.metric(name).map(|v| format!("{v}")).unwrap_or_default();
        let mut row = vec![
            r.label.clone(),
            g("accuracy"),
            g("dsp"),
            g("lut"),
            g("latency_ns"),
            g("power_w"),
            if on_front.contains(&i) { "1".into() } else { "0".into() },
        ];
        if let Some(c) = cost {
            row.extend([
                c.probes.train_issued.to_string(),
                c.probes.train_computed.to_string(),
                hit_rate(c.probes.train_issued, c.probes.train_computed),
                c.probes.hw_issued.to_string(),
                c.probes.hw_computed.to_string(),
                hit_rate(c.probes.hw_issued, c.probes.hw_computed),
                c.grid_size.to_string(),
                c.budget.to_string(),
                c.spent.to_string(),
            ]);
            // surrogate columns stay in the header (stable schema) but
            // are blank for runs that never enabled it
            match &c.surrogate {
                Some(s) => {
                    row.extend([
                        s.fits.to_string(),
                        s.predictions.to_string(),
                        s.probes_saved().to_string(),
                    ]);
                    for o in 0..4 {
                        row.push(
                            s.mean_abs_error
                                .get(o)
                                .map(|e| format!("{e}"))
                                .unwrap_or_default(),
                        );
                    }
                }
                None => row.extend(vec![String::new(); 7]),
            }
            // wall-clock columns: blank when the caller didn't time the
            // run (wall_s is a diagnostic, never replay-comparable)
            if c.wall_secs > 0.0 {
                let computed = c.probes.train_computed + c.probes.hw_computed;
                row.push(format!("{:.3}", c.wall_secs));
                row.push(format!("{:.1}", computed as f64 / c.wall_secs));
            } else {
                row.extend([String::new(), String::new()]);
            }
        }
        for &key in &cfg_keys {
            row.push(
                r.cfg
                    .iter()
                    .find(|(k, _)| k == key)
                    .map(|(_, v)| render_value(v))
                    .unwrap_or_default(),
            );
        }
        w.row(&row);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::ProbeCounts;

    /// The explorer's objective mapping: (acc ↑, dsp ↓, lut ↓, lat ↓)
    /// points into the minimizing vectors [`VariantResult::min_objectives`]
    /// produces (accuracy negated).
    fn front4(pts: &[(f64, f64, f64, f64)]) -> Vec<usize> {
        let min_points: Vec<Vec<f64>> = pts
            .iter()
            .map(|&(acc, dsp, lut, lat)| vec![-acc, dsp, lut, lat])
            .collect();
        crate::search::pareto::pareto_front_min(&min_points)
    }

    #[test]
    fn explorer_objectives_front_basics() {
        // (acc, dsp, lut, latency_ns)
        let pts = vec![
            (0.76, 100.0, 5000.0, 50.0), // on front (best acc)
            (0.75, 40.0, 2000.0, 50.0),  // on front (cheap, nearly as good)
            (0.74, 120.0, 6000.0, 60.0), // dominated by 0 and 1
            (0.70, 40.0, 2000.0, 50.0),  // dominated by 1
        ];
        assert_eq!(front4(&pts), vec![0, 1]);
    }

    #[test]
    fn explorer_objectives_keep_latency_tradeoff() {
        // identical accuracy: a high-reuse variant (cheap, slow) and a
        // fully-unrolled one (expensive, fast) are both non-dominated
        let pts = vec![(0.75, 200.0, 9000.0, 40.0), (0.75, 30.0, 1500.0, 160.0)];
        assert_eq!(front4(&pts), vec![0, 1]);
    }

    #[test]
    fn explorer_objectives_keep_ties() {
        let pts = vec![(0.5, 10.0, 10.0, 1.0), (0.5, 10.0, 10.0, 1.0)];
        assert_eq!(front4(&pts), vec![0, 1]);
        assert!(front4(&[]).is_empty());
        assert_eq!(front4(&[(0.1, 1.0, 1.0, 1.0)]), vec![0]);
    }

    #[test]
    fn expand_variants_cartesian_product() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [["a", "b"]],
                "explore": {
                  "orders": [["a", "b"], ["b", "a"]],
                  "cfg_grid": {"k": [1, 2]}
                }}"#,
        )
        .unwrap();
        let variants = expand_variants(&spec).unwrap();
        assert_eq!(variants.len(), 4);
        assert_eq!(spec.explore.as_ref().unwrap().n_variants(), 4);
        let labels: Vec<&str> = variants.iter().map(|v| v.label.as_str()).collect();
        assert_eq!(labels, vec!["a-b k=1", "a-b k=2", "b-a k=1", "b-a k=2"]);
        // order variants are chains in the given order
        let ba = &variants[2].spec.graph;
        let order = ba.topo_order().unwrap();
        let names: Vec<&str> =
            order.iter().map(|&i| ba.node(i).unwrap().instance.as_str()).collect();
        assert_eq!(names, vec!["b", "a"]);
        // cfg points carried per variant
        assert_eq!(variants[1].cfg.len(), 1);
        assert_eq!(variants[1].cfg[0].1.as_f64(), Some(2.0));
    }

    #[test]
    fn variant_for_matches_grid_expansion() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [["a", "b"]],
                "explore": {
                  "orders": [["b", "a"]],
                  "cfg_grid": {"k": [2]}
                }}"#,
        )
        .unwrap();
        let all = expand_variants(&spec).unwrap();
        let expanded = &all[0];
        let built = variant_for(
            &spec,
            Some(&["b".to_string(), "a".to_string()]),
            vec![("k".to_string(), Value::Number(2.0))],
        )
        .unwrap();
        assert_eq!(built.label, expanded.label);
        assert_eq!(built.cfg, expanded.cfg);
        assert_eq!(format!("{:?}", built.spec.graph), format!("{:?}", expanded.spec.graph));
        // the base variant keeps the flow's name
        assert_eq!(variant_for(&spec, None, vec![]).unwrap().label, "t");
    }

    fn fake_result(label: &str, cfg: Vec<(String, Value)>, acc: f64) -> VariantResult {
        VariantResult {
            label: label.into(),
            cfg,
            metrics: [
                ("accuracy".to_string(), acc),
                ("dsp".to_string(), 10.0),
                ("lut".to_string(), 100.0),
                ("latency_ns".to_string(), 50.0),
            ]
            .into_iter()
            .collect(),
            n_models: 1,
            events: vec![],
        }
    }

    #[test]
    fn front_csv_gains_cfg_override_columns() {
        let results = vec![
            fake_result("a k=1", vec![("k".into(), Value::Number(1.0))], 0.9),
            fake_result("b", vec![("m".into(), Value::String("x".into()))], 0.8),
        ];
        let front = front_of(&results).unwrap();
        assert_eq!(front, vec![0]); // result 1 is dominated (lower accuracy)
        let csv = front_csv(&ExploreOutcome { results, front }, None).render();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert_eq!(
            header,
            "variant,accuracy,dsp,lut,latency_ns,power_w,on_front,k,m"
        );
        let rows: Vec<&str> = lines.collect();
        assert!(rows[0].starts_with("a k=1,0.9,"), "{}", rows[0]);
        assert!(rows[0].ends_with(",1,1,"), "{}", rows[0]);
        assert!(rows[1].ends_with(",0,,x"), "{}", rows[1]);
    }

    #[test]
    fn front_csv_appends_probe_columns_when_given_counts() {
        let results = vec![fake_result("a", vec![], 0.9)];
        let front = front_of(&results).unwrap();
        let cost = SearchCost {
            probes: ProbeCounts {
                train_issued: 40,
                train_computed: 10,
                hw_issued: 8,
                hw_computed: 8,
                ..Default::default()
            },
            grid_size: 16,
            budget: 12,
            spent: 12,
            surrogate: None,
            wall_secs: 0.0,
        };
        let csv = front_csv(&ExploreOutcome { results, front }, Some(&cost)).render();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "variant,accuracy,dsp,lut,latency_ns,power_w,on_front,\
             train_issued,train_computed,train_cache_hit_rate,hw_issued,hw_computed,hw_cache_hit_rate,\
             grid_size,budget,spent,sur_fits,sur_predictions,sur_probes_saved,\
             sur_mae_accuracy,sur_mae_dsp,sur_mae_lut,sur_mae_latency_ns,\
             wall_s,probes_per_s"
        );
        // 75% of training probes were cache hits; no hardware hits;
        // the surrogate and wall-clock columns are blank for a
        // surrogate-less, untimed run
        assert!(
            lines
                .next()
                .unwrap()
                .ends_with(",1,40,10,0.7500,8,8,0.0000,16,12,12,,,,,,,,,"),
            "{csv}"
        );
    }

    #[test]
    fn front_csv_fills_wall_clock_columns_when_timed() {
        let results = vec![fake_result("a", vec![], 0.9)];
        let front = front_of(&results).unwrap();
        let cost = SearchCost {
            probes: ProbeCounts {
                train_issued: 40,
                train_computed: 10,
                hw_issued: 8,
                hw_computed: 8,
                ..Default::default()
            },
            grid_size: 16,
            budget: 12,
            spent: 12,
            surrogate: None,
            wall_secs: 2.0,
        };
        let csv = front_csv(&ExploreOutcome { results, front }, Some(&cost)).render();
        let row = csv.lines().nth(1).unwrap();
        // 18 computed probes over 2 s → 9.0 probes/s
        assert!(row.ends_with(",2.000,9.0"), "{csv}");
    }

    #[test]
    fn front_csv_fills_surrogate_columns_from_the_report() {
        let results = vec![fake_result("a", vec![], 0.9)];
        let front = front_of(&results).unwrap();
        let cost = SearchCost {
            probes: ProbeCounts { train_issued: 10, ..Default::default() },
            grid_size: 24,
            budget: 24,
            spent: 24,
            surrogate: Some(crate::search::SurrogateReport {
                fits: 3,
                predictions: 20,
                deferred: 15,
                validated: 2,
                mean_abs_error: vec![0.5, 1.0, 2.0, 4.0],
            }),
            wall_secs: 0.0,
        };
        let csv = front_csv(&ExploreOutcome { results, front }, Some(&cost)).render();
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",24,24,24,3,20,13,0.5,1,2,4,,"), "{csv}");
    }

    #[test]
    fn expand_without_explore_is_single_base_variant() {
        let spec = FlowSpec::parse(
            r#"{"name": "solo", "tasks": [{"id": "a", "type": "X"}], "edges": []}"#,
        )
        .unwrap();
        let variants = expand_variants(&spec).unwrap();
        assert_eq!(variants.len(), 1);
        assert_eq!(variants[0].label, "solo");
        assert!(variants[0].cfg.is_empty());
    }

    #[test]
    fn orders_reject_guards_and_back_edges() {
        // silently flattening guards into plain chains would compare
        // architectures the user never declared
        let err = expand_variants(
            &FlowSpec::parse(
                r#"{"name": "t",
                    "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                    "edges": [{"from": "a", "to": "b",
                               "when": {"metric": "a.acc", "op": ">=", "value": 0.5}}],
                    "explore": {"orders": [["a", "b"]]}}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("conditional edges"), "{err}");

        let err = expand_variants(
            &FlowSpec::parse(
                r#"{"name": "t",
                    "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                    "edges": [["a", "b"]],
                    "back_edges": [{"from": "b", "to": "a", "max_iters": 2}],
                    "explore": {"orders": [["a", "b"]]}}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("back edges"), "{err}");
    }

    #[test]
    fn order_must_be_permutation() {
        let err = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [["a", "b"]],
                "explore": {"orders": [["a"]]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("permutation"), "{err}");
    }
}
