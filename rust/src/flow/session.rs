//! Process-wide session: execution runtime + manifest + caches.
//!
//! Tasks are stateless; everything expensive (backend-bound executables,
//! synthesized datasets) is cached here and shared across the whole flow
//! (and across flows in a bench run).
//!
//! The session no longer assumes PJRT: it is constructed over any
//! [`Runtime`] (see [`crate::runtime::ExecBackend`]).  The convenience
//! constructors use [`Runtime::cpu`], which defaults to the pure-Rust
//! reference interpreter and honors `METAML_BACKEND=xla` when the PJRT
//! backend is compiled in.
//!
//! One session is shared by every DSE probe worker (`Session` is
//! `Send + Sync`): the executable/dataset caches are `Mutex`-guarded
//! maps of `Arc` handles, and the lock is held across a cache miss so
//! racing workers bind a variant exactly once.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};

use crate::data::{Dataset, DatasetSpec};
use crate::error::Result;
use crate::runtime::{Manifest, ModelExecutable, Runtime};

pub struct Session {
    pub runtime: Runtime,
    pub manifest: Manifest,
    execs: Mutex<HashMap<String, Arc<ModelExecutable>>>,
    datasets: Mutex<HashMap<String, Arc<Dataset>>>,
}

impl Session {
    /// Session over an explicit backend runtime and manifest.
    pub fn with_backend(runtime: Runtime, manifest: Manifest) -> Self {
        Session {
            runtime,
            manifest,
            execs: Mutex::new(HashMap::new()),
            datasets: Mutex::new(HashMap::new()),
        }
    }

    /// Session over an explicit backend runtime, loading the manifest
    /// from an artifacts directory.
    pub fn open_with(runtime: Runtime, artifacts_dir: &str) -> Result<Self> {
        Ok(Self::with_backend(runtime, Manifest::load(artifacts_dir)?))
    }

    /// Default-backend session over an artifacts directory.
    pub fn open(artifacts_dir: &str) -> Result<Self> {
        Self::open_with(Runtime::cpu()?, artifacts_dir)
    }

    /// Session with a live runtime but an empty manifest — for
    /// engine/flow tests that use mock tasks and never touch artifacts.
    pub fn without_artifacts() -> Result<Self> {
        Ok(Self::with_backend(Runtime::cpu()?, Manifest::empty()))
    }

    /// Backend-bound train+eval executable for a variant tag (cached).
    pub fn executable(&self, tag: &str) -> Result<Arc<ModelExecutable>> {
        let mut execs = self.execs.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(e) = execs.get(tag) {
            return Ok(e.clone());
        }
        let exec = Arc::new(ModelExecutable::load(&self.runtime, &self.manifest, tag)?);
        execs.insert(tag.to_string(), exec.clone());
        Ok(exec)
    }

    /// The synthetic dataset for a model family (cached; generation is
    /// deterministic so every task sees identical data).
    pub fn dataset(&self, model: &str) -> Result<Arc<Dataset>> {
        let mut datasets = self.datasets.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(d) = datasets.get(model) {
            return Ok(d.clone());
        }
        let variant = self
            .manifest
            .variants
            .iter()
            .find(|v| v.model == model)
            .ok_or_else(|| crate::Error::Manifest(format!("no model {model}")))?;
        let spec =
            DatasetSpec::for_model(model, &variant.input_shape, variant.n_classes);
        let data = Arc::new(Dataset::generate(&spec));
        datasets.insert(model.to_string(), data.clone());
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn session_is_shareable_across_probe_workers() {
        assert_send_sync::<Session>();
        assert_send_sync::<Arc<ModelExecutable>>();
        assert_send_sync::<Arc<Dataset>>();
    }
}
