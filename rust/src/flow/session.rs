//! Process-wide session: PJRT runtime + manifest + caches.
//!
//! Tasks are stateless; everything expensive (compiled executables,
//! synthesized datasets) is cached here and shared across the whole flow
//! (and across flows in a bench run).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::data::{Dataset, DatasetSpec};
use crate::error::Result;
use crate::runtime::{Manifest, ModelExecutable, Runtime};

pub struct Session {
    pub runtime: Runtime,
    pub manifest: Manifest,
    execs: RefCell<HashMap<String, Rc<ModelExecutable>>>,
    datasets: RefCell<HashMap<String, Rc<Dataset>>>,
}

impl Session {
    pub fn open(artifacts_dir: &str) -> Result<Self> {
        Ok(Session {
            runtime: Runtime::cpu()?,
            manifest: Manifest::load(artifacts_dir)?,
            execs: RefCell::new(HashMap::new()),
            datasets: RefCell::new(HashMap::new()),
        })
    }

    /// Session with a live PJRT runtime but an empty manifest — for
    /// engine/flow tests that use mock tasks and never touch artifacts.
    pub fn without_artifacts() -> Result<Self> {
        Ok(Session {
            runtime: Runtime::cpu()?,
            manifest: Manifest::empty(),
            execs: RefCell::new(HashMap::new()),
            datasets: RefCell::new(HashMap::new()),
        })
    }

    /// Compiled train+eval executables for a variant tag (cached).
    pub fn executable(&self, tag: &str) -> Result<Rc<ModelExecutable>> {
        if let Some(e) = self.execs.borrow().get(tag) {
            return Ok(e.clone());
        }
        let exec = Rc::new(ModelExecutable::load(&self.runtime, &self.manifest, tag)?);
        self.execs.borrow_mut().insert(tag.to_string(), exec.clone());
        Ok(exec)
    }

    /// The synthetic dataset for a model family (cached; generation is
    /// deterministic so every task sees identical data).
    pub fn dataset(&self, model: &str) -> Result<Rc<Dataset>> {
        if let Some(d) = self.datasets.borrow().get(model) {
            return Ok(d.clone());
        }
        let variant = self
            .manifest
            .variants
            .iter()
            .find(|v| v.model == model)
            .ok_or_else(|| crate::Error::Manifest(format!("no model {model}")))?;
        let spec =
            DatasetSpec::for_model(model, &variant.input_shape, variant.n_classes);
        let data = Rc::new(Dataset::generate(&spec));
        self.datasets.borrow_mut().insert(model.to_string(), data.clone());
        Ok(data)
    }
}
