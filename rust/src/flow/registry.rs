//! Task registry: resolves task-type names to constructors.
//!
//! The registry is what makes flows *recomposable from config*: a flow
//! spec references tasks by name, the registry instantiates them, and
//! users register custom tasks alongside the built-ins (see
//! examples/custom_flow.rs).  `table()` renders the paper's Table I.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::flow::task::PipeTask;

// Constructors are `Send + Sync` so one registry serves every worker
// of the multi-flow explorer (task objects themselves are created and
// used within a single worker thread).
type Ctor = Box<dyn Fn() -> Box<dyn PipeTask> + Send + Sync>;

#[derive(Default)]
pub struct TaskRegistry {
    ctors: BTreeMap<String, Ctor>,
}

impl TaskRegistry {
    pub fn empty() -> Self {
        Self::default()
    }

    /// Registry pre-populated with the paper's Table I tasks.
    pub fn builtin() -> Self {
        use crate::tasks;
        let mut r = Self::empty();
        r.register("KERAS-MODEL-GEN", || Box::new(tasks::ModelGenTask));
        r.register("PRUNING", || Box::new(tasks::PruningTask));
        r.register("SCALING", || Box::new(tasks::ScalingTask));
        r.register("QUANTIZATION", || Box::new(tasks::QuantizationTask));
        r.register("HLS4ML", || Box::new(tasks::Hls4mlTask));
        r.register("REUSE_SEARCH", || Box::new(tasks::ReuseSearchTask));
        r.register("VIVADO-HLS", || Box::new(tasks::VivadoHlsTask));
        r
    }

    pub fn register(
        &mut self,
        name: impl Into<String>,
        ctor: impl Fn() -> Box<dyn PipeTask> + Send + Sync + 'static,
    ) {
        self.ctors.insert(name.into(), Box::new(ctor));
    }

    pub fn create(&self, name: &str) -> Result<Box<dyn PipeTask>> {
        self.ctors
            .get(name)
            .map(|c| c())
            .ok_or_else(|| Error::Flow(format!("unknown task type {name:?}")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.ctors.keys().map(String::as_str).collect()
    }

    /// Render the implemented-task table (paper Table I).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str("| Type | Role | Multiplicity | Parameters |\n");
        out.push_str("|------|------|--------------|------------|\n");
        for name in self.names() {
            let t = self.create(name).unwrap();
            let (i, o) = t.multiplicity();
            let params: Vec<String> = t
                .params()
                .iter()
                .map(|p| match p.default {
                    Some(d) => format!("{} (={})", p.name, d),
                    None => p.name.to_string(),
                })
                .collect();
            out.push_str(&format!(
                "| {} | {} | {}-to-{} | {} |\n",
                t.name(),
                t.role(),
                i,
                o,
                params.join(", ")
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::task::{ParamSpec, TaskCtx, TaskOutcome, TaskRole};

    struct Dummy;
    impl PipeTask for Dummy {
        fn name(&self) -> &str {
            "DUMMY"
        }
        fn role(&self) -> TaskRole {
            TaskRole::Optimization
        }
        fn multiplicity(&self) -> (usize, usize) {
            (1, 1)
        }
        fn params(&self) -> Vec<ParamSpec> {
            vec![]
        }
        fn run(&self, _ctx: &mut TaskCtx) -> crate::Result<TaskOutcome> {
            Ok(TaskOutcome::default())
        }
    }

    #[test]
    fn register_and_create() {
        let mut r = TaskRegistry::empty();
        r.register("DUMMY", || Box::new(Dummy));
        assert!(r.create("DUMMY").is_ok());
        assert!(r.create("NOPE").is_err());
        assert_eq!(r.names(), vec!["DUMMY"]);
    }

    #[test]
    fn builtin_has_table1_tasks() {
        let r = TaskRegistry::builtin();
        for name in [
            "KERAS-MODEL-GEN",
            "PRUNING",
            "SCALING",
            "QUANTIZATION",
            "HLS4ML",
            "REUSE_SEARCH",
            "VIVADO-HLS",
        ] {
            assert!(r.create(name).is_ok(), "{name} missing");
        }
        let table = r.table();
        assert!(table.contains("PRUNING"));
        assert!(table.contains("tolerate_acc_loss"));
    }
}
