//! Design-flow architecture (paper §III): pipe tasks + cyclic task graphs.
//!
//! A design flow is a directed graph whose nodes are **pipe-task
//! instances** and whose edges are dependencies ("complete A before B").
//! Forward edges define a deterministic topological execution order;
//! *back edges* make the graph cyclic and express iteration — the engine
//! re-executes the enclosed sub-path while the back edge's source task
//! requests another pass (bounded by `max_iters`).
//!
//! Tasks communicate exclusively through the [crate::metamodel::MetaModel],
//! never directly — that is what makes flows recomposable (Fig 2: swapping
//! the order of SCALING/PRUNING/QUANTIZATION is an edge-list change).
//!
//! The composable-IR extensions (conditional edges, strategy nodes,
//! sub-flow flattening) live in [graph] and [crate::config::spec]; the
//! [engine] is a small control-flow VM over that IR, and [explore] runs
//! many flow *architectures* concurrently and reports a Pareto front.

pub mod engine;
pub mod explore;
pub mod graph;
pub mod registry;
pub mod session;
pub mod task;

pub use engine::Engine;
pub use explore::{ExploreOutcome, ExploreSpec, FlowVariant, VariantResult};
pub use graph::{CmpOp, EdgeGuard, FlowGraph, FlowPlan, NodeId, NodeKind, StrategyArm};
pub use registry::TaskRegistry;
pub use session::Session;
pub use task::{ParamSpec, PipeTask, TaskCtx, TaskOutcome, TaskRole};
