//! The flow execution engine.
//!
//! Executes a validated flow graph against a meta-model: forward edges in
//! deterministic topological order, back edges as bounded iteration of
//! their enclosed sub-path.  Task orchestration stays on the coordinator
//! thread (tasks mutate the shared meta-model), while O-tasks fan their
//! candidate probes out across the [`crate::dse::ProbePool`] worker
//! threads.  Determinism is part of the contract regardless of worker
//! count — re-running a flow with the same CFG and seed reproduces the
//! LOG bit for bit.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::flow::graph::{FlowGraph, NodeId};
use crate::flow::registry::TaskRegistry;
use crate::flow::session::Session;
use crate::flow::task::{TaskCtx, TaskOutcome};
use crate::metamodel::{LogEvent, MetaModel};

pub struct Engine<'a> {
    pub session: &'a Session,
    pub registry: &'a TaskRegistry,
}

impl<'a> Engine<'a> {
    pub fn new(session: &'a Session, registry: &'a TaskRegistry) -> Self {
        Engine { session, registry }
    }

    /// Execute `graph` against `meta`. Returns the per-node outcomes of
    /// the final pass over each node.
    pub fn run(&self, graph: &FlowGraph, meta: &mut MetaModel) -> Result<Vec<TaskOutcome>> {
        let order = graph.validate()?;
        // multiplicity check: a task demanding k inputs must have k
        // incoming forward edges (0-to-1 tasks are sources, etc.).
        // In-degrees are computed once for the whole graph (one pass over
        // the edge set) rather than per node.
        let in_degrees = graph.in_degrees();
        for node in graph.nodes() {
            let task = self.registry.create(&node.task_type)?;
            let (want_in, _) = task.multiplicity();
            let have = in_degrees[node.id];
            if have != want_in {
                return Err(Error::Flow(format!(
                    "task {} ({}) is {}-input but has {} incoming edges",
                    node.instance,
                    node.task_type,
                    want_in,
                    have
                )));
            }
        }

        meta.log.push(LogEvent::FlowStarted { flow: graph.name.clone() });
        let mut outcomes: Vec<TaskOutcome> =
            vec![TaskOutcome::default(); graph.nodes().len()];

        let mut pc = 0usize; // index into topo order
        // remaining re-execution budget per back edge: max_iters bounds
        // how many times the enclosed sub-path is *re*-executed, so a
        // max_iters == 1 edge fires exactly once (the initial pass is
        // not counted against the budget)
        let mut budgets: Vec<usize> =
            graph.back_edges().iter().map(|b| b.max_iters).collect();

        while pc < order.len() {
            let node_id = order[pc];
            let outcome = self.run_node(graph, meta, node_id)?;
            let iterate = outcome.request_iteration;
            outcomes[node_id] = outcome;

            // back edge whose source is this node and which still has
            // budget fires if the task requested iteration
            let mut jumped = false;
            if iterate {
                for (i, be) in graph.back_edges().iter().enumerate() {
                    if be.from == node_id && budgets[i] > 0 {
                        budgets[i] -= 1;
                        let target_pos = order
                            .iter()
                            .position(|&n| n == be.to)
                            .expect("validated back edge");
                        meta.log.push(LogEvent::IterationAdvanced {
                            task: graph.node(node_id)?.instance.clone(),
                            iteration: be.max_iters - budgets[i],
                        });
                        pc = target_pos;
                        jumped = true;
                        break;
                    }
                }
            }
            if !jumped {
                pc += 1;
            }
        }

        meta.log.push(LogEvent::FlowFinished { flow: graph.name.clone() });
        Ok(outcomes)
    }

    fn run_node(
        &self,
        graph: &FlowGraph,
        meta: &mut MetaModel,
        node_id: NodeId,
    ) -> Result<TaskOutcome> {
        let node = graph.node(node_id)?.clone();
        let task = self.registry.create(&node.task_type)?;
        meta.log.push(LogEvent::TaskStarted { task: node.instance.clone() });
        let t0 = Instant::now();
        let mut ctx = TaskCtx {
            meta,
            session: self.session,
            instance: node.instance.clone(),
        };
        let outcome = task.run(&mut ctx).map_err(|e| Error::Task {
            task: node.instance.clone(),
            msg: e.to_string(),
        })?;
        meta.log.push(LogEvent::TaskFinished {
            task: node.instance.clone(),
            secs: t0.elapsed().as_secs_f64(),
        });
        Ok(outcome)
    }
}
