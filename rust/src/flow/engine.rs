//! The flow execution engine: a small control-flow VM over the flow IR.
//!
//! Executes a validated flow graph against a meta-model.  The VM walks
//! the deterministic topological order with:
//!
//! * **guarded successor selection** — a node runs iff it is a source
//!   or at least one incoming forward edge is *taken* (its origin ran
//!   and its guard, if any, holds against the meta-model metrics);
//!   otherwise the node is skipped, and skipping propagates downstream;
//! * **strategy (S-task) nodes** — the first arm whose `when` guard
//!   passes (or the first unguarded arm) is selected and its child flow
//!   is executed inline with `"{instance}."`-prefixed task names;
//! * **bounded back edges** — per-edge re-execution budgets, with
//!   O(1) jump targets via the precomputed topo-position map.
//!
//! Every control decision (guard evaluation, skip, arm selection,
//! iteration) is recorded in the LOG, so runs stay bit-for-bit
//! reproducible: task orchestration is sequential on the coordinator
//! thread, O-tasks fan probes out across the [`crate::dse::ProbePool`],
//! and wall-clock data (durations) goes to the LOG side-note table,
//! never the event stream.

use std::time::Instant;

use crate::config::FlowSpec;
use crate::dse::ProbeTiers;
use crate::error::{Error, Result};
use crate::flow::graph::{EdgeGuard, FlowGraph, FlowPlan, NodeId, NodeKind, StrategyArm};
use crate::flow::registry::TaskRegistry;
use crate::flow::session::Session;
use crate::flow::task::{TaskCtx, TaskOutcome};
use crate::metamodel::{LogEvent, MetaModel};
use crate::obs::trace;

pub struct Engine<'a> {
    pub session: &'a Session,
    pub registry: &'a TaskRegistry,
    /// When set (multi-flow exploration), every O-task probe service in
    /// this engine shares one tier stack per probe kind (training *and*
    /// hardware, optionally disk-backed), deduplicating identical
    /// candidate evaluations across flow variants.
    services: Option<ProbeTiers>,
}

impl<'a> Engine<'a> {
    pub fn new(session: &'a Session, registry: &'a TaskRegistry) -> Self {
        Engine { session, registry, services: None }
    }

    /// Engine whose tasks share `services` tiers for probe memoization
    /// (used by [`crate::flow::explore`] to deduplicate across
    /// variants, and by the CLI to persist under `--cache-dir`).
    pub fn with_services(
        session: &'a Session,
        registry: &'a TaskRegistry,
        services: ProbeTiers,
    ) -> Self {
        Engine { session, registry, services: Some(services) }
    }

    /// Execute `graph` against `meta`. Returns the per-node outcomes of
    /// the final pass over each node (default outcomes for skipped
    /// nodes).  Validates the graph once; callers holding a parsed
    /// [`FlowSpec`] should prefer [`run_spec`](Self::run_spec), which
    /// reuses the plan computed at parse time.
    pub fn run(&self, graph: &FlowGraph, meta: &mut MetaModel) -> Result<Vec<TaskOutcome>> {
        let plan = graph.validate()?;
        self.run_graph(graph, &plan, meta, "")
    }

    /// Execute a parsed spec, reusing its parse-time validation plan
    /// (no re-validation, no topo recomputation).  A graph mutated
    /// after parsing (`spec.graph` is public) is detected by the
    /// plan's node/edge counts and replanned instead of running
    /// against stale positions.
    pub fn run_spec(&self, spec: &FlowSpec, meta: &mut MetaModel) -> Result<Vec<TaskOutcome>> {
        if !spec.plan().matches(&spec.graph) {
            return self.run(&spec.graph, meta);
        }
        self.run_graph(&spec.graph, spec.plan(), meta, "")
    }

    /// The VM proper.  `prefix` namespaces task instances of nested
    /// strategy-arm flows ("opt.prune").
    fn run_graph(
        &self,
        graph: &FlowGraph,
        plan: &FlowPlan,
        meta: &mut MetaModel,
        prefix: &str,
    ) -> Result<Vec<TaskOutcome>> {
        self.check_multiplicity(graph, plan, !prefix.is_empty())?;

        let flow_name = format!("{prefix}{}", graph.name);
        let mut flow_span = trace::span("flow", "flow.run");
        flow_span.arg("flow", flow_name.as_str());
        meta.log.push(LogEvent::FlowStarted { flow: flow_name.clone() });

        let n = graph.nodes().len();
        // incoming forward edges per node, in deterministic (from, to)
        // order — one pass over the edge map
        let mut in_edges: Vec<Vec<(NodeId, Option<&EdgeGuard>)>> = vec![Vec::new(); n];
        for (f, t, g) in graph.guarded_edges() {
            in_edges[t].push((f, g));
        }

        let mut outcomes: Vec<TaskOutcome> = vec![TaskOutcome::default(); n];
        // ran[v]: v executed (not skipped) in the current pass
        let mut ran = vec![false; n];
        // remaining re-execution budget per back edge: max_iters bounds
        // how many times the enclosed sub-path is *re*-executed, so a
        // max_iters == 1 edge fires exactly once (the initial pass is
        // not counted against the budget)
        let mut budgets: Vec<usize> =
            graph.back_edges().iter().map(|b| b.max_iters).collect();

        let mut pc = 0usize; // index into topo order
        while pc < plan.order.len() {
            let node_id = plan.order[pc];
            let node = graph.node(node_id)?;
            let instance = format!("{prefix}{}", node.instance);

            // guarded successor selection: evaluate EVERY in-edge whose
            // origin ran (no short-circuit — each decision is logged)
            let mut enabled = in_edges[node_id].is_empty();
            for &(from, guard) in &in_edges[node_id] {
                if !ran[from] {
                    continue;
                }
                match guard {
                    None => enabled = true,
                    Some(g) => {
                        let value = eval_guard(meta, prefix, g)?;
                        let taken = g.op.apply(value, g.value);
                        meta.log.push(LogEvent::EdgeEvaluated {
                            from: format!("{prefix}{}", graph.node(from)?.instance),
                            to: instance.clone(),
                            metric: g.metric.clone(),
                            value,
                            taken,
                        });
                        enabled = enabled || taken;
                    }
                }
            }

            if !enabled {
                meta.log.push(LogEvent::TaskSkipped { task: instance });
                // a node skipped on a back-edge re-pass must not keep
                // the outcome of a superseded earlier pass
                outcomes[node_id] = TaskOutcome::default();
                pc += 1;
                continue;
            }

            let outcome = self.run_node(meta, node, &instance, prefix)?;
            ran[node_id] = true;
            let iterate = outcome.request_iteration;
            outcomes[node_id] = outcome;

            // back edge whose source is this node and which still has
            // budget: an unguarded edge fires when the task requested
            // iteration; a guarded edge fires when its predicate holds
            // against the meta-model — the cross-stage feedback path
            // ("VIVADO-HLS → QUANTIZATION when synth.dsp > budget")
            let mut jumped = false;
            for (i, be) in graph.back_edges().iter().enumerate() {
                if be.from != node_id || budgets[i] == 0 {
                    continue;
                }
                let fire = match &be.when {
                    None => iterate,
                    Some(g) => {
                        let value = eval_guard(meta, prefix, g)?;
                        let taken = g.op.apply(value, g.value);
                        meta.log.push(LogEvent::EdgeEvaluated {
                            from: instance.clone(),
                            to: format!("{prefix}{}", graph.node(be.to)?.instance),
                            metric: g.metric.clone(),
                            value,
                            taken,
                        });
                        taken
                    }
                };
                if !fire {
                    continue;
                }
                budgets[i] -= 1;
                meta.log.push(LogEvent::IterationAdvanced {
                    task: instance.clone(),
                    iteration: be.max_iters - budgets[i],
                });
                // O(1) jump via the precomputed position map;
                // the re-executed range starts a fresh pass
                let target = plan.pos[be.to];
                for &v in &plan.order[target..=pc] {
                    ran[v] = false;
                }
                pc = target;
                jumped = true;
                break;
            }
            if !jumped {
                pc += 1;
            }
        }

        meta.log.push(LogEvent::FlowFinished { flow: flow_name });
        Ok(outcomes)
    }

    /// Multiplicity check against the plan's split in-degrees.  A task
    /// demanding k inputs must have exactly k unguarded incoming edges;
    /// when conditional edges are present the check relaxes to a range
    /// (every unguarded edge is always an input, and enough guarded
    /// edges must exist to possibly satisfy k).  Strategy nodes are
    /// exempt (their arms are checked when executed), and in a `nested`
    /// (strategy-arm) flow the entry nodes are too — they consume the
    /// outer flow's models through the shared meta-model.
    fn check_multiplicity(&self, graph: &FlowGraph, plan: &FlowPlan, nested: bool) -> Result<()> {
        for node in graph.nodes() {
            let task_type = match &node.kind {
                NodeKind::Task { task_type } => task_type,
                NodeKind::Strategy { .. } => continue,
            };
            let task = self.registry.create(task_type)?;
            let (want_in, _) = task.multiplicity();
            let plain = plan.in_plain[node.id];
            let guarded = plan.in_guarded[node.id];
            if nested && plain == 0 && guarded == 0 {
                continue;
            }
            let ok = if guarded == 0 {
                plain == want_in
            } else {
                plain <= want_in && plain + guarded >= want_in
            };
            if !ok {
                return Err(Error::Flow(format!(
                    "task {} ({}) is {}-input but has {} unconditional and {} conditional incoming edges",
                    node.instance, task_type, want_in, plain, guarded
                )));
            }
        }
        Ok(())
    }

    fn run_node(
        &self,
        meta: &mut MetaModel,
        node: &crate::flow::graph::FlowNode,
        instance: &str,
        prefix: &str,
    ) -> Result<TaskOutcome> {
        meta.log.push(LogEvent::TaskStarted { task: instance.to_string() });
        // opened before any probe work so pool batches nest under it
        let mut task_span = trace::span("flow", "flow.task");
        task_span.arg("instance", instance);
        if let NodeKind::Task { task_type } = &node.kind {
            task_span.arg("task", task_type.as_str());
        }
        let t0 = Instant::now();
        let outcome = match &node.kind {
            NodeKind::Task { task_type } => {
                let task = self.registry.create(task_type)?;
                let mut ctx = TaskCtx {
                    meta,
                    session: self.session,
                    instance: instance.to_string(),
                    services: self.services.clone(),
                };
                task.run(&mut ctx).map_err(|e| Error::Task {
                    task: instance.to_string(),
                    msg: e.to_string(),
                })?
            }
            NodeKind::Strategy { arms } => self.run_strategy(meta, instance, prefix, arms)?,
        };
        // duration is wall-clock: side table, never the event stream
        meta.log.note(instance, "secs", t0.elapsed().as_secs_f64());
        meta.log.push(LogEvent::TaskFinished { task: instance.to_string() });
        Ok(outcome)
    }

    /// Select and run one strategy arm.  Arms are tried in declaration
    /// order; every guard evaluation is logged, the first passing (or
    /// first unguarded) arm wins, and its flow runs inline with
    /// `"{instance}."`-prefixed task names.
    fn run_strategy(
        &self,
        meta: &mut MetaModel,
        instance: &str,
        prefix: &str,
        arms: &[StrategyArm],
    ) -> Result<TaskOutcome> {
        let mut selected: Option<&StrategyArm> = None;
        for arm in arms {
            match &arm.when {
                None => {
                    selected = Some(arm);
                    break;
                }
                Some(g) => {
                    let value = eval_guard(meta, prefix, g)?;
                    let taken = g.op.apply(value, g.value);
                    meta.log.push(LogEvent::EdgeEvaluated {
                        from: instance.to_string(),
                        to: arm.name.clone(),
                        metric: g.metric.clone(),
                        value,
                        taken,
                    });
                    if taken {
                        selected = Some(arm);
                        break;
                    }
                }
            }
        }
        let arm = selected.ok_or_else(|| {
            Error::Task {
                task: instance.to_string(),
                msg: "no strategy arm selected (all guards false and no default arm)"
                    .into(),
            }
        })?;
        meta.log.push(LogEvent::StrategySelected {
            task: instance.to_string(),
            arm: arm.name.clone(),
        });

        let plan = arm.flow.validate()?;
        let sub_prefix = format!("{instance}.");
        let sub_outcomes = self.run_graph(&arm.flow, &plan, meta, &sub_prefix)?;
        // an iteration request left over after the arm's own (bounded)
        // back edges bubbles up, so outer back edges sourced at the
        // strategy node keep the documented re-execution semantics
        Ok(TaskOutcome {
            request_iteration: sub_outcomes.iter().any(|o| o.request_iteration),
            produced: sub_outcomes.iter().flat_map(|o| o.produced.clone()).collect(),
        })
    }
}

/// Resolve a guard's metric against the meta-model: the latest LOG
/// metric of the referenced task (prefixed instance first, then the
/// bare name for cross-scope references), falling back to model-space
/// artifact metrics by producer.  A missing metric is a hard error —
/// guards over never-recorded metrics are spec bugs, not silent skips.
fn eval_guard(meta: &MetaModel, prefix: &str, guard: &EdgeGuard) -> Result<f64> {
    let mut edge_span = trace::span("flow", "flow.edge");
    edge_span.arg("metric", guard.metric.as_str());
    let (task, name) = guard.metric.rsplit_once('.').ok_or_else(|| {
        Error::Flow(format!(
            "guard metric {:?} must be \"<task>.<metric>\"",
            guard.metric
        ))
    })?;
    // current scope (prefixed) fully shadows the outer scope: LOG then
    // model-space under the prefix, and only then the bare-name
    // cross-scope fallbacks
    let prefixed = format!("{prefix}{task}");
    let nested = !prefix.is_empty();
    let value = meta
        .log
        .latest_metric(&prefixed, name)
        .or_else(|| meta.space.latest_metric(&prefixed, name))
        .or_else(|| if nested { meta.log.latest_metric(task, name) } else { None })
        .or_else(|| if nested { meta.space.latest_metric(task, name) } else { None });
    if let Some(v) = value {
        edge_span.arg("value", v);
    }
    value.ok_or_else(|| {
        Error::Flow(format!(
            "guard metric {:?} not found (no LOG metric or model-space metric \
             named {name:?} recorded by task {task:?})",
            guard.metric
        ))
    })
}
