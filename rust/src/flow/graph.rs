//! The design-flow graph: task instances + dependency edges (+ back edges).

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Error, Result};

pub type NodeId = usize;

/// A task instance in a flow.
#[derive(Debug, Clone)]
pub struct FlowNode {
    pub id: NodeId,
    /// Instance name, unique per flow ("pruning", "pruning2", …).
    pub instance: String,
    /// Task type name resolved against the registry ("PRUNING", …).
    pub task_type: String,
}

/// A back edge enabling iteration (cyclic design flows, paper §III).
#[derive(Debug, Clone, Copy)]
pub struct BackEdge {
    pub from: NodeId,
    pub to: NodeId,
    /// Hard bound on re-executions of the enclosed sub-path.
    pub max_iters: usize,
}

/// Directed flow graph.  Forward edges must be acyclic (validated); back
/// edges may close cycles and drive iteration.
#[derive(Debug, Default, Clone)]
pub struct FlowGraph {
    pub name: String,
    nodes: Vec<FlowNode>,
    edges: BTreeSet<(NodeId, NodeId)>,
    back_edges: Vec<BackEdge>,
}

impl FlowGraph {
    pub fn new(name: impl Into<String>) -> Self {
        FlowGraph { name: name.into(), ..Default::default() }
    }

    /// Add a task instance; returns its node id.
    pub fn add_task(&mut self, instance: impl Into<String>, task_type: impl Into<String>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(FlowNode {
            id,
            instance: instance.into(),
            task_type: task_type.into(),
        });
        id
    }

    /// Add a dependency edge from → to ("from completes before to").
    pub fn connect(&mut self, from: NodeId, to: NodeId) -> Result<()> {
        self.check_node(from)?;
        self.check_node(to)?;
        if from == to {
            return Err(Error::Flow(format!("self edge on node {from}")));
        }
        self.edges.insert((from, to));
        Ok(())
    }

    /// Add a back edge driving iteration of the sub-path to..=from.
    pub fn connect_back(&mut self, from: NodeId, to: NodeId, max_iters: usize) -> Result<()> {
        self.check_node(from)?;
        self.check_node(to)?;
        self.back_edges.push(BackEdge { from, to, max_iters });
        Ok(())
    }

    fn check_node(&self, id: NodeId) -> Result<()> {
        if id >= self.nodes.len() {
            return Err(Error::Flow(format!("unknown node {id}")));
        }
        Ok(())
    }

    pub fn nodes(&self) -> &[FlowNode] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> Result<&FlowNode> {
        self.nodes.get(id).ok_or_else(|| Error::Flow(format!("unknown node {id}")))
    }

    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.edges.iter().copied()
    }

    pub fn back_edges(&self) -> &[BackEdge] {
        &self.back_edges
    }

    /// In-degree over forward edges (multiplicity checking).
    pub fn in_degree(&self, id: NodeId) -> usize {
        self.edges.iter().filter(|(_, t)| *t == id).count()
    }

    /// All forward-edge in-degrees, indexable by [`NodeId`], computed in
    /// one pass over the edge set (the engine's multiplicity check is
    /// O(V + E) with this instead of O(V·E) via per-node [`in_degree`]).
    ///
    /// [`in_degree`]: FlowGraph::in_degree
    pub fn in_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.nodes.len()];
        for &(_, t) in &self.edges {
            deg[t] += 1;
        }
        deg
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.edges.iter().filter(|(f, _)| *f == id).count()
    }

    /// Deterministic topological order over the forward edges.
    ///
    /// Kahn's algorithm with the lowest-id tie-break, so the same graph
    /// always executes in the same order (the engine is single-threaded
    /// by design — the PJRT client is not Sync; parallel branches are
    /// interleaved deterministically instead).
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let mut indeg: BTreeMap<NodeId, usize> =
            self.nodes.iter().map(|n| (n.id, 0)).collect();
        for (_, t) in &self.edges {
            *indeg.get_mut(t).unwrap() += 1;
        }
        let mut ready: BTreeSet<NodeId> = indeg
            .iter()
            .filter(|(_, &d)| d == 0)
            .map(|(&id, _)| id)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(&id) = ready.iter().next() {
            ready.remove(&id);
            order.push(id);
            for (f, t) in &self.edges {
                if *f == id {
                    let d = indeg.get_mut(t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        ready.insert(*t);
                    }
                }
            }
        }
        if order.len() != self.nodes.len() {
            return Err(Error::Flow(
                "forward edges contain a cycle (use connect_back for iteration)"
                    .into(),
            ));
        }
        Ok(order)
    }

    /// Validate back edges: target must precede source in topo order.
    pub fn validate(&self) -> Result<Vec<NodeId>> {
        let order = self.topo_order()?;
        let pos: BTreeMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for be in &self.back_edges {
            if pos[&be.to] > pos[&be.from] {
                return Err(Error::Flow(format!(
                    "back edge {} -> {} does not point backwards",
                    be.from, be.to
                )));
            }
            if be.max_iters == 0 {
                return Err(Error::Flow("back edge max_iters must be >= 1".into()));
            }
        }
        Ok(order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> FlowGraph {
        let mut g = FlowGraph::new("chain");
        let a = g.add_task("gen", "KERAS-MODEL-GEN");
        let b = g.add_task("prune", "PRUNING");
        let c = g.add_task("hls", "HLS4ML");
        g.connect(a, b).unwrap();
        g.connect(b, c).unwrap();
        g
    }

    #[test]
    fn topo_order_of_chain() {
        assert_eq!(chain().topo_order().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn topo_order_deterministic_on_diamond() {
        let mut g = FlowGraph::new("diamond");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        let d = g.add_task("d", "T");
        g.connect(a, b).unwrap();
        g.connect(a, c).unwrap();
        g.connect(b, d).unwrap();
        g.connect(c, d).unwrap();
        // lowest-id tie-break => b before c
        assert_eq!(g.topo_order().unwrap(), vec![a, b, c, d]);
    }

    #[test]
    fn forward_cycle_rejected() {
        let mut g = FlowGraph::new("cyc");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        g.connect(a, b).unwrap();
        g.connect(b, a).unwrap();
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn self_edge_rejected() {
        let mut g = FlowGraph::new("s");
        let a = g.add_task("a", "T");
        assert!(g.connect(a, a).is_err());
    }

    #[test]
    fn back_edge_validation() {
        let mut g = chain();
        g.connect_back(2, 0, 3).unwrap();
        assert!(g.validate().is_ok());
        // forward-pointing back edge rejected
        let mut g2 = chain();
        g2.connect_back(0, 2, 3).unwrap();
        assert!(g2.validate().is_err());
        // zero max_iters rejected
        let mut g3 = chain();
        g3.connect_back(2, 0, 0).unwrap();
        assert!(g3.validate().is_err());
    }

    #[test]
    fn degrees() {
        let g = chain();
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.in_degree(1), 1);
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.out_degree(2), 0);
    }

    #[test]
    fn in_degrees_matches_per_node_scan() {
        let mut g = FlowGraph::new("diamond");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        let d = g.add_task("d", "T");
        g.connect(a, b).unwrap();
        g.connect(a, c).unwrap();
        g.connect(b, d).unwrap();
        g.connect(c, d).unwrap();
        let degs = g.in_degrees();
        assert_eq!(degs, vec![0, 1, 1, 2]);
        for id in 0..4 {
            assert_eq!(degs[id], g.in_degree(id));
        }
        // back edges must not contribute to forward in-degrees
        g.connect_back(d, a, 2).unwrap();
        assert_eq!(g.in_degrees(), vec![0, 1, 1, 2]);
    }

    #[test]
    fn unknown_node_errors() {
        let mut g = FlowGraph::new("x");
        let a = g.add_task("a", "T");
        assert!(g.connect(a, 99).is_err());
        assert!(g.node(99).is_err());
    }
}
