//! The design-flow graph IR: task instances + dependency edges (+ back
//! edges), extended with **conditional edges** and **S-task (strategy)
//! nodes** so one spec can describe alternative control paths.
//!
//! * Forward edges may carry an [`EdgeGuard`] — a predicate over
//!   meta-model metrics (`"prune.accuracy" >= 0.72`).  An unguarded
//!   edge is always taken; a guarded edge is taken only when its
//!   predicate holds at the moment the engine reaches the target node.
//! * A [`NodeKind::Strategy`] node holds a list of [`StrategyArm`]s —
//!   child flows of which exactly one is selected and executed at
//!   runtime (first arm whose `when` guard passes; an arm without a
//!   guard is the unconditional default).
//! * Back edges drive bounded re-execution of a sub-path; a guarded
//!   back edge fires on its metric predicate instead of a task-side
//!   iteration request (cross-stage feedback, e.g. hardware results
//!   re-triggering a DNN-stage search).
//!
//! The graph is pure structure; all evaluation (guards, arm selection,
//! skipping) happens in [`crate::flow::Engine`], which logs every
//! decision so runs stay reproducible.

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Error, Result};

pub type NodeId = usize;

/// Comparison operator of an [`EdgeGuard`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    /// Parse the spec-JSON operator spelling ("<", "<=", ">", ">=",
    /// "==", "!=").
    pub fn parse(s: &str) -> Result<CmpOp> {
        Ok(match s {
            "<" => CmpOp::Lt,
            "<=" => CmpOp::Le,
            ">" => CmpOp::Gt,
            ">=" => CmpOp::Ge,
            "==" => CmpOp::Eq,
            "!=" => CmpOp::Ne,
            other => {
                return Err(Error::Config(format!(
                    "unknown guard op {other:?} (expected <, <=, >, >=, ==, !=)"
                )))
            }
        })
    }

    /// Apply `lhs OP rhs`.
    pub fn apply(self, lhs: f64, rhs: f64) -> bool {
        match self {
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
            CmpOp::Eq => lhs == rhs,
            CmpOp::Ne => lhs != rhs,
        }
    }
}

impl std::fmt::Display for CmpOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
        };
        write!(f, "{s}")
    }
}

/// A predicate over meta-model metrics: `metric OP value`, where
/// `metric` is `"<task-instance>.<metric-name>"` (the engine reads the
/// latest LOG value, falling back to model-space artifact metrics).
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeGuard {
    pub metric: String,
    pub op: CmpOp,
    pub value: f64,
}

impl std::fmt::Display for EdgeGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {} {}", self.metric, self.op, self.value)
    }
}

/// One alternative of a strategy node: a named child flow plus an
/// optional selection guard.  Arms are tried in declaration order; the
/// first whose guard passes (or the first unguarded arm) is executed.
#[derive(Debug, Clone)]
pub struct StrategyArm {
    pub name: String,
    pub when: Option<EdgeGuard>,
    pub flow: FlowGraph,
}

/// What a flow node is.
#[derive(Debug, Clone)]
pub enum NodeKind {
    /// A pipe-task instance resolved against the registry.
    Task { task_type: String },
    /// An S-task: selects and runs exactly one arm at runtime.
    Strategy { arms: Vec<StrategyArm> },
}

/// A node in a flow.
#[derive(Debug, Clone)]
pub struct FlowNode {
    pub id: NodeId,
    /// Instance name, unique per flow ("pruning", "pruning2", …).
    pub instance: String,
    pub kind: NodeKind,
}

impl FlowNode {
    /// Task type name for task nodes; `"S-TASK"` for strategy nodes.
    pub fn task_type(&self) -> &str {
        match &self.kind {
            NodeKind::Task { task_type } => task_type,
            NodeKind::Strategy { .. } => "S-TASK",
        }
    }

    pub fn is_strategy(&self) -> bool {
        matches!(self.kind, NodeKind::Strategy { .. })
    }
}

/// A back edge enabling iteration (cyclic design flows, paper §III).
///
/// An unguarded back edge fires when its source task requests another
/// pass (`TaskOutcome::request_iteration`).  A guarded back edge fires
/// when its predicate holds against the meta-model after the source
/// node runs — the spec-level way to express cross-stage feedback like
/// "VIVADO-HLS → QUANTIZATION while `synth.dsp` exceeds the budget".
/// Both are bounded by `max_iters`.
#[derive(Debug, Clone)]
pub struct BackEdge {
    pub from: NodeId,
    pub to: NodeId,
    /// Hard bound on re-executions of the enclosed sub-path.
    pub max_iters: usize,
    /// Optional firing predicate (metric-driven iteration).
    pub when: Option<EdgeGuard>,
}

/// Everything the engine precomputes from one validation pass: the
/// deterministic topological order, the order-position of every node
/// (O(1) back-edge jumps), and the split forward in-degrees used by
/// the multiplicity check.
#[derive(Debug, Clone)]
pub struct FlowPlan {
    pub order: Vec<NodeId>,
    /// `pos[node]` = index of `node` in `order`.
    pub pos: Vec<usize>,
    /// Unguarded forward in-degree per node.
    pub in_plain: Vec<usize>,
    /// Guarded (conditional) forward in-degree per node.
    pub in_guarded: Vec<usize>,
    /// Edge/back-edge counts at validation time — lets the engine
    /// detect a graph mutated after its plan was computed.
    pub n_edges: usize,
    pub n_back_edges: usize,
}

impl FlowPlan {
    /// Does this plan fully describe `graph`?  A structural check in
    /// O(V + E) — order is a permutation positioned by `pos`, every
    /// forward edge points forward in it, split in-degrees match, and
    /// back edges are backward with positive budgets — so a graph
    /// swapped or mutated after validation (even preserving counts)
    /// can never run against a stale plan.
    pub fn matches(&self, graph: &FlowGraph) -> bool {
        let n = graph.nodes().len();
        if self.order.len() != n
            || self.pos.len() != n
            || self.n_back_edges != graph.back_edges().len()
        {
            return false;
        }
        for (i, &node) in self.order.iter().enumerate() {
            if node >= n || self.pos[node] != i {
                return false;
            }
        }
        let mut n_edges = 0usize;
        let mut in_plain = vec![0usize; n];
        let mut in_guarded = vec![0usize; n];
        for (f, t, guard) in graph.guarded_edges() {
            n_edges += 1;
            if self.pos[f] >= self.pos[t] {
                return false;
            }
            if guard.is_some() {
                in_guarded[t] += 1;
            } else {
                in_plain[t] += 1;
            }
        }
        n_edges == self.n_edges
            && in_plain == self.in_plain
            && in_guarded == self.in_guarded
            && graph
                .back_edges()
                .iter()
                .all(|be| self.pos[be.to] <= self.pos[be.from] && be.max_iters >= 1)
    }
}

/// Directed flow graph.  Forward edges must be acyclic (validated); back
/// edges may close cycles and drive iteration.
#[derive(Debug, Default, Clone)]
pub struct FlowGraph {
    pub name: String,
    nodes: Vec<FlowNode>,
    edges: BTreeMap<(NodeId, NodeId), Option<EdgeGuard>>,
    back_edges: Vec<BackEdge>,
}

impl FlowGraph {
    pub fn new(name: impl Into<String>) -> Self {
        FlowGraph { name: name.into(), ..Default::default() }
    }

    /// Add a task instance; returns its node id.
    pub fn add_task(&mut self, instance: impl Into<String>, task_type: impl Into<String>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(FlowNode {
            id,
            instance: instance.into(),
            kind: NodeKind::Task { task_type: task_type.into() },
        });
        id
    }

    /// Add a strategy (S-task) node selecting one of `arms` at runtime.
    pub fn add_strategy(
        &mut self,
        instance: impl Into<String>,
        arms: Vec<StrategyArm>,
    ) -> Result<NodeId> {
        let instance = instance.into();
        if arms.is_empty() {
            return Err(Error::Flow(format!("strategy {instance:?} has no arms")));
        }
        let mut seen = BTreeSet::new();
        for arm in &arms {
            if !seen.insert(arm.name.clone()) {
                return Err(Error::Flow(format!(
                    "strategy {instance:?} has duplicate arm {:?}",
                    arm.name
                )));
            }
        }
        let id = self.nodes.len();
        self.nodes.push(FlowNode { id, instance, kind: NodeKind::Strategy { arms } });
        Ok(id)
    }

    /// Add an unconditional dependency edge from → to.
    pub fn connect(&mut self, from: NodeId, to: NodeId) -> Result<()> {
        self.insert_edge(from, to, None)
    }

    /// Add a conditional edge: taken only when `guard` holds at the
    /// moment the engine reaches `to`.
    pub fn connect_when(&mut self, from: NodeId, to: NodeId, guard: EdgeGuard) -> Result<()> {
        self.insert_edge(from, to, Some(guard))
    }

    fn insert_edge(&mut self, from: NodeId, to: NodeId, guard: Option<EdgeGuard>) -> Result<()> {
        self.check_node(from)?;
        self.check_node(to)?;
        if from == to {
            return Err(Error::Flow(format!("self edge on node {from}")));
        }
        // one edge per (from, to): silently last-winning guards would
        // change control flow; route alternatives through distinct nodes
        if self.edges.contains_key(&(from, to)) {
            return Err(Error::Flow(format!(
                "duplicate edge {from} -> {to} (one edge per node pair; \
                 guards cannot be stacked)"
            )));
        }
        self.edges.insert((from, to), guard);
        Ok(())
    }

    /// Add a back edge driving iteration of the sub-path to..=from.
    pub fn connect_back(&mut self, from: NodeId, to: NodeId, max_iters: usize) -> Result<()> {
        self.check_node(from)?;
        self.check_node(to)?;
        self.back_edges.push(BackEdge { from, to, max_iters, when: None });
        Ok(())
    }

    /// Add a guarded back edge: fires (while budget remains) whenever
    /// `guard` holds after the source node runs, independent of the
    /// task's own iteration request.
    pub fn connect_back_when(
        &mut self,
        from: NodeId,
        to: NodeId,
        max_iters: usize,
        guard: EdgeGuard,
    ) -> Result<()> {
        self.check_node(from)?;
        self.check_node(to)?;
        self.back_edges.push(BackEdge { from, to, max_iters, when: Some(guard) });
        Ok(())
    }

    fn check_node(&self, id: NodeId) -> Result<()> {
        if id >= self.nodes.len() {
            return Err(Error::Flow(format!("unknown node {id}")));
        }
        Ok(())
    }

    pub fn nodes(&self) -> &[FlowNode] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> Result<&FlowNode> {
        self.nodes.get(id).ok_or_else(|| Error::Flow(format!("unknown node {id}")))
    }

    /// Node id by instance name.
    pub fn node_by_instance(&self, instance: &str) -> Option<NodeId> {
        self.nodes.iter().find(|n| n.instance == instance).map(|n| n.id)
    }

    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.edges.keys().copied()
    }

    /// Forward edges with their guards.
    pub fn guarded_edges(
        &self,
    ) -> impl Iterator<Item = (NodeId, NodeId, Option<&EdgeGuard>)> + '_ {
        self.edges.iter().map(|(&(f, t), g)| (f, t, g.as_ref()))
    }

    pub fn back_edges(&self) -> &[BackEdge] {
        &self.back_edges
    }

    /// In-degree over forward edges (multiplicity checking).
    pub fn in_degree(&self, id: NodeId) -> usize {
        self.edges.keys().filter(|(_, t)| *t == id).count()
    }

    /// All forward-edge in-degrees, indexable by [`NodeId`], computed in
    /// one pass over the edge set (the engine's multiplicity check is
    /// O(V + E) with this instead of O(V·E) via per-node [`in_degree`]).
    ///
    /// [`in_degree`]: FlowGraph::in_degree
    pub fn in_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.nodes.len()];
        for &(_, t) in self.edges.keys() {
            deg[t] += 1;
        }
        deg
    }

    pub fn out_degree(&self, id: NodeId) -> usize {
        self.edges.keys().filter(|(f, _)| *f == id).count()
    }

    /// All forward-edge out-degrees in one pass (counterpart of
    /// [`in_degrees`](FlowGraph::in_degrees); sub-flow exit detection).
    pub fn out_degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.nodes.len()];
        for &(f, _) in self.edges.keys() {
            deg[f] += 1;
        }
        deg
    }

    /// Deterministic topological order over the forward edges.
    ///
    /// Kahn's algorithm with the lowest-id tie-break, so the same graph
    /// always executes in the same order (task orchestration is
    /// single-threaded by design; parallelism lives in the DSE probe
    /// pool and the multi-flow explorer, both of which preserve
    /// deterministic traces).
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let mut indeg: BTreeMap<NodeId, usize> =
            self.nodes.iter().map(|n| (n.id, 0)).collect();
        for (_, t) in self.edges.keys() {
            *indeg.get_mut(t).unwrap() += 1;
        }
        let mut ready: BTreeSet<NodeId> = indeg
            .iter()
            .filter(|(_, &d)| d == 0)
            .map(|(&id, _)| id)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(&id) = ready.iter().next() {
            ready.remove(&id);
            order.push(id);
            for (f, t) in self.edges.keys() {
                if *f == id {
                    let d = indeg.get_mut(t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        ready.insert(*t);
                    }
                }
            }
        }
        if order.len() != self.nodes.len() {
            return Err(Error::Flow(
                "forward edges contain a cycle (use connect_back for iteration)"
                    .into(),
            ));
        }
        Ok(order)
    }

    /// Validate the whole graph once and return the engine's
    /// [`FlowPlan`]: topo order + position map + split in-degrees.
    /// Checks back edges (target must precede source, positive budget)
    /// and recursively validates every strategy arm's child flow.
    pub fn validate(&self) -> Result<FlowPlan> {
        let order = self.topo_order()?;
        let mut pos = vec![0usize; self.nodes.len()];
        for (i, &n) in order.iter().enumerate() {
            pos[n] = i;
        }
        for be in &self.back_edges {
            if pos[be.to] > pos[be.from] {
                return Err(Error::Flow(format!(
                    "back edge {} -> {} does not point backwards",
                    be.from, be.to
                )));
            }
            if be.max_iters == 0 {
                return Err(Error::Flow("back edge max_iters must be >= 1".into()));
            }
        }
        let mut in_plain = vec![0usize; self.nodes.len()];
        let mut in_guarded = vec![0usize; self.nodes.len()];
        for (&(_, t), guard) in &self.edges {
            if guard.is_some() {
                in_guarded[t] += 1;
            } else {
                in_plain[t] += 1;
            }
        }
        for node in &self.nodes {
            if let NodeKind::Strategy { arms } = &node.kind {
                for arm in arms {
                    arm.flow.validate().map_err(|e| {
                        Error::Flow(format!(
                            "strategy {:?} arm {:?}: {e}",
                            node.instance, arm.name
                        ))
                    })?;
                }
            }
        }
        Ok(FlowPlan {
            order,
            pos,
            in_plain,
            in_guarded,
            n_edges: self.edges.len(),
            n_back_edges: self.back_edges.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> FlowGraph {
        let mut g = FlowGraph::new("chain");
        let a = g.add_task("gen", "KERAS-MODEL-GEN");
        let b = g.add_task("prune", "PRUNING");
        let c = g.add_task("hls", "HLS4ML");
        g.connect(a, b).unwrap();
        g.connect(b, c).unwrap();
        g
    }

    fn guard(metric: &str, op: CmpOp, value: f64) -> EdgeGuard {
        EdgeGuard { metric: metric.into(), op, value }
    }

    #[test]
    fn topo_order_of_chain() {
        assert_eq!(chain().topo_order().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn topo_order_deterministic_on_diamond() {
        let mut g = FlowGraph::new("diamond");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        let d = g.add_task("d", "T");
        g.connect(a, b).unwrap();
        g.connect(a, c).unwrap();
        g.connect(b, d).unwrap();
        g.connect(c, d).unwrap();
        // lowest-id tie-break => b before c
        assert_eq!(g.topo_order().unwrap(), vec![a, b, c, d]);
    }

    #[test]
    fn forward_cycle_rejected() {
        let mut g = FlowGraph::new("cyc");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        g.connect(a, b).unwrap();
        g.connect(b, a).unwrap();
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn self_edge_rejected() {
        let mut g = FlowGraph::new("s");
        let a = g.add_task("a", "T");
        assert!(g.connect(a, a).is_err());
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut g = FlowGraph::new("dup");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        g.connect(a, b).unwrap();
        // a second edge on the same pair must not silently replace the
        // first one's guard
        let err = g
            .connect_when(a, b, guard("a.acc", CmpOp::Ge, 0.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate edge"), "{err}");
    }

    #[test]
    fn back_edge_validation() {
        let mut g = chain();
        g.connect_back(2, 0, 3).unwrap();
        assert!(g.validate().is_ok());
        // forward-pointing back edge rejected
        let mut g2 = chain();
        g2.connect_back(0, 2, 3).unwrap();
        assert!(g2.validate().is_err());
        // zero max_iters rejected
        let mut g3 = chain();
        g3.connect_back(2, 0, 0).unwrap();
        assert!(g3.validate().is_err());
    }

    #[test]
    fn degrees() {
        let g = chain();
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.in_degree(1), 1);
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.out_degree(2), 0);
    }

    #[test]
    fn in_degrees_matches_per_node_scan() {
        let mut g = FlowGraph::new("diamond");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        let d = g.add_task("d", "T");
        g.connect(a, b).unwrap();
        g.connect(a, c).unwrap();
        g.connect(b, d).unwrap();
        g.connect(c, d).unwrap();
        let degs = g.in_degrees();
        assert_eq!(degs, vec![0, 1, 1, 2]);
        for id in 0..4 {
            assert_eq!(degs[id], g.in_degree(id));
        }
        // back edges must not contribute to forward in-degrees
        g.connect_back(d, a, 2).unwrap();
        assert_eq!(g.in_degrees(), vec![0, 1, 1, 2]);
    }

    #[test]
    fn unknown_node_errors() {
        let mut g = FlowGraph::new("x");
        let a = g.add_task("a", "T");
        assert!(g.connect(a, 99).is_err());
        assert!(g.node(99).is_err());
    }

    #[test]
    fn plan_pos_matches_order() {
        let g = chain();
        let plan = g.validate().unwrap();
        for (i, &n) in plan.order.iter().enumerate() {
            assert_eq!(plan.pos[n], i);
        }
    }

    #[test]
    fn plan_detects_post_validation_mutation() {
        let mut g = chain();
        let plan = g.validate().unwrap();
        assert!(plan.matches(&g));
        let d = g.add_task("extra", "T");
        assert!(!plan.matches(&g));
        let plan2 = g.validate().unwrap();
        assert!(plan2.matches(&g));
        g.connect(0, d).unwrap();
        assert!(!plan2.matches(&g));
        let plan3 = g.validate().unwrap();
        g.connect_back(d, 0, 1).unwrap();
        assert!(!plan3.matches(&g));
    }

    #[test]
    fn out_degrees_matches_per_node_scan() {
        let mut g = FlowGraph::new("fan");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        g.connect(a, b).unwrap();
        g.connect(a, c).unwrap();
        let degs = g.out_degrees();
        assert_eq!(degs, vec![2, 0, 0]);
        for id in 0..3 {
            assert_eq!(degs[id], g.out_degree(id));
        }
    }

    #[test]
    fn plan_splits_guarded_in_degrees() {
        let mut g = FlowGraph::new("guarded");
        let a = g.add_task("a", "T");
        let b = g.add_task("b", "T");
        let c = g.add_task("c", "T");
        g.connect(a, c).unwrap();
        g.connect(a, b).unwrap();
        g.connect_when(b, c, guard("a.acc", CmpOp::Ge, 0.5)).unwrap();
        let plan = g.validate().unwrap();
        assert_eq!(plan.in_plain[c], 1);
        assert_eq!(plan.in_guarded[c], 1);
        assert_eq!(plan.in_plain[b], 1);
        assert_eq!(plan.in_guarded[b], 0);
    }

    #[test]
    fn cmp_op_parse_apply_roundtrip() {
        for (s, lhs, rhs, expect) in [
            ("<", 1.0, 2.0, true),
            ("<=", 2.0, 2.0, true),
            (">", 1.0, 2.0, false),
            (">=", 2.0, 2.0, true),
            ("==", 3.0, 3.0, true),
            ("!=", 3.0, 3.0, false),
        ] {
            let op = CmpOp::parse(s).unwrap();
            assert_eq!(op.apply(lhs, rhs), expect, "{s}");
            assert_eq!(op.to_string(), s);
        }
        assert!(CmpOp::parse("~=").is_err());
    }

    #[test]
    fn strategy_node_validation() {
        let mut arm_flow = FlowGraph::new("arm");
        arm_flow.add_task("p", "PRUNING");
        let mut g = FlowGraph::new("strat");
        let gen = g.add_task("gen", "GEN");
        let s = g
            .add_strategy(
                "opt",
                vec![
                    StrategyArm {
                        name: "agg".into(),
                        when: Some(guard("gen.accuracy", CmpOp::Ge, 0.7)),
                        flow: arm_flow.clone(),
                    },
                    StrategyArm { name: "light".into(), when: None, flow: arm_flow.clone() },
                ],
            )
            .unwrap();
        g.connect(gen, s).unwrap();
        assert!(g.validate().is_ok());
        assert_eq!(g.node(s).unwrap().task_type(), "S-TASK");
        assert!(g.node(s).unwrap().is_strategy());

        // empty arms rejected
        assert!(g.add_strategy("s2", vec![]).is_err());
        // duplicate arm names rejected
        assert!(g
            .add_strategy(
                "s3",
                vec![
                    StrategyArm { name: "x".into(), when: None, flow: arm_flow.clone() },
                    StrategyArm { name: "x".into(), when: None, flow: arm_flow },
                ],
            )
            .is_err());

        // a strategy whose arm contains a cyclic flow fails validation
        let mut bad_arm = FlowGraph::new("bad");
        let x = bad_arm.add_task("x", "T");
        let y = bad_arm.add_task("y", "T");
        bad_arm.connect(x, y).unwrap();
        bad_arm.connect(y, x).unwrap();
        let mut g2 = FlowGraph::new("strat2");
        g2.add_strategy(
            "opt",
            vec![StrategyArm { name: "only".into(), when: None, flow: bad_arm }],
        )
        .unwrap();
        let err = g2.validate().unwrap_err().to_string();
        assert!(err.contains("opt") && err.contains("only"), "{err}");
    }
}
