//! The pipe task abstraction (paper §III–IV, Table I).

use std::sync::Arc;

use crate::dse::{ProbePool, ProbeService, ProbeTiers};
use crate::error::Result;
use crate::flow::session::Session;
use crate::metamodel::MetaModel;

/// O-task (self-contained optimization) vs λ-task (functional
/// transformation between model abstractions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskRole {
    Optimization,
    Lambda,
}

impl std::fmt::Display for TaskRole {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TaskRole::Optimization => write!(f, "O"),
            TaskRole::Lambda => write!(f, "λ"),
        }
    }
}

/// A declared parameter of a task (Table I's "Parameters" column).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: &'static str,
    pub description: &'static str,
    /// Rendered default (None = required / no default).
    pub default: Option<&'static str>,
}

/// What a task reports back to the engine.
#[derive(Debug, Clone, Default)]
pub struct TaskOutcome {
    /// Model-space ids this execution produced.
    pub produced: Vec<u64>,
    /// When true and the node is the source of a back edge, the engine
    /// re-executes the enclosed sub-path (bounded by the edge's max_iters).
    pub request_iteration: bool,
}

impl TaskOutcome {
    pub fn produced(ids: impl IntoIterator<Item = u64>) -> Self {
        TaskOutcome { produced: ids.into_iter().collect(), request_iteration: false }
    }
}

/// Execution context handed to a task: the shared meta-model plus the
/// process-wide session (PJRT runtime, manifest, dataset/executable caches).
pub struct TaskCtx<'a> {
    pub meta: &'a mut MetaModel,
    pub session: &'a Session,
    /// Task-instance id (CFG namespace and LOG attribution).
    pub instance: String,
    /// Engine-provided probe tiers (in-memory memos per probe kind,
    /// plus an optional persistent disk tier) shared across the whole
    /// run (set by the multi-flow explorer so identical probes dedupe
    /// across variants); `None` = each task memoizes privately.
    pub services: Option<ProbeTiers>,
}

impl<'a> TaskCtx<'a> {
    /// Scoped CFG lookups with declared-default fallback handled by tasks.
    pub fn cfg_f64(&self, param: &str, default: f64) -> f64 {
        self.meta.cfg.get_f64(&self.instance, param).unwrap_or(default)
    }

    pub fn cfg_usize(&self, param: &str, default: usize) -> usize {
        self.meta.cfg.get_usize(&self.instance, param).unwrap_or(default)
    }

    pub fn cfg_str(&self, param: &str, default: &str) -> String {
        self.meta
            .cfg
            .get_str(&self.instance, param)
            .unwrap_or(default)
            .to_string()
    }

    pub fn cfg_bool(&self, param: &str, default: bool) -> bool {
        self.meta.cfg.get_bool(&self.instance, param).unwrap_or(default)
    }

    /// DSE worker count for this task instance.  Precedence: the `jobs`
    /// CFG key (instance-scoped, then global — the CLI `--jobs` flag
    /// sets the global key), then `METAML_JOBS`, then available
    /// parallelism (see [`crate::dse::default_jobs`]).  A zero from the
    /// CFG falls back to the default chain.
    pub fn jobs(&self) -> usize {
        self.meta
            .cfg
            .get_usize(&self.instance, "jobs")
            .filter(|&n| n >= 1)
            .unwrap_or_else(crate::dse::default_jobs)
    }

    /// The probe service for this task run: sized by [`Self::jobs`],
    /// backed by the engine's shared probe tiers when they are active
    /// (multi-flow exploration, `--cache-dir` persistence) or private
    /// in-memory memos otherwise.  Tasks program against the trait —
    /// the engine decides where probe results actually come from.
    pub fn probes(&self) -> Arc<dyn ProbeService> {
        match &self.services {
            Some(tiers) => tiers.service(self.jobs()),
            None => Arc::new(ProbePool::new(self.jobs())),
        }
    }

    /// How many times this task instance has started in the current
    /// flow run, counting the in-progress execution (>= 1 inside
    /// [`PipeTask::run`]).  Lets tasks escalate their configuration on
    /// back-edge re-executions — e.g. QUANTIZATION widening α_q each
    /// time a VIVADO-HLS → QUANTIZATION back edge fires — while staying
    /// stateless and replay-deterministic (the count is derived from
    /// the LOG event stream, never from wall-clock state).
    pub fn runs_started(&self) -> usize {
        self.meta.log.count_task_started(&self.instance)
    }

    pub fn log_metric(&mut self, name: &str, value: f64) {
        let instance = self.instance.clone();
        self.meta.log.metric(&instance, name, value);
    }

    pub fn log_message(&mut self, text: impl Into<String>) {
        let instance = self.instance.clone();
        self.meta.log.message(&instance, text);
    }

    /// Record a wall-clock-dependent measurement (duration, cache hit
    /// count) in the LOG side table — never the replay-comparable
    /// event stream.
    pub fn log_note(&mut self, name: &str, value: f64) {
        let instance = self.instance.clone();
        self.meta.log.note(&instance, name, value);
    }
}

/// A reusable pipe task (Table I row).
pub trait PipeTask {
    /// Canonical task type name ("PRUNING", "HLS4ML", …).
    fn name(&self) -> &str;

    fn role(&self) -> TaskRole;

    /// (inputs, outputs) multiplicity, e.g. (1, 1) or (0, 1).
    fn multiplicity(&self) -> (usize, usize);

    /// Declared parameters (Table I's parameter column).
    fn params(&self) -> Vec<ParamSpec>;

    /// Execute against the meta-model.
    fn run(&self, ctx: &mut TaskCtx) -> Result<TaskOutcome>;
}
