//! Flow specifications: build FlowGraphs from JSON config or built-ins.
//!
//! A spec file is the user-facing way to compose design flows (paper:
//! "users can select a set of design-flow tasks, arrange them in a
//! desired order, and fine-tune their parameters"):
//!
//! ```json
//! {
//!   "name": "s_p_q",
//!   "cfg": { "model": "jet_dnn", "pruning.tolerate_acc_loss": 0.02 },
//!   "tasks": [
//!     {"id": "gen",   "type": "KERAS-MODEL-GEN"},
//!     {"id": "scale", "type": "SCALING"},
//!     {"id": "prune", "type": "PRUNING"}
//!   ],
//!   "edges": [["gen", "scale"], ["scale", "prune"]],
//!   "back_edges": [{"from": "prune", "to": "scale", "max_iters": 2}]
//! }
//! ```

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::flow::{FlowGraph, NodeId};
use crate::json::{self, Value};
use crate::metamodel::Cfg;

/// A parsed flow spec: graph + CFG entries.
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub graph: FlowGraph,
    pub cfg_entries: Vec<(String, Value)>,
}

impl FlowSpec {
    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> Result<FlowSpec> {
        let root = json::parse(text)?;
        let name = root.req_str("name")?.to_string();
        let mut graph = FlowGraph::new(name);
        let mut ids: BTreeMap<String, NodeId> = BTreeMap::new();

        for t in root.req_array("tasks")? {
            let id = t.req_str("id")?.to_string();
            let ty = t.req_str("type")?.to_string();
            if ids.contains_key(&id) {
                return Err(Error::Config(format!("duplicate task id {id:?}")));
            }
            let node = graph.add_task(id.clone(), ty);
            ids.insert(id, node);
        }

        let resolve = |name: &str| -> Result<NodeId> {
            ids.get(name)
                .copied()
                .ok_or_else(|| Error::Config(format!("unknown task id {name:?}")))
        };

        for e in root.req_array("edges")? {
            let pair = e
                .as_array()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| Error::Config("edge must be [from, to]".into()))?;
            let from = pair[0]
                .as_str()
                .ok_or_else(|| Error::Config("edge endpoint must be a string".into()))?;
            let to = pair[1]
                .as_str()
                .ok_or_else(|| Error::Config("edge endpoint must be a string".into()))?;
            graph.connect(resolve(from)?, resolve(to)?)?;
        }

        if let Some(Value::Array(back)) = root.get("back_edges") {
            for b in back {
                graph.connect_back(
                    resolve(b.req_str("from")?)?,
                    resolve(b.req_str("to")?)?,
                    b.req_usize("max_iters")?,
                )?;
            }
        }

        let mut cfg_entries = Vec::new();
        if let Some(Value::Object(map)) = root.get("cfg") {
            for (k, v) in map {
                cfg_entries.push((k.clone(), v.clone()));
            }
        }

        graph.validate()?;
        Ok(FlowSpec { graph, cfg_entries })
    }

    pub fn load(path: &str) -> Result<FlowSpec> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn apply_cfg(&self, cfg: &mut Cfg) {
        for (k, v) in &self.cfg_entries {
            cfg.set(k.clone(), v.clone());
        }
    }
}

/// The paper's flow architectures as built-in specs.
pub fn builtin_flow_names() -> Vec<&'static str> {
    vec!["baseline", "pruning", "scaling", "quantization", "s_p_q", "p_s_q"]
}

/// Construct a built-in flow (Fig 2 architectures).
///
/// All built-ins end with HLS4ML → VIVADO-HLS so every run produces an
/// RTL report; `baseline` is the no-O-task reference flow.
pub fn builtin_flow(name: &str) -> Result<FlowSpec> {
    let chain = |flow_name: &str, middle: &[(&str, &str)]| {
        let mut tasks = vec![("gen", "KERAS-MODEL-GEN")];
        tasks.extend_from_slice(middle);
        // quantization runs at the HLS level => after HLS4ML (Fig 2b)
        let q_at_hls = middle.iter().any(|(id, _)| *id == "quantize");
        let mut pre_hls: Vec<(&str, &str)> =
            tasks.iter().copied().filter(|(id, _)| !(q_at_hls && *id == "quantize")).collect();
        pre_hls.push(("hls4ml", "HLS4ML"));
        if q_at_hls {
            pre_hls.push(("quantize", "QUANTIZATION"));
        }
        pre_hls.push(("synth", "VIVADO-HLS"));
        let mut spec = String::new();
        spec.push_str(&format!("{{\"name\": \"{flow_name}\", \"tasks\": ["));
        spec.push_str(
            &pre_hls
                .iter()
                .map(|(id, ty)| format!("{{\"id\": \"{id}\", \"type\": \"{ty}\"}}"))
                .collect::<Vec<_>>()
                .join(", "),
        );
        spec.push_str("], \"edges\": [");
        spec.push_str(
            &pre_hls
                .windows(2)
                .map(|w| format!("[\"{}\", \"{}\"]", w[0].0, w[1].0))
                .collect::<Vec<_>>()
                .join(", "),
        );
        spec.push_str("]}");
        FlowSpec::parse(&spec)
    };

    match name {
        "baseline" => chain("baseline", &[]),
        "pruning" => chain("pruning", &[("prune", "PRUNING")]),
        "scaling" => chain("scaling", &[("scale", "SCALING")]),
        "quantization" => chain("quantization", &[("quantize", "QUANTIZATION")]),
        // Fig 2(b): scaling → pruning → (HLS4ML) → quantization
        "s_p_q" => chain(
            "s_p_q",
            &[("scale", "SCALING"), ("prune", "PRUNING"), ("quantize", "QUANTIZATION")],
        ),
        // Fig 2(c): different O-task order — pruning → scaling → quantization
        "p_s_q" => chain(
            "p_s_q",
            &[("prune", "PRUNING"), ("scale", "SCALING"), ("quantize", "QUANTIZATION")],
        ),
        other => Err(Error::Config(format!("unknown builtin flow {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_spec() {
        let spec = FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "KERAS-MODEL-GEN"}],
                "edges": []}"#,
        )
        .unwrap();
        assert_eq!(spec.graph.nodes().len(), 1);
        assert!(spec.cfg_entries.is_empty());
    }

    #[test]
    fn parse_with_edges_cfg_and_back_edges() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "cfg": {"model": "jet_dnn", "prune.tolerate_acc_loss": 0.04},
                "tasks": [{"id": "gen", "type": "KERAS-MODEL-GEN"},
                           {"id": "prune", "type": "PRUNING"}],
                "edges": [["gen", "prune"]],
                "back_edges": [{"from": "prune", "to": "gen", "max_iters": 2}]}"#,
        )
        .unwrap();
        assert_eq!(spec.graph.nodes().len(), 2);
        assert_eq!(spec.graph.back_edges().len(), 1);
        assert_eq!(spec.cfg_entries.len(), 2);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FlowSpec::parse("{}").is_err());
        // duplicate ids
        assert!(FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"},
                {"id": "a", "type": "Y"}], "edges": []}"#
        )
        .is_err());
        // unknown edge endpoint
        assert!(FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"}],
                "edges": [["a", "b"]]}"#
        )
        .is_err());
    }

    #[test]
    fn builtins_build_and_validate() {
        for name in builtin_flow_names() {
            let spec = builtin_flow(name).unwrap();
            assert!(spec.graph.validate().is_ok(), "{name}");
            // every builtin ends in VIVADO-HLS
            assert!(spec
                .graph
                .nodes()
                .iter()
                .any(|n| n.task_type == "VIVADO-HLS"));
        }
        assert!(builtin_flow("nope").is_err());
    }

    #[test]
    fn s_p_q_order_matches_fig2b() {
        let spec = builtin_flow("s_p_q").unwrap();
        let order = spec.graph.topo_order().unwrap();
        let types: Vec<&str> = order
            .iter()
            .map(|&id| spec.graph.node(id).unwrap().task_type.as_str())
            .collect();
        assert_eq!(
            types,
            vec![
                "KERAS-MODEL-GEN",
                "SCALING",
                "PRUNING",
                "HLS4ML",
                "QUANTIZATION",
                "VIVADO-HLS"
            ]
        );
    }
}
