//! Flow specifications: build FlowGraphs from JSON config or built-ins.
//!
//! A spec file is the user-facing way to compose design flows (paper:
//! "users can select a set of design-flow tasks, arrange them in a
//! desired order, and fine-tune their parameters"):
//!
//! ```json
//! {
//!   "name": "s_p_q",
//!   "cfg": { "model": "jet_dnn", "prune.tolerate_acc_loss": 0.02 },
//!   "tasks": [
//!     {"id": "gen",   "type": "KERAS-MODEL-GEN"},
//!     {"id": "scale", "type": "SCALING"},
//!     {"id": "prune", "type": "PRUNING"}
//!   ],
//!   "edges": [["gen", "scale"], ["scale", "prune"]],
//!   "back_edges": [{"from": "prune", "to": "scale", "max_iters": 2}]
//! }
//! ```
//!
//! The composable-IR extensions:
//!
//! * **Conditional edges** — an edge may be an object with a `when`
//!   guard over meta-model metrics; the edge is taken only when the
//!   predicate holds at runtime:
//!   `{"from": "prune", "to": "quantize",
//!     "when": {"metric": "prune.accuracy", "op": ">=", "value": 0.72}}`
//! * **Guarded back edges** — a back edge may also carry a `when`
//!   guard; it then fires (bounded by `max_iters`) whenever the
//!   predicate holds after the source task runs, which is how specs
//!   express cross-stage feedback from the hardware stage:
//!   `{"from": "synth", "to": "quantize", "max_iters": 2,
//!     "when": {"metric": "synth.dsp", "op": ">", "value": 64}}`
//! * **Strategy (S-task) nodes** — a task entry with a `strategy` key
//!   declares arms (each a child flow, optionally guarded); exactly one
//!   arm is selected and executed at runtime:
//!   `{"id": "opt", "strategy": {"arms": [
//!      {"name": "aggressive", "when": {...}, "flow": {...}},
//!      {"name": "light", "flow": {...}}]}}`
//! * **Sub-flows** — a task entry with a `flow` key embeds a child flow,
//!   flattened at parse time with `"<id>."`-prefixed instance names;
//!   edges touching the composite id attach to the child's entry
//!   (no internal in-edge) / exit (no internal out-edge) nodes:
//!   `{"id": "opt", "flow": {"tasks": [...], "edges": [...]}}`
//! * **Variant grids** — an `explore` section declares task-order
//!   permutations and/or CFG value grids for the multi-flow explorer
//!   (see [`crate::flow::explore`]):
//!   `"explore": {"orders": [["gen","scale","prune"], ...],
//!                "cfg_grid": {"prune.tolerate_acc_loss": [0.01, 0.03]}}`
//! * **Budgeted search** — a `search` section selects how the variant
//!   space is traversed (strategy, evaluation budget, seed, numeric
//!   range dimensions, optional online surrogate); see
//!   [`crate::search`]:
//!   `"search": {"strategy": "evolve", "budget": 8, "seed": 7,
//!               "range": {"hls.clock_period": {"min": 4, "max": 10}},
//!               "surrogate": {"warmup": 2, "every": 2}}`

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::flow::explore::ExploreSpec;
use crate::flow::graph::{CmpOp, EdgeGuard, FlowPlan, StrategyArm};
use crate::flow::{FlowGraph, NodeId};
use crate::json::{self, Value};
use crate::metamodel::Cfg;
use crate::search::SearchSpec;

/// A parsed flow spec: graph + CFG entries + optional variant grid +
/// optional budgeted-search section, with the validation plan computed
/// once at parse time (the engine's `run_spec` reuses it instead of
/// re-validating).
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub graph: FlowGraph,
    pub cfg_entries: Vec<(String, Value)>,
    pub explore: Option<ExploreSpec>,
    /// The `search` section: strategy/budget/seed + numeric range
    /// dimensions for the budgeted search (see [`crate::search`]).
    pub search: Option<SearchSpec>,
    plan: FlowPlan,
}

/// What a task id resolves to after sub-flow flattening.
enum Resolved {
    Single(NodeId),
    Composite { entries: Vec<NodeId>, exits: Vec<NodeId> },
}

impl Resolved {
    fn entries(&self) -> Vec<NodeId> {
        match self {
            Resolved::Single(id) => vec![*id],
            Resolved::Composite { entries, .. } => entries.clone(),
        }
    }

    fn exits(&self) -> Vec<NodeId> {
        match self {
            Resolved::Single(id) => vec![*id],
            Resolved::Composite { exits, .. } => exits.clone(),
        }
    }
}

/// Parse `{"metric": ..., "op": ..., "value": ...}` into a guard.
pub fn parse_guard(v: &Value) -> Result<EdgeGuard> {
    Ok(EdgeGuard {
        metric: v.req_str("metric")?.to_string(),
        op: CmpOp::parse(v.req_str("op")?)?,
        value: v.req_f64("value")?,
    })
}

/// Parse one `{tasks, edges, back_edges?}` object into a fresh graph
/// (used for the top level and for strategy-arm flows).
fn parse_flow_graph(name: &str, obj: &Value) -> Result<FlowGraph> {
    let mut graph = FlowGraph::new(name);
    let mut ids: BTreeMap<String, Resolved> = BTreeMap::new();
    parse_scope(&mut graph, obj, "", &mut ids)?;
    Ok(graph)
}

/// Parse the tasks + edges of one scope into `graph`, prefixing
/// instance names with `prefix` (sub-flow flattening) and recording
/// what each id resolves to in `ids`.
fn parse_scope(
    graph: &mut FlowGraph,
    obj: &Value,
    prefix: &str,
    ids: &mut BTreeMap<String, Resolved>,
) -> Result<()> {
    for t in obj.req_array("tasks")? {
        let id = t.req_str("id")?.to_string();
        let full = format!("{prefix}{id}");
        let resolved = if let Some(strat) = t.get("strategy") {
            let arms = parse_arms(strat)?;
            Resolved::Single(graph.add_strategy(full.clone(), arms)?)
        } else if let Some(child) = t.get("flow") {
            let before = graph.nodes().len();
            parse_scope(graph, child, &format!("{full}."), ids)?;
            let child_nodes: Vec<NodeId> = (before..graph.nodes().len()).collect();
            if child_nodes.is_empty() {
                return Err(Error::Config(format!("sub-flow {full:?} has no tasks")));
            }
            // At this point the graph holds exactly the child's internal
            // edges (outer edges are added after all tasks of the outer
            // scope parse), so degree-0 identifies entries/exits —
            // computed in one pass each, not per node.
            let (in_deg, out_deg) = (graph.in_degrees(), graph.out_degrees());
            let entries: Vec<NodeId> =
                child_nodes.iter().copied().filter(|&n| in_deg[n] == 0).collect();
            let exits: Vec<NodeId> =
                child_nodes.iter().copied().filter(|&n| out_deg[n] == 0).collect();
            Resolved::Composite { entries, exits }
        } else {
            let ty = t.req_str("type")?.to_string();
            Resolved::Single(graph.add_task(full.clone(), ty))
        };
        if ids.insert(full.clone(), resolved).is_some() {
            return Err(Error::Config(format!(
                "duplicate task id {full:?} (after sub-flow flattening)"
            )));
        }
    }

    let resolve = |ids: &BTreeMap<String, Resolved>, name: &str| -> Result<(Vec<NodeId>, Vec<NodeId>)> {
        let full = format!("{prefix}{name}");
        ids.get(&full)
            .map(|r| (r.entries(), r.exits()))
            .ok_or_else(|| Error::Config(format!("unknown task id {full:?}")))
    };

    for e in obj.req_array("edges")? {
        let (from, to, guard) = if let Some(pair) = e.as_array() {
            if pair.len() != 2 {
                return Err(Error::Config("edge must be [from, to]".into()));
            }
            let ends: Vec<&str> = pair
                .iter()
                .map(|p| {
                    p.as_str().ok_or_else(|| {
                        Error::Config("edge endpoint must be a string".into())
                    })
                })
                .collect::<Result<_>>()?;
            (ends[0], ends[1], None)
        } else {
            let guard = match e.get("when") {
                Some(w) => Some(parse_guard(w)?),
                None => None,
            };
            (e.req_str("from")?, e.req_str("to")?, guard)
        };
        let (_, from_exits) = resolve(ids, from)?;
        let (to_entries, _) = resolve(ids, to)?;
        for &f in &from_exits {
            for &t in &to_entries {
                match &guard {
                    Some(g) => graph.connect_when(f, t, g.clone())?,
                    None => graph.connect(f, t)?,
                }
            }
        }
    }

    if let Some(Value::Array(back)) = obj.get("back_edges") {
        for b in back {
            let (from_name, to_name) = (b.req_str("from")?, b.req_str("to")?);
            let (_, from_exits) = resolve(ids, from_name)?;
            let (to_entries, _) = resolve(ids, to_name)?;
            // a back edge must bind exactly one (source, target) pair —
            // fanning out over a multi-entry/exit composite would
            // multiply the declared max_iters budget
            if from_exits.len() != 1 || to_entries.len() != 1 {
                return Err(Error::Config(format!(
                    "back edge {from_name:?} -> {to_name:?} must resolve to a \
                     single node pair (composite endpoint has {} exits / {} \
                     entries)",
                    from_exits.len(),
                    to_entries.len()
                )));
            }
            let max_iters = b.req_usize("max_iters")?;
            // an optional `when` guard turns the edge metric-driven:
            // it fires while the predicate holds (bounded by max_iters)
            // instead of waiting for a task iteration request
            match b.get("when") {
                Some(w) => graph.connect_back_when(
                    from_exits[0],
                    to_entries[0],
                    max_iters,
                    parse_guard(w)?,
                )?,
                None => graph.connect_back(from_exits[0], to_entries[0], max_iters)?,
            }
        }
    }
    Ok(())
}

/// Parse `{"arms": [{"name", "when"?, "flow"}]}` strategy declarations.
fn parse_arms(strat: &Value) -> Result<Vec<StrategyArm>> {
    let mut arms = Vec::new();
    for a in strat.req_array("arms")? {
        let name = a.req_str("name")?.to_string();
        let when = match a.get("when") {
            Some(w) => Some(parse_guard(w)?),
            None => None,
        };
        let flow = parse_flow_graph(&name, a.req("flow")?)?;
        arms.push(StrategyArm { name, when, flow });
    }
    Ok(arms)
}

impl FlowSpec {
    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> Result<FlowSpec> {
        let root = json::parse(text)?;
        let name = root.req_str("name")?.to_string();
        let graph = parse_flow_graph(&name, &root)?;

        let mut cfg_entries = Vec::new();
        if let Some(Value::Object(map)) = root.get("cfg") {
            for (k, v) in map {
                cfg_entries.push((k.clone(), v.clone()));
            }
        }

        let explore = match root.get("explore") {
            Some(v) => Some(ExploreSpec::parse(v, &graph)?),
            None => None,
        };

        let search = match root.get("search") {
            Some(v) => Some(SearchSpec::parse(v)?),
            None => None,
        };

        let plan = graph.validate()?;
        Ok(FlowSpec { graph, cfg_entries, explore, search, plan })
    }

    pub fn load(path: &str) -> Result<FlowSpec> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// The validation plan computed at parse time (topo order, position
    /// map, split in-degrees).
    pub fn plan(&self) -> &FlowPlan {
        &self.plan
    }

    /// Rebuild a spec around a replacement graph, revalidating once
    /// (used by the explorer's order permutations).
    pub fn with_graph(&self, graph: FlowGraph) -> Result<FlowSpec> {
        let plan = graph.validate()?;
        Ok(FlowSpec {
            graph,
            cfg_entries: self.cfg_entries.clone(),
            explore: None,
            search: None,
            plan,
        })
    }

    pub fn apply_cfg(&self, cfg: &mut Cfg) {
        for (k, v) in &self.cfg_entries {
            cfg.set(k.clone(), v.clone());
        }
    }
}

/// The paper's flow architectures as built-in specs.
pub fn builtin_flow_names() -> Vec<&'static str> {
    vec!["baseline", "pruning", "scaling", "quantization", "s_p_q", "p_s_q"]
}

/// Construct a built-in flow (Fig 2 architectures).
///
/// All built-ins end with HLS4ML → VIVADO-HLS so every run produces an
/// RTL report; `baseline` is the no-O-task reference flow.
pub fn builtin_flow(name: &str) -> Result<FlowSpec> {
    let chain = |flow_name: &str, middle: &[(&str, &str)]| {
        let mut tasks = vec![("gen", "KERAS-MODEL-GEN")];
        tasks.extend_from_slice(middle);
        // quantization runs at the HLS level => after HLS4ML (Fig 2b)
        let q_at_hls = middle.iter().any(|(id, _)| *id == "quantize");
        let mut pre_hls: Vec<(&str, &str)> =
            tasks.iter().copied().filter(|(id, _)| !(q_at_hls && *id == "quantize")).collect();
        pre_hls.push(("hls4ml", "HLS4ML"));
        if q_at_hls {
            pre_hls.push(("quantize", "QUANTIZATION"));
        }
        pre_hls.push(("synth", "VIVADO-HLS"));
        let mut spec = String::new();
        spec.push_str(&format!("{{\"name\": \"{flow_name}\", \"tasks\": ["));
        spec.push_str(
            &pre_hls
                .iter()
                .map(|(id, ty)| format!("{{\"id\": \"{id}\", \"type\": \"{ty}\"}}"))
                .collect::<Vec<_>>()
                .join(", "),
        );
        spec.push_str("], \"edges\": [");
        spec.push_str(
            &pre_hls
                .windows(2)
                .map(|w| format!("[\"{}\", \"{}\"]", w[0].0, w[1].0))
                .collect::<Vec<_>>()
                .join(", "),
        );
        spec.push_str("]}");
        FlowSpec::parse(&spec)
    };

    match name {
        "baseline" => chain("baseline", &[]),
        "pruning" => chain("pruning", &[("prune", "PRUNING")]),
        "scaling" => chain("scaling", &[("scale", "SCALING")]),
        "quantization" => chain("quantization", &[("quantize", "QUANTIZATION")]),
        // Fig 2(b): scaling → pruning → (HLS4ML) → quantization
        "s_p_q" => chain(
            "s_p_q",
            &[("scale", "SCALING"), ("prune", "PRUNING"), ("quantize", "QUANTIZATION")],
        ),
        // Fig 2(c): different O-task order — pruning → scaling → quantization
        "p_s_q" => chain(
            "p_s_q",
            &[("prune", "PRUNING"), ("scale", "SCALING"), ("quantize", "QUANTIZATION")],
        ),
        other => Err(Error::Config(format!("unknown builtin flow {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::NodeKind;

    #[test]
    fn parse_minimal_spec() {
        let spec = FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "KERAS-MODEL-GEN"}],
                "edges": []}"#,
        )
        .unwrap();
        assert_eq!(spec.graph.nodes().len(), 1);
        assert!(spec.cfg_entries.is_empty());
        assert!(spec.explore.is_none());
        assert_eq!(spec.plan().order, vec![0]);
    }

    #[test]
    fn parse_with_edges_cfg_and_back_edges() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "cfg": {"model": "jet_dnn", "prune.tolerate_acc_loss": 0.04},
                "tasks": [{"id": "gen", "type": "KERAS-MODEL-GEN"},
                           {"id": "prune", "type": "PRUNING"}],
                "edges": [["gen", "prune"]],
                "back_edges": [{"from": "prune", "to": "gen", "max_iters": 2}]}"#,
        )
        .unwrap();
        assert_eq!(spec.graph.nodes().len(), 2);
        assert_eq!(spec.graph.back_edges().len(), 1);
        assert!(spec.graph.back_edges()[0].when.is_none());
        assert_eq!(spec.cfg_entries.len(), 2);
    }

    #[test]
    fn parses_guarded_back_edges() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "quantize", "type": "QUANTIZATION"},
                           {"id": "synth", "type": "VIVADO-HLS"}],
                "edges": [["quantize", "synth"]],
                "back_edges": [{"from": "synth", "to": "quantize",
                                "max_iters": 2,
                                "when": {"metric": "synth.dsp", "op": ">",
                                         "value": 64}}]}"#,
        )
        .unwrap();
        let be = &spec.graph.back_edges()[0];
        assert_eq!(be.max_iters, 2);
        let g = be.when.as_ref().expect("guard parsed");
        assert_eq!(g.metric, "synth.dsp");
        assert_eq!(g.op, CmpOp::Gt);
        assert_eq!(g.value, 64.0);
    }

    #[test]
    fn parses_search_section() {
        let spec = FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"}], "edges": [],
                "explore": {"cfg_grid": {"k": [1, 2]}},
                "search": {"strategy": "random", "budget": 3, "seed": 11}}"#,
        )
        .unwrap();
        let s = spec.search.as_ref().expect("search section parsed");
        assert_eq!(s.strategy, "random");
        assert_eq!(s.budget, Some(3));
        assert_eq!(s.seed, 11);
        // a bad section fails the whole spec parse
        assert!(FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"}], "edges": [],
                "search": {"strategy": "nope"}}"#,
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FlowSpec::parse("{}").is_err());
        // duplicate ids
        assert!(FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"},
                {"id": "a", "type": "Y"}], "edges": []}"#
        )
        .is_err());
        // unknown edge endpoint
        assert!(FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"}],
                "edges": [["a", "b"]]}"#
        )
        .is_err());
    }

    #[test]
    fn parses_conditional_edges() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [{"from": "a", "to": "b",
                           "when": {"metric": "a.accuracy", "op": ">=", "value": 0.72}}]}"#,
        )
        .unwrap();
        let guards: Vec<_> = spec.graph.guarded_edges().collect();
        assert_eq!(guards.len(), 1);
        let g = guards[0].2.unwrap();
        assert_eq!(g.metric, "a.accuracy");
        assert_eq!(g.op, CmpOp::Ge);
        assert_eq!(g.value, 0.72);
        // bad op rejected
        assert!(FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [{"from": "a", "to": "b",
                           "when": {"metric": "a.x", "op": "~=", "value": 1}}]}"#,
        )
        .is_err());
    }

    #[test]
    fn parses_strategy_tasks() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [
                  {"id": "gen", "type": "KERAS-MODEL-GEN"},
                  {"id": "opt", "strategy": {"arms": [
                     {"name": "agg",
                      "when": {"metric": "gen.accuracy", "op": ">=", "value": 0.7},
                      "flow": {"tasks": [{"id": "prune", "type": "PRUNING"}],
                               "edges": []}},
                     {"name": "light",
                      "flow": {"tasks": [{"id": "scale", "type": "SCALING"}],
                               "edges": []}}]}}
                ],
                "edges": [["gen", "opt"]]}"#,
        )
        .unwrap();
        let opt = spec.graph.node_by_instance("opt").unwrap();
        let node = spec.graph.node(opt).unwrap();
        match &node.kind {
            NodeKind::Strategy { arms } => {
                assert_eq!(arms.len(), 2);
                assert_eq!(arms[0].name, "agg");
                assert!(arms[0].when.is_some());
                assert!(arms[1].when.is_none());
                assert_eq!(arms[1].flow.nodes().len(), 1);
            }
            _ => panic!("opt should be a strategy node"),
        }
    }

    #[test]
    fn flattens_sub_flows_with_namespacing() {
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [
                  {"id": "gen", "type": "KERAS-MODEL-GEN"},
                  {"id": "opt", "flow": {
                     "tasks": [{"id": "prune", "type": "PRUNING"},
                               {"id": "quantize", "type": "QUANTIZATION"}],
                     "edges": [["prune", "quantize"]]}},
                  {"id": "hls", "type": "HLS4ML"}
                ],
                "edges": [["gen", "opt"], ["opt", "hls"]]}"#,
        )
        .unwrap();
        let names: Vec<&str> =
            spec.graph.nodes().iter().map(|n| n.instance.as_str()).collect();
        assert_eq!(names, vec!["gen", "opt.prune", "opt.quantize", "hls"]);
        // outer edges attach to the composite's entry/exit nodes
        let gen = spec.graph.node_by_instance("gen").unwrap();
        let prune = spec.graph.node_by_instance("opt.prune").unwrap();
        let quant = spec.graph.node_by_instance("opt.quantize").unwrap();
        let hls = spec.graph.node_by_instance("hls").unwrap();
        let edges: Vec<(NodeId, NodeId)> = spec.graph.edges().collect();
        assert!(edges.contains(&(gen, prune)));
        assert!(edges.contains(&(prune, quant)));
        assert!(edges.contains(&(quant, hls)));
        assert_eq!(edges.len(), 3);
    }

    #[test]
    fn sub_flow_namespace_collision_rejected() {
        // explicit task "opt.prune" collides with flattened sub-flow node
        let err = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [
                  {"id": "opt.prune", "type": "PRUNING"},
                  {"id": "opt", "flow": {
                     "tasks": [{"id": "prune", "type": "PRUNING"}],
                     "edges": []}}
                ],
                "edges": []}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("opt.prune"), "{err}");
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn builtins_build_and_validate() {
        for name in builtin_flow_names() {
            let spec = builtin_flow(name).unwrap();
            assert!(spec.graph.validate().is_ok(), "{name}");
            // every builtin ends in VIVADO-HLS
            assert!(spec
                .graph
                .nodes()
                .iter()
                .any(|n| n.task_type() == "VIVADO-HLS"));
        }
        assert!(builtin_flow("nope").is_err());
    }

    #[test]
    fn s_p_q_order_matches_fig2b() {
        let spec = builtin_flow("s_p_q").unwrap();
        let order = spec.graph.topo_order().unwrap();
        let types: Vec<&str> = order
            .iter()
            .map(|&id| spec.graph.node(id).unwrap().task_type())
            .collect();
        assert_eq!(
            types,
            vec![
                "KERAS-MODEL-GEN",
                "SCALING",
                "PRUNING",
                "HLS4ML",
                "QUANTIZATION",
                "VIVADO-HLS"
            ]
        );
    }
}
