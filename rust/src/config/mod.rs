//! Flow-spec configuration: JSON flow definitions + the paper's built-ins.

pub mod spec;

pub use spec::{builtin_flow, builtin_flow_names, FlowSpec};
