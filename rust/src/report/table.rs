//! Aligned text tables (paper-style rows for benches and the CLI).

/// Column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        let mut row: Vec<String> = cells.to_vec();
        row.resize(self.header.len(), String::new());
        self.rows.push(row);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format helpers for table cells.
pub fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

pub fn pct(v: f64) -> String {
    format!("{v:.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "acc"]);
        t.row_strs(&["jet_dnn", "0.761"]);
        t.row_strs(&["a-much-longer-name", "0.7"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("jet_dnn"));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(&["a", "b", "c"]);
        t.row_strs(&["1"]);
        assert!(t.render().lines().count() == 3);
    }
}
