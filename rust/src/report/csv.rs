//! CSV emission for figure series (benches write bench_out/*.csv).

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// Simple CSV writer with header enforcement.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn row_f64(&mut self, cells: &[f64]) {
        let formatted: Vec<String> = cells.iter().map(|v| format!("{v}")).collect();
        self.row(&formatted);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(
                &r.iter()
                    .map(|c| {
                        if c.contains(',') || c.contains('"') {
                            format!("\"{}\"", c.replace('"', "\"\""))
                        } else {
                            c.clone()
                        }
                    })
                    .collect::<Vec<_>>()
                    .join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write to a path, creating parent directories.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_quotes() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "x,y".into()]);
        w.row_f64(&[0.5, 2.0]);
        let s = w.render();
        assert_eq!(s, "a,b\n1,\"x,y\"\n0.5,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_enforced() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }
}
