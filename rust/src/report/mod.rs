//! Report rendering: aligned text tables + CSV series for figures.

pub mod csv;
pub mod table;

pub use csv::CsvWriter;
pub use table::Table;
